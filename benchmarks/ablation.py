"""Ablation grid over the serving engine's beyond-paper features.

One live engine run per configuration (reduced llama compute, full llama-7b
economics), same workload: isolates the contribution of each feature to cost
and TTFT relative to (a) the recompute baseline and (b) the paper's plain
reuse pipeline.

    PYTHONPATH=src python -m benchmarks.ablation
"""
from __future__ import annotations

from typing import Dict, List

import jax

from repro.configs import get_config, reduced_config
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER
from repro.data.synthetic import WorkloadSpec, serving_workload
from repro.kvcache.hierarchy import TierSpec
from repro.models import registry
from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine
from repro.serving.scheduler import HedgePolicy

# The tier hierarchy rows: write-backs land hot (host_dram), the break-even
# pass demotes cold entries toward s3, and the cloud link is bounded so burst
# fetches queue instead of streaming for free in parallel.
_HIERARCHY = dict(
    tier_specs=[
        TierSpec("host_dram", 64.0),
        TierSpec("local_nvme", 512.0),
        TierSpec("s3", 4096.0, concurrency=2),
    ],
    store_tier="host_dram",
    migration_interval_s=1.0,
    spill_on_pressure=True,
)

# config name -> EngineConfig kwargs; every reuse row plans with the
# unconditional-reuse planner so the ablation isolates the execute-side
# features (tiers, overlap, hedging, prefetch), not the policy.
CONFIGS: Dict[str, dict] = {
    "recompute": dict(reuse_enabled=False),
    "paper": dict(),
    "paper+int8": dict(compress_tier="io2"),
    "paper+overlap": dict(overlap_load=True),
    "paper+hedge": dict(hedge=HedgePolicy(threshold_s=0.8)),
    "paper+prefetch": dict(prefetch_lookahead=4),
    "paper+tiers": dict(**_HIERARCHY),
    "beyond(all)": dict(
        compress_tier="io2", overlap_load=True,
        hedge=HedgePolicy(threshold_s=0.8), prefetch_lookahead=4,
    ),
    "beyond+tiers": dict(
        overlap_load=True, hedge=HedgePolicy(threshold_s=0.8),
        prefetch_lookahead=4, **_HIERARCHY,
    ),
}


def sweep(n_requests: int = 18, n_contexts: int = 3, seed: int = 0) -> List[dict]:
    cfg = reduced_config(get_config("llama-7b"))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    spec = WorkloadSpec(
        n_contexts=n_contexts,
        reuses_per_context=max(1, n_requests // n_contexts),
        context_len=96, prompt_len=16, output_len=8,
        # bursty arrivals: requests queue behind busy slots, so lookahead
        # prefetch has loads to hide (it is inert on an empty queue)
        arrival_rate_per_s=50.0, seed=seed,
    )
    reqs = serving_workload(cfg, spec)

    rows = []
    ref_tokens = None
    for name, kw in CONFIGS.items():
        eng = ServingEngine(
            cfg, params,
            engine_cfg=EngineConfig(
                max_slots=2, max_len=256, chunk_tokens=16,
                cost_arch="llama-7b", **kw,
            ),
            planner=AlwaysReusePlanner(),
            pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF),
        )
        for r in reqs:
            eng.submit(Request(**r.__dict__))
        s = eng.run()
        toks = {rec.req_id: rec.tokens for rec in eng.records}
        if name == "recompute":
            ref_tokens = toks
        rows.append(
            {
                "config": name,
                "cost": s.total_cost,
                "ttft": s.mean_ttft_s,
                "p99_e2e": s.p99_e2e_s,
                "hits": s.reuse_hits,
                "tokens_exact": toks == ref_tokens,
            }
        )
    return rows


def run() -> List[str]:
    rows = sweep()
    base = rows[0]
    return [
        f"ablation/{r['config']},{r['ttft']*1e6:.0f},"
        f"cost_x={base['cost']/max(r['cost'],1e-12):.2f};"
        f"ttft_x={base['ttft']/max(r['ttft'],1e-9):.2f};"
        f"exact={int(r['tokens_exact'])}"
        for r in rows
    ]


if __name__ == "__main__":
    rows = sweep()
    base = rows[0]
    print(f"{'config':config<16s}" if False else f"{'config':<16s} {'cost $':>9s} "
          f"{'vs base':>8s} {'TTFT s':>8s} {'vs base':>8s} {'hits':>5s} {'exact':>6s}")
    for r in rows:
        print(
            f"{r['config']:<16s} {r['cost']:9.4f} {base['cost']/r['cost']:7.2f}x "
            f"{r['ttft']:8.3f} {base['ttft']/max(r['ttft'],1e-9):7.2f}x "
            f"{r['hits']:5d} {str(r['tokens_exact']):>6s}"
        )
