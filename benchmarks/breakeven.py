"""Paper §2 Insights table: break-even reuse count N*, storage-cost fraction,
and the simplified-ratio approximation quality — extended beyond the paper
across the assigned architectures, storage tiers and int8 compression.

    PYTHONPATH=src python benchmarks/breakeven.py [--archs a,b] [--context N]

(--archs/--context cap the sweep; the CI smoke job runs a small slice.)"""
from __future__ import annotations

import argparse
from typing import List, Optional, Sequence

from repro.configs import get_config
from repro.core.cost_model import (
    Workload, break_even_reuses, cost_kv, cost_ratio, simplified_ratio,
)
from repro.core.perf_model import PerfModel, V100_X4_HF, tpu_v5e
from repro.core.pricing import AWS_PAPER, tpu_v5e_pod

ARCHS = (
    "llama-7b", "granite-34b", "mistral-nemo-12b", "qwen2-1.5b",
    "mixtral-8x22b", "olmoe-1b-7b", "jamba-1.5-large-398b", "mamba2-1.3b",
)


def table(
    L_context: int = 10_000, archs: Optional[Sequence[str]] = None
) -> List[dict]:
    w = Workload(L_context=L_context, L_prompt=32, L_output=32, N=5)
    pm_paper = PerfModel(V100_X4_HF)
    rows = []
    for arch in archs or ARCHS:
        cfg = get_config(arch)
        for tier_name in ("io2", "gp3", "s3"):
            for comp in (1.0, 0.5):
                tier = AWS_PAPER.tier(tier_name)
                n_star = break_even_reuses(
                    cfg, w, AWS_PAPER, pm_paper, tier=tier, compression=comp
                )
                ck = cost_kv(cfg, w, AWS_PAPER, pm_paper, tier=tier, compression=comp)
                rows.append(
                    {
                        "arch": arch,
                        "tier": tier_name,
                        "compression": comp,
                        "break_even_N": n_star,
                        "ratio_N5": cost_ratio(
                            cfg, w, AWS_PAPER, pm_paper, tier=tier, compression=comp
                        ),
                        "simplified_N5": simplified_ratio(cfg, w, pm_paper),
                        "storage_fraction": ck.storage / ck.total,
                    }
                )
    return rows


def run() -> List[str]:
    out = []
    for r in table():
        if r["tier"] == "io2" and r["compression"] == 1.0:
            out.append(
                f"breakeven/{r['arch']},{r['ratio_N5']*100:.0f},"
                f"N*={r['break_even_N']};storage_frac={r['storage_fraction']:.4f}"
            )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=None,
                    help="comma-separated subset (default: all)")
    ap.add_argument("--context", type=int, default=10_000)
    args = ap.parse_args()
    archs = args.archs.split(",") if args.archs else None
    for r in table(L_context=args.context, archs=archs):
        print(
            f"{r['arch']:22s} {r['tier']:4s} comp={r['compression']:.1f} "
            f"N*={str(r['break_even_N']):>5s} ratio@N5={r['ratio_N5']:.2f}x "
            f"(simplified {r['simplified_N5']:.2f}x) storage%={100*r['storage_fraction']:.2f}"
        )
