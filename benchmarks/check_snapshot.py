"""CI gate over the serve_bench artifacts: the PR's acceptance criteria,
asserted on emitted numbers so the perf and accounting claims cannot
silently rot.

    PYTHONPATH=src python benchmarks/check_snapshot.py
        [--bench BENCH_serving.json] [--metrics BENCH_serving_metrics.json]

Reads the two files ``benchmarks/serve_bench.py`` writes and checks:

  * speedup floors — burst packed admission >= 2x single, paged decode
    >= 1.5x dense tokens/s, fused RAG prefill >= 2x full recompute,
    affinity >= 1.05x round-robin tokens/s;
  * cluster cache-hit-rate floor — affinity hit rate >= 0.80 (best possible
    is one cold first-touch per context) and strictly above round-robin;
  * zero steady-state recompiles — the steady packed lane and the affinity
    cluster lane compiled nothing during their measured waves (wave-scoped
    ``jit_misses`` from the bench file), cross-checked against the metrics
    registry: the packed jit cache's consecutive-hit streak
    (``jit_calls_since_miss``) covers at least the measured wave's batches;
  * cost conservation — every telemetry lane's ledger totals match its
    ``ServingSummary`` at 1e-9 (the residuals serve_bench recorded), ledger
    category totals are non-negative, compute dollars are attributed (the
    lanes actually served requests), and the headline ``kv_cache_hit_rate``
    gauge exists in every lane's registry dump;
  * fault tolerance — the chaos lane's seeded schedule actually fired
    (injected fetch failures, a replica crash), degradation to recompute
    happened (rate > 0) with retries observed, every request finished
    token-identical to the fault-free run, the faulted pass cost no more
    than the configured inflation ceiling, and it too compiled nothing
    during the measured wave;
  * marketplace economics — the cost-aware market mode spent strictly
    fewer fleet dollars than BOTH baselines (never-buy and always-buy),
    purchases actually happened, the adversarial seller's corrupt delivery
    was caught (never served) and the seller blacklisted, tokens stayed
    bit-identical to pure recompute across all three modes, the measured
    wave compiled nothing, and the settlement ledger's double-entry
    conservation residual is at most 1e-9;
  * flat decode p99 — the unified continuous-batching lane's victim decode
    p99 token gap stays within 1.2x its steady-state gap while a burst of
    long-context admissions lands (the legacy lane must spike above that),
    chunks actually landed, and the measured wave compiled nothing (the
    mixed launch has ONE static shape);
  * baseline diff — when the repo's committed ``BENCH_serving.json``
    (``git show HEAD:...``) was produced by the same workload config, every
    speedup headline must stay within 25% of it, so silent perf drift
    trips CI even when the absolute floors still pass.

Exits non-zero on the first violated check with a self-explanatory message.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ATOL = 1e-9


class GateError(AssertionError):
    pass


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise GateError(msg)


def _metric_value(metrics: dict, name: str, **labels) -> float:
    """One series' value out of a registry snapshot dump."""
    fam = metrics.get(name)
    _require(fam is not None, f"metric {name!r} missing from registry dump")
    for s in fam["series"]:
        if all(s["labels"].get(k) == str(v) for k, v in labels.items()):
            return float(s["value"])
    raise GateError(f"metric {name!r} has no series with labels {labels}")


def check_speedups(bench: dict) -> None:
    sp = bench["speedup"]
    _require(sp["burst"] >= 2.0,
             f"burst admission speedup {sp['burst']:.2f}x < 2x")
    _require(sp["decode_tokens_per_s"] >= 1.5,
             f"paged decode speedup {sp['decode_tokens_per_s']:.2f}x < 1.5x")
    _require(sp["rag_prefill"] >= 2.0,
             f"fused RAG prefill speedup {sp['rag_prefill']:.2f}x < 2x")
    _require(sp["cluster_tokens_per_s"] >= 1.05,
             f"affinity tokens/s gain {sp['cluster_tokens_per_s']:.3f}x "
             f"< 1.05x")


def check_cluster_hit_rate(bench: dict) -> None:
    c = bench["workloads"]["cluster"]
    aff, rr = c["affinity"], c["round_robin"]
    # best possible is (n - n_ctx)/n — one cold first-touch per context; the
    # floor leaves exactly that headroom at the CI-capped 16-request size
    _require(aff["hit_rate"] >= 0.80,
             f"affinity hit rate {aff['hit_rate']:.3f} < 0.80")
    _require(aff["hit_rate"] > rr["hit_rate"],
             f"affinity hit rate {aff['hit_rate']:.3f} does not beat "
             f"round-robin {rr['hit_rate']:.3f}")


def check_steady_state(bench: dict, lanes: dict) -> None:
    steady = bench["workloads"]["steady"]["packed"]
    _require(steady["jit_misses"] == 0,
             f"steady-state serving kept recompiling: {steady}")
    aff = bench["workloads"]["cluster"]["affinity"]
    _require(aff["jit_misses"] == 0,
             f"cluster steady state kept recompiling: {aff}")
    # registry cross-check: the packed jit cache's consecutive-hit streak at
    # collection time must cover the whole measured wave — a single compile
    # inside the wave would have reset it below the wave's batch count
    metrics = lanes["steady_packed"]["metrics"]
    streak = _metric_value(metrics, "jit_calls_since_miss",
                           replica=0, path="packed")
    _require(streak >= steady["batches"],
             f"registry says a jit compile happened inside the steady "
             f"measured wave (streak {streak:.0f} < {steady['batches']} "
             f"batches)")


def check_conservation(lanes: dict) -> None:
    for name, lane in lanes.items():
        _require(lane is not None, f"telemetry lane {name!r} missing")
        res = lane["conservation_residuals"]
        # engine lanes: {category: residual}; cluster lanes: {replica: {...}}
        per_scope = res if all(isinstance(v, dict) for v in res.values()) \
            else {"engine": res}
        for scope, rs in per_scope.items():
            for cat, r in rs.items():
                _require(r <= ATOL,
                         f"{name}/{scope}: {cat} conservation residual "
                         f"{r!r} > {ATOL}")
        totals = lane["ledger"]["totals"]
        for cat, dollars in totals.items():
            _require(dollars >= 0.0, f"{name}: negative {cat} total {dollars}")
        _require(totals["compute"] > 0.0,
                 f"{name}: no compute dollars attributed — lane served "
                 f"nothing?")
        _require("kv_cache_hit_rate" in lane["metrics"],
                 f"{name}: headline kv_cache_hit_rate gauge missing")


def check_chaos(bench: dict, lanes: dict) -> None:
    h = bench["workloads"]["chaos"]
    _require(h["token_identity"] is True,
             "chaos lane generated different tokens than the fault-free run")
    _require(h["injector"]["injected_failures"] > 0,
             f"chaos schedule injected no failures: {h['injector']}")
    _require(h["fetch_retries"] > 0,
             f"no fetch was ever retried under faults: {h}")
    _require(h["degraded_requests"] > 0 and h["degradation_rate"] > 0.0,
             f"no request degraded to recompute under faults: {h}")
    _require(h["replica_crashes"] >= 1,
             f"the scheduled mid-run replica crash never fired: {h}")
    _require(h["cost_inflation"] <= h["cost_ceiling"],
             f"graceful degradation cost x{h['cost_inflation']:.2f} exceeds "
             f"the x{h['cost_ceiling']:.1f} ceiling")
    _require(h["jit_misses"] == 0,
             f"fault handling caused steady-state recompiles: {h}")
    # wasted transfer must be accounted, not vanish: the failed attempts'
    # bytes show up as zero-dollar "fetch_failed" marker entries, so the
    # per-replica fault counters carry nonzero wasted bytes
    wasted = sum(fs["fetch_wasted_bytes"]
                 for fs in lanes["chaos"]["fault_stats"])
    _require(wasted > 0.0,
             "injected failures burned no accounted transfer bytes")


def check_market(bench: dict, lanes: dict) -> None:
    w = bench["workloads"].get("market")
    _require(w is not None, "market lane missing from bench artifact")
    m, nb, ab = w["market"], w["never_buy"], w["always_buy"]
    _require(w["token_identity"] is True,
             "marketplace modes generated different tokens than recompute")
    _require(m["purchases"] > 0,
             f"cost-aware market never bought anything: {m}")
    _require(ab["purchases"] > m["purchases"],
             f"always-buy bought no more than cost-aware "
             f"({ab['purchases']} vs {m['purchases']}) — the comparison is "
             f"vacuous")
    _require(nb["purchases"] == 0,
             f"never-buy baseline somehow traded: {nb}")
    _require(m["total_cost"] < nb["total_cost"],
             f"market fleet cost ${m['total_cost']:.6f} does not beat "
             f"never-buy ${nb['total_cost']:.6f}")
    _require(m["total_cost"] < ab["total_cost"],
             f"market fleet cost ${m['total_cost']:.6f} does not beat "
             f"always-buy ${ab['total_cost']:.6f}")
    _require(m["corrupt_blocked"] >= 1,
             f"the armed adversary's corrupt delivery was never caught: {m}")
    _require(m["corrupt_served"] == 0,
             f"a corrupt payload was SERVED: {m}")
    _require(m["adversary_blacklisted"] is True,
             f"the corrupt seller was not blacklisted: {m}")
    _require(m["jit_misses"] == 0,
             f"market measured wave kept recompiling: {m}")
    _require(m["settlement_residual"] <= ATOL,
             f"settlement double-entry residual {m['settlement_residual']!r} "
             f"> {ATOL}")
    stats = lanes["market"].get("market")
    _require(stats is not None, "market lane carries no exchange stats")
    _require(stats["corrupt_served"] == 0,
             f"exchange stats report a served corrupt payload: {stats}")


P99_GAP_CEILING = 1.2  # unified lane: worst decode gap vs steady, at most
BASELINE_RTOL = 0.25   # committed-baseline drift allowance on speedups


def check_unified(bench: dict) -> None:
    w = bench["workloads"].get("unified")
    _require(w is not None, "unified lane missing from bench artifact")
    uni, leg = w["unified"], w["legacy"]
    _require(uni["p99_gap_ratio"] <= P99_GAP_CEILING,
             f"unified decode p99 gap x{uni['p99_gap_ratio']:.3f} of steady "
             f"exceeds the x{P99_GAP_CEILING} flat-p99 ceiling")
    _require(leg["p99_gap_ratio"] > P99_GAP_CEILING,
             f"legacy lane no longer spikes (x{leg['p99_gap_ratio']:.3f}) — "
             f"the unified comparison is vacuous; rescale the workload")
    _require(uni["jit_misses"] == 0,
             f"unified measured wave recompiled: {uni}")
    _require(uni.get("unified_steps", 0) > 0
             and uni.get("unified_chunk_tokens", 0) > 0,
             f"unified lane landed no chunks: {uni}")
    _require(uni["admission_throughput_rps"] > 0.0,
             f"unified lane admitted nothing: {uni}")


def _committed_baseline(path: str):
    """The committed copy of the bench artifact (``git show HEAD:path``), or
    None when there is no repo / no committed copy (first run, exported
    tarball) — the diff is then skipped, not failed."""
    import subprocess

    root = pathlib.Path(__file__).resolve().parent.parent
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"], cwd=root,
            capture_output=True, text=True, timeout=30,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def check_baseline(bench: dict, baseline) -> str:
    """Diff the fresh bench numbers against the committed baseline.  Only
    meaningful when both runs used the same workload config (CI always
    does); a config mismatch or a missing baseline skips with a notice."""
    if baseline is None:
        return "baseline: none committed, diff skipped"
    if baseline.get("config") != bench.get("config"):
        return "baseline: workload config differs, diff skipped"
    missing = set(baseline["speedup"]) - set(bench["speedup"])
    _require(not missing,
             f"speedup headlines vanished vs committed baseline: {missing}")
    for key, old in baseline["speedup"].items():
        new = bench["speedup"][key]
        _require(abs(new - old) <= BASELINE_RTOL * abs(old),
                 f"speedup[{key}] drifted {old:.3f} -> {new:.3f} "
                 f"(> {BASELINE_RTOL:.0%} vs committed baseline)")
    return f"baseline: {len(baseline['speedup'])} headlines within " \
           f"{BASELINE_RTOL:.0%}"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_serving.json")
    ap.add_argument("--metrics", default="BENCH_serving_metrics.json")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the committed-baseline drift diff")
    args = ap.parse_args()

    bench = json.loads(pathlib.Path(args.bench).read_text())
    snap = json.loads(pathlib.Path(args.metrics).read_text())
    _require(snap.get("schema") == 1,
             f"unknown metrics snapshot schema {snap.get('schema')!r}")
    lanes = snap["lanes"]

    try:
        check_speedups(bench)
        check_cluster_hit_rate(bench)
        check_steady_state(bench, lanes)
        check_conservation(lanes)
        check_chaos(bench, lanes)
        check_unified(bench)
        check_market(bench, lanes)
        base_note = (
            "baseline: diff disabled" if args.no_baseline
            else check_baseline(bench, _committed_baseline(args.bench))
        )
    except GateError as e:
        print(f"check_snapshot: FAIL — {e}", file=sys.stderr)
        return 1

    sp = bench["speedup"]
    aff = bench["workloads"]["cluster"]["affinity"]
    h = bench["workloads"]["chaos"]
    uni = bench["workloads"]["unified"]["unified"]
    mkt = bench["workloads"]["market"]["market"]
    print(
        f"check_snapshot: OK — burst {sp['burst']:.2f}x, "
        f"decode {sp['decode_tokens_per_s']:.2f}x, "
        f"rag {sp['rag_prefill']:.2f}x, "
        f"affinity hit rate {aff['hit_rate']:.3f}, "
        f"unified p99 gap x{uni['p99_gap_ratio']:.3f} <= x{P99_GAP_CEILING}, "
        f"0 steady recompiles, conservation <= {ATOL} on "
        f"{len(lanes)} telemetry lanes, chaos token-identical "
        f"({h['degraded_requests']} degraded, "
        f"cost x{h['cost_inflation']:.2f} <= x{h['cost_ceiling']:.1f}), "
        f"market beats never-buy {sp['market_vs_never_cost']:.2f}x and "
        f"always-buy {sp['market_vs_always_cost']:.2f}x "
        f"({mkt['purchases']} purchases, adversary blocked); "
        f"{base_note}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
