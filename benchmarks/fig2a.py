"""Paper Figure 2(a): cost and end-to-end delay vs INPUT length (1K-10K),
Llama-7B, TriviaQA-like workload (200 contexts x 5 reuses), both pipelines.

Paper's reported bands: delay saving 1.1-2.9x, cost saving 1.3-3.6x, growing
with input length.  Produced via the discrete-event simulator with the
paper-calibrated V100/HF-MP performance model.
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core import simulator
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER

LENGTHS = (1_000, 2_000, 4_000, 6_000, 8_000, 10_000)


def sweep(n_contexts: int = 200, reuses: int = 5, seed: int = 0) -> List[dict]:
    cfg = get_config("llama-7b")
    pm = PerfModel(V100_X4_HF)
    rows = []
    for L in LENGTHS:
        trace = simulator.make_trace(
            n_contexts=n_contexts, reuses_per_context=reuses, L_context=L,
            L_prompt=32, L_output=32, arrival_rate_per_s=0.02, seed=seed,
        )
        m = simulator.compare_pipelines(cfg, trace, pm, AWS_PAPER)
        rows.append({"L_input": L, **m})
    return rows


def run() -> List[str]:
    rows = sweep(n_contexts=40)  # reduced contexts: same stats, faster CI
    out = []
    for r in rows:
        out.append(
            f"fig2a/L={r['L_input']},{r['kv_e2e_s']*1e6:.0f},"
            f"cost_saving={r['cost_saving_x']:.2f}x;delay_saving={r['delay_saving_x']:.2f}x"
        )
    return out


if __name__ == "__main__":
    for r in sweep():
        print(
            f"L={r['L_input']:6d}  text: ${r['text_cost']:.3f} {r['text_e2e_s']:6.2f}s"
            f" | kv: ${r['kv_cost']:.3f} {r['kv_e2e_s']:6.2f}s"
            f" | saving: {r['cost_saving_x']:.2f}x $, {r['delay_saving_x']:.2f}x delay"
        )
