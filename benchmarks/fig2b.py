"""Paper Figure 2(b): cost and delay vs OUTPUT length (1-100 tokens) at 10K
input.  Paper bands: delay saving 1.6-3.5x, cost saving 1.7-4.5x, shrinking
as output grows (prefill saving amortised by decode)."""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core import simulator
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER

OUT_LENGTHS = (1, 5, 10, 25, 50, 100)


def sweep(n_contexts: int = 200, reuses: int = 5, seed: int = 0) -> List[dict]:
    cfg = get_config("llama-7b")
    pm = PerfModel(V100_X4_HF)
    rows = []
    for L_out in OUT_LENGTHS:
        trace = simulator.make_trace(
            n_contexts=n_contexts, reuses_per_context=reuses, L_context=10_000,
            L_prompt=32, L_output=L_out, arrival_rate_per_s=0.02, seed=seed,
        )
        m = simulator.compare_pipelines(cfg, trace, pm, AWS_PAPER)
        rows.append({"L_output": L_out, **m})
    return rows


def run() -> List[str]:
    rows = sweep(n_contexts=40)
    return [
        f"fig2b/Lout={r['L_output']},{r['kv_e2e_s']*1e6:.0f},"
        f"cost_saving={r['cost_saving_x']:.2f}x;delay_saving={r['delay_saving_x']:.2f}x"
        for r in rows
    ]


if __name__ == "__main__":
    for r in sweep():
        print(
            f"L_out={r['L_output']:4d}  text: ${r['text_cost']:.3f} {r['text_e2e_s']:6.2f}s"
            f" | kv: ${r['kv_cost']:.3f} {r['kv_e2e_s']:6.2f}s"
            f" | saving: {r['cost_saving_x']:.2f}x $, {r['delay_saving_x']:.2f}x delay"
        )
