"""Microbenchmarks: wall-time of the real jitted hot paths on this host
(reduced configs — CPU numbers are for regression tracking, not TPU claims)
+ kernel interpret-mode correctness timing."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> List[str]:
    from repro.configs import get_config, reduced_config
    from repro.models import registry

    out = []
    rng = np.random.default_rng(0)
    for arch in ("llama-7b", "mixtral-8x22b", "mamba2-1.3b"):
        cfg = reduced_config(get_config(arch))
        api = registry.get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        B, S = 2, 64
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

        fwd = jax.jit(lambda p, t: api.forward(p, cfg, t)[0])
        out.append(f"micro/forward/{arch},{_time(fwd, params, toks):.1f},B{B}xS{S}")

        state = api.init_state(cfg, B, 128)
        pre = jax.jit(lambda p, t, s: api.prefill(p, cfg, t, s))
        logits, state = pre(params, toks, state)
        out.append(f"micro/prefill/{arch},{_time(pre, params, toks, state):.1f},B{B}xS{S}")

        one = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        dec = jax.jit(lambda p, t, s: api.decode(p, cfg, t, s))
        logits, state2 = dec(params, one, state)
        out.append(f"micro/decode/{arch},{_time(dec, params, one, state):.1f},B{B}")

    # storage-path ops
    from repro.kernels import ops

    x = jnp.asarray(rng.standard_normal((64, 256, 16)), jnp.float32)
    q, s = ops.kv_quant(x)
    out.append(f"micro/kv_quant,{_time(jax.jit(ops.kv_quant), x):.1f},{x.size}elts")
    deq = jax.jit(lambda q, s: ops.kv_dequant(q, s, dtype=jnp.float32))
    out.append(f"micro/kv_dequant,{_time(deq, q, s):.1f},{x.size}elts")
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
