"""Render EXPERIMENTS.md tables from the dry-run/calibration artifacts.

Replaces ``<!-- TABLE:name -->`` markers in EXPERIMENTS.md (in place) with
generated markdown.  Idempotent: tables live between marker pairs.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks import roofline as rl

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"


def _fmt(x, digits=3):
    return f"{x:.{digits}e}" if isinstance(x, float) else str(x)


def load_cells(tag: str = "", mesh: str = "pod16x16"):
    devices = 512 if mesh == "pod2x16x16" else 256
    suffix = f"__{tag}" if tag else ""
    out = {}
    for f in sorted(ART.glob(f"*__{mesh}{suffix}.json")):
        if "__calib" in f.name:
            continue
        rec = json.loads(f.read_text())
        if tag == "" and re.search(r"__(v\d+)\.json$", f.name):
            continue
        # calibration lookup must match the tag
        rec["mesh_tagged"] = f"{mesh}{suffix}"
        cell = analyze(rec, devices, tag)
        if cell:
            out[(rec["arch"], rec["shape"])] = cell
    return out


def analyze(rec, devices, tag):
    if not rec.get("ok"):
        return None
    calib_name = (
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        + (f"__{tag}" if tag else "")
        + "__calib.json"
    )
    calib = ART / calib_name
    corr = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
            "coll": rec["collectives"]["total"], "calibrated": False}
    if calib.exists():
        c = json.loads(calib.read_text())
        d1, d2 = c.get("d1", {}), c.get("d2", {})
        if d1 and d2 and "error" not in d1 and "error" not in d2:
            D = c["periods_full"]
            for key, k1 in (("flops", "flops"), ("bytes", "bytes_accessed"),
                            ("coll", "collective_total")):
                corr[key] = d1[k1] + (D - 1) * max(d2[k1] - d1[k1], 0.0)
            corr["calibrated"] = True
    terms = {
        "compute": corr["flops"] / rl.PEAK_FLOPS,
        "memory": corr["bytes"] / rl.HBM_BW,
        "collective": corr["coll"] / rl.LINK_BW,
    }
    mf = rl.model_flops(rec["arch"], rec["shape"])
    ideal = mf / devices / rl.PEAK_FLOPS
    dom = max(terms.values())
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "bottleneck": max(terms, key=terms.get),
        "useful_ratio": mf / max(corr["flops"] * devices, 1e-30),
        "frac": ideal / max(dom, 1e-30),
        "calibrated": corr["calibrated"],
        "compile_s": rec.get("compile_s"),
        "kind": rec["kind"],
    }


def table_roofline(tag: str) -> str:
    cells = load_cells(tag)
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful FLOP ratio | roofline frac | calib |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), c in sorted(cells.items()):
        rows.append(
            f"| {arch} | {shape} | {c['compute_s']:.3e} | {c['memory_s']:.3e} | "
            f"{c['collective_s']:.3e} | {c['bottleneck']} | {c['useful_ratio']:.2f} | "
            f"{c['frac']:.3f} | {'y' if c['calibrated'] else 'n'} |"
        )
    return "\n".join(rows)


def table_compare() -> str:
    base = load_cells("")
    opt = load_cells("v3")
    rows = [
        "| arch | shape | dominant term | baseline s | optimized s | x better | "
        "frac before | frac after |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(set(base) & set(opt)):
        b, o = base[key], opt[key]
        bd = max(b["compute_s"], b["memory_s"], b["collective_s"])
        od = max(o["compute_s"], o["memory_s"], o["collective_s"])
        rows.append(
            f"| {key[0]} | {key[1]} | {b['bottleneck']}->{o['bottleneck']} | "
            f"{bd:.3e} | {od:.3e} | {bd/max(od,1e-30):.2f} | "
            f"{b['frac']:.3f} | {o['frac']:.3f} |"
        )
    return "\n".join(rows)


def table_dryrun_summary() -> str:
    rows = [
        "| mesh | compiled OK | failed | documented skips |",
        "|---|---|---|---|",
    ]
    for mesh in ("pod16x16", "pod2x16x16"):
        ok = fail = skip = 0
        for f in sorted(ART.glob(f"*__{mesh}.json")):
            if "__calib" in f.name or re.search(r"__v\d+\.json$", f.name):
                continue
            r = json.loads(f.read_text())
            if not r.get("runnable", True):
                skip += 1
            elif r.get("ok"):
                ok += 1
            else:
                fail += 1
        rows.append(f"| {mesh} | {ok} | {fail} | {skip} |")
    return "\n".join(rows)


def main() -> None:
    md = ROOT / "EXPERIMENTS.md"
    text = md.read_text()
    tables = {
        "dryrun_summary": table_dryrun_summary(),
        "roofline_baseline": table_roofline(""),
        "roofline_optimized": table_roofline("v3"),
        "compare": table_compare(),
    }
    for name, content in tables.items():
        begin, end = f"<!-- TABLE:{name} -->", f"<!-- /TABLE:{name} -->"
        pat = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
        repl = f"{begin}\n{content}\n{end}"
        if pat.search(text):
            text = pat.sub(repl, text)
        else:
            print(f"marker {name} missing in EXPERIMENTS.md")
    md.write_text(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
