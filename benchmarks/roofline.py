"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled artifact (all quantities are PER DEVICE post-SPMD — verified against
a known 1024^3 matmul probe):

  compute_term    = HLO_FLOPs_dev / (peak_FLOP/s)
  memory_term     = HLO_bytes_dev / HBM_bw
  collective_term = collective_bytes_dev / link_bw

Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip.

Also reports MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs_total — catching
remat/redundancy waste — plus the roofline fraction
  frac = ideal_compute_term / dominant_term
(1.0 = the program is pure useful compute at peak).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """Ideal model FLOPs for the whole step (all devices)."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.models.registry import count_active_params

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = count_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * (
            cfg.decoder_seq_len if cfg.family == "encdec" else shape.seq_len
        )
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * (
            shape.seq_len if cfg.family != "encdec" else shape.seq_len + cfg.decoder_seq_len
        )
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_calibration(arch: str, shape: str, mesh: str) -> Optional[dict]:
    f = ARTIFACT_DIR / f"{arch}__{shape}__{mesh}__calib.json"
    if not f.exists():
        return None
    c = json.loads(f.read_text())
    d1, d2 = c.get("d1", {}), c.get("d2", {})
    if "error" in d1 or "error" in d2 or not d1 or not d2:
        return None
    return c


def corrected(rec: dict) -> dict:
    """Depth-corrected per-device numbers.

    XLA cost_analysis counts a while-loop (scan-over-layers) body once; the
    calibration compiles UNROLLED 1- and 2-period variants so
      f(D) = f(1) + (D-1) * (f(2) - f(1))
    is exact for every linear-in-depth quantity (flops, bytes, collective
    bytes).  Falls back to the raw numbers when no calibration exists."""
    c = load_calibration(rec["arch"], rec["shape"], rec["mesh"])
    out = {
        "flops": rec["flops"],
        "bytes": rec["bytes_accessed"],
        "coll": rec["collectives"]["total"],
        "calibrated": False,
    }
    if c is None:
        return out
    D = c["periods_full"]
    for key, (k1, raw) in {
        "flops": ("flops", "flops"),
        "bytes": ("bytes_accessed", "bytes"),
        "coll": ("collective_total", "coll"),
    }.items():
        f1, f2 = c["d1"][k1], c["d2"][k1]
        out[key] = f1 + (D - 1) * max(f2 - f1, 0.0)
    out["calibrated"] = True
    return out


def analyze_cell(rec: dict, devices: int) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    corr = corrected(rec)
    flops_dev = corr["flops"]
    bytes_dev = corr["bytes"]
    coll_dev = corr["coll"]
    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    coll_t = coll_dev / LINK_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    ideal_t = mf / devices / PEAK_FLOPS
    dominant = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_total": flops_dev * devices,
        "useful_ratio": mf / max(flops_dev * devices, 1e-30),
        "roofline_fraction": ideal_t / max(dominant, 1e-30),
        "calibrated": corr["calibrated"],
        "collective_mix": {
            k: v for k, v in rec["collectives"].items() if k != "total" and v
        },
    }


def load_all(mesh: str = "pod16x16") -> List[dict]:
    devices = 512 if mesh == "pod2x16x16" else 256
    out = []
    for f in sorted(ARTIFACT_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        cell = analyze_cell(rec, devices)
        if cell:
            out.append(cell)
    return out


def markdown_table(cells: List[dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful FLOP ratio | roofline frac |\n|---|---|---|---|---|---|---|---|"
    )
    rows = [hdr]
    for c in cells:
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.3e} | "
            f"{c['memory_s']:.3e} | {c['collective_s']:.3e} | {c['bottleneck']} | "
            f"{c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def run() -> List[str]:
    """CSV lines for benchmarks.run (derived = roofline fraction); reports
    the paper-faithful baseline and, where present, the beyond-paper v3
    variant (see EXPERIMENTS.md §Perf)."""
    lines = []
    for c in load_all("pod16x16"):
        us = max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e6
        lines.append(
            f"roofline/{c['arch']}/{c['shape']},{us:.2f},"
            f"frac={c['roofline_fraction']:.3f};bottleneck={c['bottleneck']}"
        )
    try:
        from benchmarks import report

        for (arch, shape), c in sorted(report.load_cells("v3").items()):
            us = max(c["compute_s"], c["memory_s"], c["collective_s"]) * 1e6
            lines.append(
                f"roofline-v3/{arch}/{shape},{us:.2f},"
                f"frac={c['frac']:.3f};bottleneck={c['bottleneck']}"
            )
    except Exception:  # artifacts absent: baseline-only
        pass
    return lines


if __name__ == "__main__":
    cells = load_all("pod16x16")
    print(markdown_table(cells))
    worst = sorted(cells, key=lambda c: c["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for c in worst:
        print(f"  {c['arch']} {c['shape']}: {c['roofline_fraction']:.3f} ({c['bottleneck']})")
    coll = sorted(cells, key=lambda c: -c["collective_s"])[:5]
    print("most collective-bound:")
    for c in coll:
        print(f"  {c['arch']} {c['shape']}: coll={c['collective_s']:.3e}s")
