"""Benchmark runner — one module per paper table/figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV (one line per measurement):
  * fig2a     — paper Fig 2(a): savings vs input length   (simulator)
  * fig2b     — paper Fig 2(b): savings vs output length  (simulator)
  * breakeven — paper §2 insights: N*, storage fraction   (analytic model)
  * roofline  — per (arch x shape) terms from the dry-run artifacts
  * micro     — wall-time of the real jitted hot paths (reduced configs)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import ablation, breakeven, fig2a, fig2b, microbench, roofline

    modules = [
        ("fig2a", fig2a),
        ("fig2b", fig2b),
        ("breakeven", breakeven),
        ("roofline", roofline),
        ("micro", microbench),
        ("ablation", ablation),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for line in mod.run():
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
