"""Serving throughput benchmark: burst + steady-state workloads through the
packed batch-admission engine (vs single-request admission), a decode-bound
workload through paged block-pool decode (vs dense decode), and a
shuffled-chunk RAG workload through fused non-prefix reuse (vs full
recompute prefill).

    PYTHONPATH=src python benchmarks/serve_bench.py [--requests N]
        [--steady-requests N] [--slots K] [--decode-requests N]
        [--decode-slots K] [--rag-requests N] [--out BENCH_serving.json]

Numerics run the reduced config on CPU; times/costs are modeled at
``--cost-arch`` scale (paper-style V100x4 + AWS pricing), so requests/s and
TTFT are economics-model numbers, not CPU wall clock.  Emits
``BENCH_serving.json``:

  * per-workload, per-mode (packed vs single): requests/s over the modeled
    horizon, admission throughput (requests / modeled load+prefill busy
    time), mean/p95 TTFT, packed-prefill occupancy, jit bucket hit rate,
    trie-walk savings;
  * the ``decode`` workload (long generations, short prompts, ragged warm
    contexts), per-mode (paged vs dense): decode tokens/s over modeled
    decode busy time, pool block usage, shared-prefix block hits;
  * the ``rag`` workload (warm store of shared document chunks, each
    request's chunk ORDER permuted so the prefix trie misses), per-mode
    (fused vs full): modeled admission (load+prefill) time per request,
    fused-path counters (reused/recomputed tokens, sources, jit buckets);
  * the ``cluster`` workload (skewed context reuse over N engine replicas
    with a shared cold tier), per-mode (affinity vs round_robin router):
    aggregate hit rate, tokens per modeled busy second, gossip/jit
    counters, shared-tier dedup stats;
  * the ``market`` workload (three tenant engines on one marketplace,
    partially-overlapping working sets, the last tenant turned dishonest
    after jit warm), per-mode (cost-aware market vs never-buy vs
    always-buy): fleet dollars (engine costs + exchange fees), purchase /
    blocked-delivery / blacklist counters, settlement residual;
  * ``speedup``: packed-over-single admission throughput, paged-over-dense
    decode tokens/s (token-identical), full-over-fused prefill time on the
    rag workload (the CacheBlend-style selective-recompute win), and
    affinity-over-round-robin hit rate and tokens/s on the cluster
    workload.

The packed, fused and affinity lanes additionally run with a ``Telemetry``
session attached (the baseline lanes run without — so the paired
comparisons double as evidence that telemetry is free) and their registry
dumps, ledger aggregations and cost-conservation residuals are written to
``--metrics-out`` (``BENCH_serving_metrics.json``).  The acceptance
criteria — speedup floors, zero steady-state recompiles, the cluster
hit-rate floor, and ledger conservation at 1e-9 — are asserted by
``benchmarks/check_snapshot.py`` over the two artifacts (CI runs it right
after this script).
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

import numpy as np


def _requests(cfg, *, n, n_ctx, ctx_len, prompt_len, new, arrivals, seed=0,
              ctx_seed=None):
    """``ctx_seed`` pins the context pool independently of the prompt stream
    (a warmup wave and its measured wave must share contexts)."""
    rng = np.random.default_rng(seed)
    ctx_rng = np.random.default_rng(seed if ctx_seed is None else ctx_seed)
    ctxs = [
        list(map(int, ctx_rng.integers(0, cfg.vocab, ctx_len))) for _ in range(n_ctx)
    ]
    return [
        dict(
            req_id=i,
            context_tokens=ctxs[i % n_ctx],
            prompt_tokens=list(map(int, rng.integers(0, cfg.vocab, prompt_len))),
            max_new_tokens=new,
            arrival_s=float(arrivals[i]),
            expected_reuses=max(n // n_ctx, 1),
        )
        for i in range(n)
    ]


def _telemetry_lane(tel, residuals):
    """One lane's slice of the metrics snapshot artifact: the full registry
    dump, the ledger aggregations, and the conservation residuals the
    ``check_snapshot.py`` CI gate asserts on."""
    return {
        "conservation_residuals": residuals,
        "ledger": tel.ledger.as_dict(),
        "metrics": tel.registry.snapshot(),
    }


def _serve(cfg, params, reqs, *, slots, cost_arch, admit_batch, warmup=None,
           telemetry=False):
    """Serve ``reqs`` (after an optional ``warmup`` wave on the same engine —
    the steady-state measurement: compiles during warmup are free, compiles
    during the measured wave are steady-state recompiles).  ``telemetry=True``
    attaches a ``Telemetry`` session and returns its lane snapshot as the
    second element (None otherwise) — the packed lanes run WITH telemetry and
    the single lanes WITHOUT, so the packed-vs-single comparison doubles as
    evidence that telemetry costs nothing observable."""
    import jax  # noqa: F401  (engine imports need an initialized backend)

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine

    tel = None
    if telemetry:
        from repro.obs import Telemetry

        tel = Telemetry()
    ec = EngineConfig(
        max_slots=slots, max_len=256, chunk_tokens=16,
        cost_arch=cost_arch, admit_batch=admit_batch,
    )
    eng = ServingEngine(
        cfg, params, engine_cfg=ec, planner=AlwaysReusePlanner(),
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF), telemetry=tel,
    )
    if warmup is not None:
        for r in warmup:
            eng.submit(Request(**r))
        eng.run()
    warm = eng.packed_stats()  # snapshot: every metric below is wave-scoped
    t0 = eng.clock.now
    n_warm = len(eng.records)
    for r in reqs:
        eng.submit(Request(**{**r, "arrival_s": r["arrival_s"] + t0}))
    summary = eng.run()
    records = eng.records[n_warm:]  # measured wave only
    ttft = np.array([r.ttft_s for r in records])
    stats = eng.packed_stats()
    horizon = max(summary.horizon_s - t0, 1e-12)
    busy = stats["admission_busy_s"] - warm["admission_busy_s"]
    q_tokens = stats["packed_q_tokens"] - warm["packed_q_tokens"]
    q_len = stats["packed_q_len"] - warm["packed_q_len"]
    jit_calls = lambda s: s["jit"]["hits"] + s["jit"]["misses"]  # noqa: E731
    hits = stats["jit"]["hits"] - warm["jit"]["hits"]
    out = {
        "n_requests": len(records),
        "requests_per_s": len(records) / horizon,
        "admission_throughput_rps": len(records) / max(busy, 1e-12),
        "admission_busy_s": busy,
        "mean_ttft_s": float(ttft.mean()),
        "p95_ttft_s": float(np.percentile(ttft, 95)),
        "reuse_hits": sum(1 for r in records if r.action in ("load", "partial")),
        "packed_occupancy": q_tokens / max(q_len, 1),
        "jit_hit_rate": hits / max(jit_calls(stats) - jit_calls(warm), 1),
        "jit_misses": stats["jit"]["misses"] - warm["jit"]["misses"],
        "batches": stats["batches"] - warm["batches"],
        "lookup_walks": stats["lookup_walks"] - warm["lookup_walks"],
        "lookup_reuses": stats["lookup_reuses"] - warm["lookup_reuses"],
        "total_cost": summary.total_cost,
    }
    lane = None
    if tel is not None:
        tel.collect_engine(eng)
        lane = _telemetry_lane(tel, tel.check(summary))
    return out, lane


# ctx length pool for the decode-bound workload: ragged on purpose — dense
# decode bills every slot the LONGEST slot's KV stream, paged decode bills
# each slot its own live blocks, and the spread is where the win lives.
DECODE_CTX_LENS = [128, 256, 384, 512, 768, 1024, 1536, 2048]


def _serve_decode(cfg, params, *, n, slots, cost_arch, paged, seed):
    """Decode-bound workload: long generations off short prompts against a
    WARM ragged context store.  A spaced warm wave ingests the contexts
    (admission-bound, unmeasured); the measured burst then loads its context
    KV and spends its life decoding — tokens/s over modeled decode busy time
    is the paged-vs-dense comparison (numerics are identical by contract)."""
    import jax  # noqa: F401

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine

    prompt_len, new = 8, 48
    max_len = -(-(max(DECODE_CTX_LENS) + prompt_len + new) // 128) * 128
    warm = _requests(
        cfg, n=len(DECODE_CTX_LENS), n_ctx=len(DECODE_CTX_LENS), ctx_len=1,
        prompt_len=prompt_len, new=1,
        arrivals=[40.0 * i for i in range(len(DECODE_CTX_LENS))], seed=seed,
    )
    ctx_rng = np.random.default_rng(seed + 100)
    ctxs = [
        list(map(int, ctx_rng.integers(0, cfg.vocab, L))) for L in DECODE_CTX_LENS
    ]
    for r, ctx in zip(warm, ctxs):
        r["context_tokens"] = ctx
    reqs = _requests(
        cfg, n=n, n_ctx=len(ctxs), ctx_len=1, prompt_len=prompt_len, new=new,
        arrivals=[0.0] * n, seed=seed + 1,
    )
    for i, r in enumerate(reqs):
        r["context_tokens"] = ctxs[i % len(ctxs)]

    ec = EngineConfig(
        max_slots=slots, max_len=max_len, chunk_tokens=16,
        cost_arch=cost_arch, paged_decode=paged,
    )
    eng = ServingEngine(
        cfg, params, engine_cfg=ec, planner=AlwaysReusePlanner(),
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF),
    )
    for r in warm:
        eng.submit(Request(**r))
    eng.run()
    assert eng.decode_tokens == 0  # warm wave is admission-only
    t0 = eng.clock.now
    n_warm = len(eng.records)
    for r in reqs:
        eng.submit(Request(**{**r, "arrival_s": r["arrival_s"] + t0}))
    eng.run()
    records = eng.records[n_warm:]
    stats = eng.decode_stats()
    out = {
        "n_requests": len(records),
        "decode_tokens": stats["decode_tokens"],
        "decode_busy_s": stats["decode_busy_s"],
        "decode_tokens_per_s": stats["decode_tokens"] / max(
            stats["decode_busy_s"], 1e-12
        ),
        "reuse_hits": sum(1 for r in records if r.action in ("load", "partial")),
        "paged": stats["paged"],
    }
    if paged:
        out["pool_blocks"] = stats["pool_blocks"]  # capacity
        out["pool_blocks_peak"] = stats["pool_blocks_peak"]  # high-water usage
        out["shared_block_hits"] = stats["shared_block_hits"]
    return out, {r.req_id: r.tokens for r in records}


# Unified-lane workload shape: one long-generation victim decoding alone,
# then a burst of LONG-context recompute admissions landing mid-decode.
# Legacy admit-OR-decode stalls the victim for the burst's full packed
# prefill; the unified step lands the burst as co-scheduled chunks, so the
# victim's worst token gap stays within the flat-p99 envelope.  This lane
# models a TENSOR-PARALLEL serving instance (V100_X4, mfu 0.40) instead of
# the paper's naive-MP V100_X4_HF pipeline: with one-of-four GPUs active,
# any prefill flops dwarf a (memory-bound) decode step and no chunk size
# can interleave flatly — co-scheduling presumes serving-grade compute.
UNIFIED_CTX = 352          # burst context length (tokens, recompute-planned)
UNIFIED_VICTIM_NEW = 48    # victim generation length (47 measured gaps)
UNIFIED_BURST_AT = 0.02    # burst arrival: a few decode steps into the wave
UNIFIED_MAX_LEN = 512
UNIFIED_GAP_TARGET = 1.15  # budget solve target: under the 1.2x CI ceiling


def _flat_step_budget(pm, cost_cfg):
    """Largest per-launch token budget whose fully-granted mixed step stays
    within UNIFIED_GAP_TARGET of a one-row decode step, solved against the
    lane's own cost model — the budget is a hardware property, not a magic
    number.  Worst case assumed: one decode row plus one chunk ending at
    the burst's deepest position."""
    base = pm.t_decode_paged(cost_cfg, [64 + 8 + UNIFIED_VICTIM_NEW])
    g = 8
    while g + 8 <= UNIFIED_MAX_LEN and (
        pm.t_step_unified(
            cost_cfg, [64 + 8 + UNIFIED_VICTIM_NEW],
            [(g + 8, UNIFIED_CTX + 8)],
        )
        <= UNIFIED_GAP_TARGET * base
    ):
        g += 8
    return 1 + g  # the victim's decode row rides inside the budget too


def _serve_unified_lane(cfg, params, *, n, cost_arch, seed, unified):
    """Burst + in-flight decode under the unified continuous-batching step
    (vs the legacy admit-OR-decode loop): measure the victim's decode token
    gaps around the burst and the burst's admission throughput.  A same-shape
    warmup wave runs first on the same engine, so any compile inside the
    measured wave is a steady-state recompile (must be zero — the unified
    launch has ONE static shape)."""
    import jax  # noqa: F401

    from repro.configs import get_config
    from repro.core.perf_model import PerfModel, V100_X4
    from repro.core.pricing import AWS_PAPER
    from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine
    from repro.serving import events as evmod

    pm = PerfModel(V100_X4)
    budget = _flat_step_budget(pm, get_config(cost_arch))
    rng = np.random.default_rng(seed)

    def wave(base_id, t0):
        mk_ctx = lambda L: list(map(int, rng.integers(0, cfg.vocab, L)))  # noqa: E731
        mk_prompt = lambda: list(map(int, rng.integers(0, cfg.vocab, 8)))  # noqa: E731
        reqs = [dict(
            req_id=base_id, context_tokens=mk_ctx(64),
            prompt_tokens=mk_prompt(), max_new_tokens=UNIFIED_VICTIM_NEW,
            arrival_s=t0,
        )]
        reqs += [dict(
            req_id=base_id + 1 + i, context_tokens=mk_ctx(UNIFIED_CTX),
            prompt_tokens=mk_prompt(), max_new_tokens=2,
            arrival_s=t0 + UNIFIED_BURST_AT,
        ) for i in range(n)]
        return reqs

    ec = EngineConfig(
        max_slots=n + 1, max_len=UNIFIED_MAX_LEN, chunk_tokens=16,
        cost_arch=cost_arch, paged_decode=True, unified_step=unified,
        step_token_budget=budget,
        reuse_enabled=False,  # pure recompute burst: the prefill IS the load
    )
    eng = ServingEngine(
        cfg, params, engine_cfg=ec, planner=AlwaysReusePlanner(),
        pricing=AWS_PAPER, perf=pm,
    )
    for r in wave(0, 0.0):
        eng.submit(Request(**r))
    eng.run()  # warmup: compiles every launch shape
    t0 = eng.clock.now
    n_warm = len(eng.records)
    warm_busy = eng.admission_busy_s
    warm_jit = (
        dict(eng.unified_stats()["jit"]) if unified
        else dict(eng.packed_stats()["jit"])
    )
    victim_id = 100
    for r in wave(victim_id, t0):
        eng.submit(Request(**r))
    events = list(eng.drain())

    records = eng.records[n_warm:]
    burst_records = [r for r in records if r.req_id != victim_id]
    gaps = np.diff([
        e.t_s for e in events
        if isinstance(e, evmod.TokenEmitted) and e.req_id == victim_id
    ])
    steady = float(np.median(gaps))
    p99 = float(np.percentile(gaps, 99))
    busy = eng.admission_busy_s - warm_busy
    jit = (
        eng.unified_stats()["jit"] if unified else eng.packed_stats()["jit"]
    )
    out = {
        "unified": unified,
        "n_requests": len(records),
        "step_token_budget": budget,
        "decode_gap_steady_s": steady,
        "decode_gap_p99_s": p99,
        "decode_gap_max_s": float(gaps.max()),
        "p99_gap_ratio": p99 / max(steady, 1e-12),
        "admission_busy_s": busy,
        "admission_throughput_rps": len(burst_records) / max(busy, 1e-12),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in burst_records])),
        "jit_misses": jit["misses"] - warm_jit["misses"],
    }
    if unified:
        us = eng.unified_stats()
        out["unified_steps"] = us["steps"]
        out["unified_chunk_tokens"] = us["chunk_tokens"]
    return out, {r.req_id: r.tokens for r in records}


# RAG workload shape: every context is ``RAG_CTX_CHUNKS`` document chunks of
# ``RAG_CHUNK`` tokens drawn from a shared pool, PERMUTED per request — the
# chain-hash trie sees (at best) a 1-chunk prefix, while the chunk-content
# index matches everything.  Fused prefill fetches the matched chunk KV and
# recomputes only the r-fraction + prompt; the full path recomputes it all.
RAG_CHUNK = 32
# long-ish contexts: full recompute prefill is compute-bound (scales with
# ctx len) while the fused launch bottoms out at the parameter-read floor,
# which is where the CacheBlend win lives
RAG_CTX_CHUNKS = 8
RAG_POOL = 16  # two DISJOINT warm contexts cover it (a fused warm admission
# would skip write-back and leave pool chunks unstored)


def _serve_rag(cfg, params, *, n, slots, cost_arch, fused, seed,
               recompute_frac=0.16, telemetry=False):
    """Shuffled-chunk RAG workload: a warm wave stores ``RAG_POOL`` document
    chunks (via two canonical-order contexts covering the pool), then the
    measured burst issues requests whose chunk order is permuted per
    request.  ``fused=True`` serves them via chunk-composite fused prefill
    (BlendPlanner, always-fuse), ``fused=False`` via the classic
    prefix-only engine — the comparison is modeled admission (load+prefill)
    time per request."""
    import jax  # noqa: F401

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.serving import (
        AlwaysReusePlanner,
        BlendPlanner,
        EngineConfig,
        Request,
        ServingEngine,
    )

    rng = np.random.default_rng(seed)
    chunks = [
        list(map(int, rng.integers(0, cfg.vocab, RAG_CHUNK)))
        for _ in range(RAG_POOL)
    ]
    prompt_len, new = 16, 4

    def req(i, order, t):
        return dict(
            req_id=i,
            context_tokens=sum((chunks[j] for j in order), []),
            prompt_tokens=list(map(int, rng.integers(0, cfg.vocab, prompt_len))),
            max_new_tokens=new, arrival_s=t, expected_reuses=max(n // 2, 1),
        )

    warm = [req(0, list(range(RAG_CTX_CHUNKS)), 0.0),
            req(1, list(range(RAG_CTX_CHUNKS, RAG_POOL)), 20.0)]
    orders = [
        list(rng.permutation(RAG_POOL)[:RAG_CTX_CHUNKS]) for _ in range(n)
    ]
    reqs = [req(100 + i, o, 0.0) for i, o in enumerate(orders)]

    max_len = -(-(RAG_CTX_CHUNKS * RAG_CHUNK + prompt_len + new) // 128) * 128
    ec = EngineConfig(
        max_slots=slots, max_len=max_len, chunk_tokens=RAG_CHUNK,
        cost_arch=cost_arch, fusion_enabled=fused,
        store_tier="host_dram",  # warm RAG chunk KV is a hot working set
    )
    planner = (
        BlendPlanner(recompute_frac=recompute_frac, always=True)
        if fused else AlwaysReusePlanner()
    )
    tel = None
    if telemetry:
        from repro.obs import Telemetry

        tel = Telemetry()
    eng = ServingEngine(
        cfg, params, engine_cfg=ec, planner=planner,
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF), telemetry=tel,
    )
    for r in warm:
        eng.submit(Request(**r))
    eng.run()
    t0 = eng.clock.now
    n_warm = len(eng.records)
    busy0 = eng.admission_busy_s
    for r in reqs:
        eng.submit(Request(**{**r, "arrival_s": r["arrival_s"] + t0}))
    summary = eng.run()
    records = eng.records[n_warm:]
    busy = eng.admission_busy_s - busy0
    fs = eng.fused_stats()
    out = {
        "n_requests": len(records),
        "admission_busy_s": busy,
        "admission_s_per_request": busy / max(len(records), 1),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in records])),
        "reuse_hits": sum(
            1 for r in records if r.action in ("load", "partial", "fused")
        ),
        "fused_admissions": fs["admissions"],
        "fused_reused_tokens": fs["reused_tokens"],
        "fused_recompute_tokens": fs["recompute_tokens"],
        "fused_sources": fs["sources"],
        "fused_jit_misses": fs["jit"]["misses"],
    }
    lane = None
    if tel is not None:
        tel.collect_engine(eng)
        lane = _telemetry_lane(tel, tel.check(summary))
    return out, lane


# Cluster workload shape: long contexts + short generations, so admission
# (where routing quality lives — a host_dram hit vs a full recompute)
# dominates the modeled busy time instead of being diluted by decode that
# is identical under any router.  On the paper's V100 + AWS numbers,
# modeled at --cost-arch scale, a host_dram hit strictly beats recompute.
CLUSTER_CTX_LEN = 192
CLUSTER_PROMPT = 16
CLUSTER_NEW = 2


def _serve_cluster(cfg, params, *, n, replicas, cost_arch, affinity, seed,
                   telemetry=False):
    """Skewed context-reuse workload over a ``ServingCluster``: N replicas,
    private host_dram/local_nvme tiers, one shared s3 core.  A jit warm wave
    of THROWAWAY contexts is submitted to EVERY replica directly (each
    context requested twice: once to compile the recompute bucket, once the
    load bucket) — deterministic bucket coverage no router placement can
    skew — and leaves the measured contexts cold, so the measured wave's
    hit rate is pure routing quality: affinity concentrates each context's
    first-touch on one replica, round-robin pays it on every replica."""
    import jax  # noqa: F401

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.kvcache.hierarchy import TierSpec
    from repro.serving import (
        AlwaysReusePlanner,
        ClusterConfig,
        EngineConfig,
        Request,
        RoundRobinRouter,
        ServingCluster,
    )

    ec = EngineConfig(
        max_slots=4, max_len=256, chunk_tokens=16, cost_arch=cost_arch,
        tier_specs=[
            TierSpec("host_dram", 1.0),
            TierSpec("local_nvme", 1.0),
            TierSpec("s3", 1.0),
        ],
        store_tier="host_dram",
    )
    tel = None
    if telemetry:
        from repro.obs import Telemetry

        tel = Telemetry()
    cl = ServingCluster(
        cfg, params,
        cluster_cfg=ClusterConfig(n_replicas=replicas, gossip_interval_s=0.05),
        engine_cfg=ec,
        router=None if affinity else RoundRobinRouter(),
        planner_factory=AlwaysReusePlanner,
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF), telemetry=tel,
    )

    # warm wave, bypassing the router: the same 2 throwaway contexts, two
    # passes each (pass 1 compiles the full-prefill bucket, pass 2 the
    # load+suffix bucket), on EVERY replica; shapes match the measured wave
    # so every jit bucket is hot on every replica afterwards
    warm = _requests(
        cfg, n=4, n_ctx=2, ctx_len=CLUSTER_CTX_LEN,
        prompt_len=CLUSTER_PROMPT, new=CLUSTER_NEW,
        arrivals=[0.3 * i for i in range(4)],
        seed=seed + 7, ctx_seed=seed + 900,
    )
    for eng in cl.replicas:
        for r in warm:
            eng.submit(Request(**r))
        eng.run()

    # wave-scoped snapshots (per replica: the cluster has no global clock)
    warm_jit = [dict(e.packed_stats()["jit"]) for e in cl.replicas]
    warm_busy = [e.admission_busy_s + e.decode_busy_s for e in cl.replicas]
    n_warm = [len(e.records) for e in cl.replicas]
    t0 = max(e.clock.now for e in cl.replicas)

    # measured wave: n_ctx chosen to NOT divide the replica count, so
    # round-robin's alternation cannot accidentally act as a perfect
    # affinity router (i % replicas == ctx % replicas for every request)
    n_ctx = next(k for k in range(3, 3 + replicas + 1) if k % replicas != 0)
    reqs = _requests(
        cfg, n=n, n_ctx=n_ctx, ctx_len=CLUSTER_CTX_LEN,
        prompt_len=CLUSTER_PROMPT, new=CLUSTER_NEW,
        arrivals=[0.2 * i for i in range(n)],  # spaced: capacity never
        seed=seed + 1, ctx_seed=seed + 100,    # overrides affinity
    )
    for r in reqs:
        cl.submit(Request(**{**r, "arrival_s": r["arrival_s"] + t0}))
    csum = cl.run()

    records = [
        r for e, k in zip(cl.replicas, n_warm) for r in e.records[k:]
    ]
    hits = sum(1 for r in records if r.action in ("load", "partial"))
    busy = sum(
        e.admission_busy_s + e.decode_busy_s - w
        for e, w in zip(cl.replicas, warm_busy)
    )
    tokens = sum(len(r.tokens) for r in records)
    jit_misses = sum(
        e.packed_stats()["jit"]["misses"] - w["misses"]
        for e, w in zip(cl.replicas, warm_jit)
    )
    stats = cl.stats()
    out = {
        "n_requests": len(records),
        "n_replicas": replicas,
        "n_ctx": n_ctx,
        "hit_rate": hits / max(len(records), 1),
        "reuse_hits": hits,
        "tokens": tokens,
        "busy_s": busy,
        # aggregate serving throughput: generated tokens per modeled busy
        # second across the fleet (wall horizon is arrival-dominated here
        # and identical across routers by construction)
        "tokens_per_busy_s": tokens / max(busy, 1e-12),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in records])),
        "jit_misses": jit_misses,
        "gossip_ticks": stats["gossip_ticks"],
        "requests_per_replica": [len(e.records) - k for e, k in
                                 zip(cl.replicas, n_warm)],
        "shared": stats.get("shared"),
    }
    lane = None
    if tel is not None:
        tel.collect_cluster(cl)
        residuals = {
            str(i): r for i, r in tel.check_cluster(csum).items()
        }
        lane = _telemetry_lane(tel, residuals)
    return out, lane, tel, {r.req_id: r.tokens for r in records}


# Chaos lane knobs.  Per-op rates sit well above the 5% acceptance floor so
# the seeded schedule reliably exercises every failure path at bench size;
# max_attempts=2 keeps retry-exhaustion (the degradation path) observable
# without needing three consecutive bad draws on one key.  The inflation
# ceiling bounds what graceful degradation may cost vs the fault-free run.
CHAOS_FAIL_RATE = 0.4
CHAOS_CORRUPT_RATE = 0.2
CHAOS_COST_CEILING = 2.5
CHAOS_CRASH_AT = 1.1  # s after the measured wave opens: mid-flight
CHAOS_INJ_SEED = 29  # injector seed offset: fixes WHICH ops fail


def _serve_chaos(cfg, params, *, n, replicas, cost_arch, seed):
    """Fault-tolerance lane: the SAME skewed cluster workload twice — once
    fault-free, once under a seeded schedule (transient fetch failures,
    in-flight corruption, a host_dram brownout window, one mid-wave replica
    crash) — producing the comparisons the CI gate asserts: bitwise token
    identity, bounded cost inflation, observed retries/degradations, a
    fired crash, per-replica ledger conservation, zero steady-state
    recompiles.

    Both passes run ``admit_batch=1``: a crash resubmission burst must not
    invent new packed-shape jit buckets mid-measurement, and per-request
    admission makes the clean pass a true cost baseline.  The injector is
    built unarmed and armed only after the warm wave, so every bucket
    compiles fault-free and measured-wave degradations reuse hot kernels."""
    import jax  # noqa: F401

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.kvcache.faults import FaultInjector, RetryPolicy
    from repro.kvcache.hierarchy import TierSpec
    from repro.obs import Telemetry
    from repro.serving import (
        AlwaysReusePlanner,
        ClusterConfig,
        EngineConfig,
        Request,
        ServingCluster,
    )
    from repro.serving import events as ev

    def one_pass(faults):
        tel = Telemetry()
        ec = EngineConfig(
            max_slots=4, max_len=256, chunk_tokens=16, cost_arch=cost_arch,
            tier_specs=[
                TierSpec("host_dram", 1.0),
                TierSpec("local_nvme", 1.0),
                TierSpec("s3", 1.0),
            ],
            store_tier="host_dram",
            admit_batch=1,
            faults=faults,
            retry_policy=RetryPolicy(max_attempts=2),
        )
        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(n_replicas=replicas,
                                      gossip_interval_s=0.05),
            engine_cfg=ec, planner_factory=AlwaysReusePlanner,
            pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF), telemetry=tel,
        )
        warm = _requests(
            cfg, n=4, n_ctx=2, ctx_len=CLUSTER_CTX_LEN,
            prompt_len=CLUSTER_PROMPT, new=CLUSTER_NEW,
            arrivals=[0.3 * i for i in range(4)],
            seed=seed + 7, ctx_seed=seed + 900,
        )
        for eng in cl.replicas:
            for r in warm:
                eng.submit(Request(**r))
            eng.run()

        warm_jit = [dict(e.packed_stats()["jit"]) for e in cl.replicas]
        n_warm = [len(e.records) for e in cl.replicas]
        warm_cost = sum(e.summary().total_cost for e in cl.replicas)
        t0 = max(e.clock.now for e in cl.replicas)

        if faults is not None:
            faults.arm(fail_rate={"*": CHAOS_FAIL_RATE},
                       corrupt_rate={"*": CHAOS_CORRUPT_RATE})
            faults.add_brownout("host_dram", t0 + 1.6, t0 + 2.0)
            faults.schedule_crash(1, t0 + CHAOS_CRASH_AT)

        n_ctx = next(k for k in range(3, 3 + replicas + 1) if k % replicas)
        reqs = _requests(
            cfg, n=n, n_ctx=n_ctx, ctx_len=CLUSTER_CTX_LEN,
            prompt_len=CLUSTER_PROMPT, new=CLUSTER_NEW,
            arrivals=[0.2 * i for i in range(n)],
            seed=seed + 1, ctx_seed=seed + 100,
        )
        for r in reqs:
            cl.submit(Request(**{**r, "arrival_s": r["arrival_s"] + t0}))
        csum = cl.run()

        records = [
            r for e, k in zip(cl.replicas, n_warm) for r in e.records[k:]
        ]
        jit_misses = sum(
            e.packed_stats()["jit"]["misses"] - w["misses"]
            for e, w in zip(cl.replicas, warm_jit)
        )
        # measured-wave spend only: the warm wave is identical across the
        # two passes, so it would dilute the inflation ratio, not inform it
        cost = csum.total_cost - warm_cost
        return cl, csum, tel, records, jit_misses, cost

    _, _, _, rec0, jit0, cost0 = one_pass(None)
    inj = FaultInjector(seed=seed + CHAOS_INJ_SEED)
    cl1, csum1, tel, rec1, jit1, cost1 = one_pass(inj)

    tok0 = {r.req_id: r.tokens for r in rec0}
    tok1 = {r.req_id: r.tokens for r in rec1}
    identical = tok1 == tok0
    assert identical, "chaos-run tokens diverged from the fault-free run"

    evs = [e for _, e in cl1.events]
    n_failed = sum(isinstance(e, ev.FetchFailed) for e in evs)
    n_retried = sum(isinstance(e, ev.FetchRetried) for e in evs)
    n_degraded = sum(isinstance(e, ev.DegradedToRecompute) for e in evs)
    n_crashes = sum(isinstance(e, ev.ReplicaCrashed) for e in evs)

    tel.collect_cluster(cl1)
    residuals = {str(i): r for i, r in tel.check_cluster(csum1).items()}

    out = {
        "n_requests": len(rec1),
        "n_replicas": replicas,
        "fail_rate": CHAOS_FAIL_RATE,
        "corrupt_rate": CHAOS_CORRUPT_RATE,
        "token_identity": bool(identical),
        "fetch_failures": n_failed,
        "fetch_retries": n_retried,
        "degraded_requests": n_degraded,
        "degradation_rate": n_degraded / max(len(rec1), 1),
        "replica_crashes": n_crashes,
        "injector": inj.stats(),
        # dollars on re-issued fetch attempts, separable by construction
        # (the retry loop brackets them with the "fetch_retry" activity)
        "retry_dollars": tel.ledger.by_activity().get("fetch_retry", 0.0),
        "clean_cost": cost0,
        "faulted_cost": cost1,
        "cost_inflation": cost1 / max(cost0, 1e-12),
        "cost_ceiling": CHAOS_COST_CEILING,
        "jit_misses_clean": jit0,
        "jit_misses": jit1,
    }
    lane = _telemetry_lane(tel, residuals)
    lane["fault_stats"] = [e.fault_stats() for e in cl1.replicas]
    return out, lane, {r.req_id: r.tokens for r in rec1}


# Marketplace lane knobs.  Two context lengths split the buy-vs-recompute
# decision.  Prefill time at paper ``cost_arch`` scale has a parameter-read
# floor (~$1.1e-4 whether 16 or 48 tokens), so a 32-token context's KV is
# worth almost nothing over recomputing it, while a 256-token context's
# prefill dollars (~$4.3e-4 over the floor) dwarf both the deep spot-check
# (one floor-priced sample prefill) and the exchange fee.  Sellers price by
# the production write-premium rule — ask = premium x saved_per_use /
# expected_sales — and at 1.25x/1 sale the short ask lands just above its
# recompute headroom (decline) and the long ask well below (buy): the
# cost-aware planner trades exactly the profitable half, the always-buy
# baseline pays fees + verification on worthless shorts too, and never-buy
# recomputes everything.  Three tenants hold disjoint working sets and each
# shops its successor's (t0 -> t1 -> t2 -> t0); t2 turns dishonest
# (in-flight corruption via kvcache.faults) AFTER the jit warm wave.
MARKET_CTX_LEN = 256
MARKET_SHORT_LEN = 32
MARKET_PROMPT = 16
MARKET_NEW = 4
MARKET_TENANTS = 3
MARKET_LONGS = 3  # long contexts per tenant working set
MARKET_SHORTS = 2  # short contexts per tenant working set
MARKET_WRITE_PREMIUM = 1.25  # the production cache-write premium
MARKET_EXPECTED_SALES = 1.0
MARKET_VERIFY_RATE = 0.25
# Flat per-purchase exchange fee (pure fleet deadweight, collected by the
# settlement ledger on top of the 5% rate).  This is what separates the
# cost-aware planner from always-buy: at the parameter-read floor a
# 32-token context saves almost nothing over recompute, so every short
# purchase always-buy makes burns ~the flat fee for free.  The window is
# wide — f > ~2e-5 punishes always-buy's four short purchases, f < ~3.5e-4
# keeps the six long purchases net-positive vs never-buy.
MARKET_FLAT_FEE = 1e-4
MARKET_ADV_SEED = 41  # adversary injector seed offset


def _serve_market(cfg, params, *, cost_arch, seed, mode, telemetry=False):
    """One marketplace configuration over three tenant engines sharing one
    exchange: ``mode`` picks the planner economy — "market" (cost-aware
    buy-vs-recompute), "never" (no marketplace: every cold context
    recomputes), "always" (buy whenever any peer has the bytes).

    Warm wave: each tenant seeds throwaway contexts of both lengths, then
    shops its peer's — compiling every jit bucket the measured wave needs
    (recompute + purchase-absorb shapes, decode, the spot-check sample
    prefill).  The adversary is armed only after, so measured-wave corrupt
    deliveries exercise verification against hot kernels.  Measured wave:
    each tenant serves its own working set (recompute + write back), then
    its successor's (the market's moment: buy, decline, or degrade).
    Totals are wave-scoped; the fleet dollar figure adds the exchange's
    collected fees (purchase prices net out tenant-to-tenant, fees are the
    deadweight) so the three modes compare on real resources burned."""
    import jax  # noqa: F401

    from repro.core.perf_model import PerfModel, V100_X4_HF
    from repro.core.pricing import AWS_PAPER
    from repro.kvcache.faults import FaultInjector
    from repro.market import Marketplace, MarketPlanner
    from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine

    tel = None
    if telemetry:
        from repro.obs import Telemetry

        tel = Telemetry()
    mp = Marketplace(
        verify_rate=MARKET_VERIFY_RATE, flat_fee=MARKET_FLAT_FEE,
        seed=seed, blacklist_after=1,
    )
    names = [f"t{i}" for i in range(MARKET_TENANTS)]
    engines = []
    for i, name in enumerate(names):
        if mode == "never":
            planner, session = AlwaysReusePlanner(), None
        else:
            planner = MarketPlanner(
                AlwaysReusePlanner(), always=(mode == "always")
            )
            session = mp.join(name)
        engines.append(ServingEngine(
            cfg, params,
            engine_cfg=EngineConfig(
                max_slots=4, max_len=512, chunk_tokens=16,
                cost_arch=cost_arch, admit_batch=1,
            ),
            planner=planner, pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF),
            telemetry=tel, telemetry_replica=i, market=session,
        ))
    for ts in mp.tenants.values():
        # production write-premium pricing (see the knob comment): the ask
        # tracks each entry's stamped recompute value, not its byte count
        ts.write_premium = MARKET_WRITE_PREMIUM
        ts.expected_sales = MARKET_EXPECTED_SALES

    rng = np.random.default_rng(seed + 3000)
    tok = lambda L: list(map(int, rng.integers(0, cfg.vocab, L)))  # noqa: E731
    own = [
        {"long": [tok(MARKET_CTX_LEN) for _ in range(MARKET_LONGS)],
         "short": [tok(MARKET_SHORT_LEN) for _ in range(MARKET_SHORTS)]}
        for _ in names
    ]
    warm_ctx = [
        {"long": tok(MARKET_CTX_LEN), "short": tok(MARKET_SHORT_LEN)}
        for _ in names
    ]
    rid = [0] * len(names)  # per-tenant request ids

    def wave(i, ctxs):
        eng, base = engines[i], engines[i].clock.now
        for k, ctx in enumerate(ctxs):
            eng.submit(Request(
                req_id=rid[i], context_tokens=ctx,
                prompt_tokens=tok(MARKET_PROMPT), max_new_tokens=MARKET_NEW,
                arrival_s=base + 0.05 * k,
            ))
            rid[i] += 1
        eng.run()

    # warm: seed own throwaways, then shop the successor's (honest trades —
    # the purchase path's buckets compile here, under every mode's planner)
    for i in range(len(names)):
        wave(i, [warm_ctx[i]["long"], warm_ctx[i]["short"]])
    for i in range(len(names)):
        j = (i + 1) % len(names)
        wave(i, [warm_ctx[j]["long"], warm_ctx[j]["short"]])

    warm_jit = [dict(e.packed_stats()["jit"]) for e in engines]
    warm_cost = [e.summary().total_cost for e in engines]
    warm_fees = mp.settlement.fees_collected
    warm_purchases, warm_failed = mp.purchases, mp.failed_purchases
    warm_blocked, warm_quotes = mp.corrupt_blocked, mp.quotes_served
    warm_spend = sum(e.market_spend for e in engines)
    n_warm = [len(e.records) for e in engines]

    if mode != "never":
        inj = FaultInjector(seed=seed + MARKET_ADV_SEED)
        inj.arm(corrupt_rate=1.0)
        mp.arm_adversary(names[-1], inj)

    # measured: own working set first (recompute + write back everywhere),
    # then the successor's — longs before shorts, so a tenant facing the
    # adversary meets it on a purchase-worthy context and the blacklist
    # covers the rest of its set identically under every mode
    for i in range(len(names)):
        wave(i, own[i]["long"] + own[i]["short"])
    for i in range(len(names)):
        j = (i + 1) % len(names)
        wave(i, own[j]["long"] + own[j]["short"])

    records = [
        (i, r) for i, (e, k) in enumerate(zip(engines, n_warm))
        for r in e.records[k:]
    ]
    cost = sum(e.summary().total_cost - w for e, w in zip(engines, warm_cost))
    fees = mp.settlement.fees_collected - warm_fees
    jit_misses = sum(
        e.packed_stats()["jit"]["misses"] - w["misses"]
        for e, w in zip(engines, warm_jit)
    )
    out = {
        "mode": mode,
        "n_requests": len(records),
        "n_tenants": len(names),
        "purchases": mp.purchases - warm_purchases,
        "failed_purchases": mp.failed_purchases - warm_failed,
        "quotes_served": mp.quotes_served - warm_quotes,
        "corrupt_blocked": mp.corrupt_blocked - warm_blocked,
        "corrupt_served": mp.corrupt_served,
        "adversary_blacklisted": bool(
            mp.reputation.is_blacklisted(names[-1])
        ),
        "market_spend": sum(e.market_spend for e in engines) - warm_spend,
        "fees_collected": fees,
        "engine_cost": cost,
        # the comparison figure: real resources burned fleet-wide (tenant
        # purchase prices net to zero; the exchange's fee take does not)
        "total_cost": cost + fees,
        "settlement_residual": mp.settlement.conservation_residual(),
        "reuse_hits": sum(
            1 for _, r in records if r.action in ("load", "partial")
        ),
        "jit_misses": jit_misses,
        "mean_ttft_s": float(np.mean([r.ttft_s for _, r in records])),
        "accounts": dict(mp.settlement.accounts),
    }
    lane = None
    if tel is not None:
        for i, eng in enumerate(engines):
            tel.collect_engine(eng, replica=i)
        residuals = {
            name: tel.check(eng.summary(), replica=i)
            for i, (name, eng) in enumerate(zip(names, engines))
        }
        residuals["settlement"] = {
            "double_entry": mp.settlement.conservation_residual()
        }
        lane = _telemetry_lane(tel, residuals)
        lane["market"] = mp.stats()
    return out, lane, {(i, r.req_id): r.tokens for i, r in records}


def run(
    n_burst: int = 24,
    n_steady: int = 24,
    slots: int = 8,
    arch: str = "llama-7b",
    cost_arch: str = "llama-7b",
    seed: int = 0,
    n_decode: int = 32,
    decode_slots: int = 32,
    n_rag: int = 16,
    n_unified: int = 4,
    n_cluster: int = 24,
    cluster_replicas: int = 2,
    n_chaos: int = 16,
) -> Dict:
    import jax

    from repro.configs import get_config, reduced_config
    from repro.models import registry

    cfg = reduced_config(get_config(arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)

    workloads = {
        # burst: a query burst against a WARM context store (the paper's
        # reuse regime — contexts were ingested by earlier traffic, here the
        # n_ctx seed requests at t=0).  Suffix prefills are short, so
        # admission is parameter-read/storage-load bound and the packed
        # kernel amortizes one parameter read (and overlaps the loads) over
        # the whole batch.
        "burst": _requests(
            cfg, n=n_burst, n_ctx=2, ctx_len=96, prompt_len=16, new=4,
            arrivals=[0.0] * 2 + [1.0] * (n_burst - 2), seed=seed,
        ),
        # steady: Poisson-ish arrivals over a few shapes — exercises the jit
        # bucket cache (zero recompiles after warmup is asserted below)
        "steady": _requests(
            cfg, n=n_steady, n_ctx=3, ctx_len=96, prompt_len=16, new=4,
            arrivals=np.cumsum(rng.exponential(0.05, n_steady)), seed=seed + 1,
            ctx_seed=seed + 100,
        ),
    }

    # steady-state is measured AFTER a same-shape warmup wave on the same
    # engine: every jit bucket compiles during warmup, so any compile in the
    # measured wave is a steady-state recompile (must be zero).
    warmups = {
        "burst": None,
        "steady": _requests(
            cfg, n=max(n_steady, 2 * slots), n_ctx=3, ctx_len=96,
            prompt_len=16, new=4,
            arrivals=np.cumsum(rng.exponential(0.05, max(n_steady, 2 * slots))),
            seed=seed + 2, ctx_seed=seed + 100,
        ),
    }

    # telemetry lanes: every reuse-side lane (packed, fused, affinity) runs
    # with a Telemetry session attached, the baseline lanes run without —
    # the paired comparisons double as "telemetry changes nothing" evidence,
    # and the lane snapshots feed the check_snapshot.py conservation gate.
    results: Dict = {"workloads": {}, "speedup": {}}
    telemetry: Dict = {}
    for name, reqs in workloads.items():
        packed, tel_lane = _serve(
            cfg, params, reqs, slots=slots, cost_arch=cost_arch,
            admit_batch=None, warmup=warmups[name], telemetry=True,
        )
        single, _ = _serve(cfg, params, reqs, slots=slots, cost_arch=cost_arch,
                           admit_batch=1, warmup=warmups[name])
        results["workloads"][name] = {"packed": packed, "single": single}
        telemetry[f"{name}_packed"] = tel_lane
        results["speedup"][name] = (
            packed["admission_throughput_rps"]
            / max(single["admission_throughput_rps"], 1e-12)
        )
    # decode-bound phase: paged block-pool decode vs dense, same numerics
    paged_d, toks_p = _serve_decode(
        cfg, params, n=n_decode, slots=decode_slots, cost_arch=cost_arch,
        paged=True, seed=seed,
    )
    dense_d, toks_d = _serve_decode(
        cfg, params, n=n_decode, slots=decode_slots, cost_arch=cost_arch,
        paged=False, seed=seed,
    )
    assert toks_p == toks_d, "paged decode must be token-identical to dense"
    results["workloads"]["decode"] = {"paged": paged_d, "dense": dense_d}
    results["speedup"]["decode_tokens_per_s"] = (
        paged_d["decode_tokens_per_s"] / max(dense_d["decode_tokens_per_s"], 1e-12)
    )
    # unified continuous-batching phase: a long-context burst landing
    # mid-decode, chunked+co-scheduled vs the legacy admit-OR-decode stall
    uni, utoks = _serve_unified_lane(
        cfg, params, n=n_unified, cost_arch=cost_arch, seed=seed,
        unified=True,
    )
    leg, ltoks = _serve_unified_lane(
        cfg, params, n=n_unified, cost_arch=cost_arch, seed=seed,
        unified=False,
    )
    assert utoks == ltoks, "unified step must be token-identical to legacy"
    results["workloads"]["unified"] = {"unified": uni, "legacy": leg}
    results["speedup"]["unified_decode_p99"] = (
        leg["p99_gap_ratio"] / max(uni["p99_gap_ratio"], 1e-12)
    )
    # shuffled-chunk RAG phase: fused non-prefix reuse vs full recompute
    rag_f, tel_lane = _serve_rag(cfg, params, n=n_rag, slots=slots,
                                 cost_arch=cost_arch, fused=True, seed=seed,
                                 telemetry=True)
    rag_full, _ = _serve_rag(cfg, params, n=n_rag, slots=slots,
                             cost_arch=cost_arch, fused=False, seed=seed)
    results["workloads"]["rag"] = {"fused": rag_f, "full": rag_full}
    telemetry["rag_fused"] = tel_lane
    results["speedup"]["rag_prefill"] = (
        rag_full["admission_s_per_request"]
        / max(rag_f["admission_s_per_request"], 1e-12)
    )
    # cluster phase: cache-affinity routing vs round-robin over replicas
    clu_a, tel_lane, clu_tel, ctoks_a = _serve_cluster(
        cfg, params, n=n_cluster, replicas=cluster_replicas,
        cost_arch=cost_arch, affinity=True, seed=seed, telemetry=True,
    )
    clu_r, _, _, ctoks_r = _serve_cluster(
        cfg, params, n=n_cluster, replicas=cluster_replicas,
        cost_arch=cost_arch, affinity=False, seed=seed,
    )
    assert ctoks_a == ctoks_r, (
        "routing/telemetry must never change generated tokens"
    )
    results["workloads"]["cluster"] = {"affinity": clu_a, "round_robin": clu_r}
    telemetry["cluster_affinity"] = tel_lane
    results["speedup"]["cluster_hit_rate"] = (
        clu_a["hit_rate"] / max(clu_r["hit_rate"], 1e-12)
    )
    results["speedup"]["cluster_tokens_per_s"] = (
        clu_a["tokens_per_busy_s"] / max(clu_r["tokens_per_busy_s"], 1e-12)
    )
    # chaos phase: the same cluster workload under a seeded fault schedule
    # must finish every request token-identical at bounded extra cost
    chaos, tel_lane, _ = _serve_chaos(
        cfg, params, n=n_chaos, replicas=cluster_replicas,
        cost_arch=cost_arch, seed=seed,
    )
    results["workloads"]["chaos"] = chaos
    telemetry["chaos"] = tel_lane
    # marketplace phase: three tenant economies over the same workload —
    # cost-aware buying must beat BOTH baselines on fleet dollars, with the
    # adversarial seller caught (never served) and tokens bit-identical to
    # pure recompute across all three
    mkt, tel_lane, mtoks = _serve_market(
        cfg, params, cost_arch=cost_arch, seed=seed, mode="market",
        telemetry=True,
    )
    never, _, ntoks = _serve_market(
        cfg, params, cost_arch=cost_arch, seed=seed, mode="never",
    )
    always, _, atoks = _serve_market(
        cfg, params, cost_arch=cost_arch, seed=seed, mode="always",
    )
    assert mtoks == ntoks and atoks == ntoks, (
        "marketplace modes generated different tokens than pure recompute"
    )
    results["workloads"]["market"] = {
        "market": mkt, "never_buy": never, "always_buy": always,
        "token_identity": True,
    }
    telemetry["market"] = tel_lane
    results["speedup"]["market_vs_never_cost"] = (
        never["total_cost"] / max(mkt["total_cost"], 1e-12)
    )
    results["speedup"]["market_vs_always_cost"] = (
        always["total_cost"] / max(mkt["total_cost"], 1e-12)
    )

    results["config"] = {
        "arch": arch, "cost_arch": cost_arch, "slots": slots,
        "n_burst": n_burst, "n_steady": n_steady,
        "n_decode": n_decode, "decode_slots": decode_slots,
        "decode_ctx_lens": DECODE_CTX_LENS,
        "n_rag": n_rag, "rag_chunk": RAG_CHUNK,
        "n_unified": n_unified, "unified_ctx": UNIFIED_CTX,
        "unified_victim_new": UNIFIED_VICTIM_NEW,
        "rag_ctx_chunks": RAG_CTX_CHUNKS, "rag_pool": RAG_POOL,
        "n_cluster": n_cluster, "cluster_replicas": cluster_replicas,
        "cluster_ctx_len": CLUSTER_CTX_LEN,
        "n_chaos": n_chaos, "chaos_fail_rate": CHAOS_FAIL_RATE,
        "chaos_corrupt_rate": CHAOS_CORRUPT_RATE,
        "chaos_cost_ceiling": CHAOS_COST_CEILING,
        "market_tenants": MARKET_TENANTS,
        "market_ctx_len": MARKET_CTX_LEN,
        "market_short_len": MARKET_SHORT_LEN,
        "market_longs": MARKET_LONGS, "market_shorts": MARKET_SHORTS,
        "market_write_premium": MARKET_WRITE_PREMIUM,
        "market_expected_sales": MARKET_EXPECTED_SALES,
        "market_verify_rate": MARKET_VERIFY_RATE,
        "market_flat_fee": MARKET_FLAT_FEE,
    }
    # the affinity lane's span trees, for the optional Perfetto export (the
    # docs/OBSERVABILITY.md walkthrough reads exactly this trace)
    spans = clu_tel.spans() if clu_tel is not None else []
    return results, telemetry, spans


def main() -> List[str]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=24, help="burst workload size")
    ap.add_argument("--steady-requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--decode-requests", type=int, default=32,
                    help="decode-bound workload size")
    ap.add_argument("--decode-slots", type=int, default=32)
    ap.add_argument("--rag-requests", type=int, default=16,
                    help="shuffled-chunk RAG workload size")
    ap.add_argument("--unified-requests", type=int, default=4,
                    help="unified-lane burst size (long-context admissions "
                    "landing mid-decode)")
    ap.add_argument("--cluster-requests", type=int, default=24,
                    help="cluster workload size (measured wave)")
    ap.add_argument("--cluster-replicas", type=int, default=2)
    ap.add_argument("--chaos-requests", type=int, default=16,
                    help="fault-injection lane size (measured wave, run "
                    "twice: clean and faulted)")
    ap.add_argument("--arch", default="llama-7b")
    ap.add_argument("--cost-arch", default="llama-7b")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--metrics-out", default="BENCH_serving_metrics.json",
                    help="telemetry snapshot artifact (registry dumps, "
                    "ledger aggregations, conservation residuals per lane)")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="export the affinity cluster lane's span trees as "
                    "Chrome trace-event JSON (open at ui.perfetto.dev)")
    args = ap.parse_args()

    res, telemetry, spans = run(
        n_burst=args.requests, n_steady=args.steady_requests,
        slots=args.slots, arch=args.arch, cost_arch=args.cost_arch,
        n_decode=args.decode_requests, decode_slots=args.decode_slots,
        n_rag=args.rag_requests,
        n_unified=args.unified_requests,
        n_cluster=args.cluster_requests,
        cluster_replicas=args.cluster_replicas,
        n_chaos=args.chaos_requests,
    )
    pathlib.Path(args.out).write_text(json.dumps(res, indent=2))
    snap = {
        "schema": 1,
        "source": "benchmarks/serve_bench.py",
        "bench_artifact": args.out,
        "lanes": telemetry,
    }
    pathlib.Path(args.metrics_out).write_text(json.dumps(snap, indent=2))
    if args.perfetto:
        from repro.obs import write_chrome_trace

        write_chrome_trace(args.perfetto, spans)

    lines = []
    for name, modes in res["workloads"].items():
        if name in ("decode", "rag", "unified", "cluster", "chaos", "market"):
            continue
        p, s = modes["packed"], modes["single"]
        lines.append(
            f"{name}: packed {p['admission_throughput_rps']:.1f} req/s admission "
            f"(occupancy {p['packed_occupancy']:.2f}, jit hit {p['jit_hit_rate']:.2f}) "
            f"vs single {s['admission_throughput_rps']:.1f} req/s "
            f"-> {res['speedup'][name]:.1f}x; "
            f"mean TTFT {p['mean_ttft_s']*1e3:.1f} ms vs {s['mean_ttft_s']*1e3:.1f} ms"
        )
    d = res["workloads"]["decode"]
    lines.append(
        f"decode: paged {d['paged']['decode_tokens_per_s']:.1f} tok/s "
        f"(shared blocks {d['paged']['shared_block_hits']}) "
        f"vs dense {d['dense']['decode_tokens_per_s']:.1f} tok/s "
        f"-> {res['speedup']['decode_tokens_per_s']:.2f}x"
    )
    u = res["workloads"]["unified"]
    lines.append(
        f"unified: decode p99 gap x{u['unified']['p99_gap_ratio']:.2f} "
        f"of steady ({u['unified']['unified_steps']} mixed launches, "
        f"{u['unified']['unified_chunk_tokens']} chunk tokens, "
        f"{u['unified']['jit_misses']} steady recompiles) vs legacy "
        f"x{u['legacy']['p99_gap_ratio']:.2f} -> "
        f"{res['speedup']['unified_decode_p99']:.2f}x flatter"
    )
    g = res["workloads"]["rag"]
    lines.append(
        f"rag: fused {g['fused']['admission_s_per_request']*1e3:.1f} ms/req "
        f"admission ({g['fused']['fused_admissions']} fused, "
        f"{g['fused']['fused_reused_tokens']} reused / "
        f"{g['fused']['fused_recompute_tokens']} recomputed tokens) "
        f"vs full {g['full']['admission_s_per_request']*1e3:.1f} ms/req "
        f"-> {res['speedup']['rag_prefill']:.2f}x"
    )
    c = res["workloads"]["cluster"]
    lines.append(
        f"cluster: affinity hit rate {c['affinity']['hit_rate']:.3f} "
        f"({c['affinity']['tokens_per_busy_s']:.1f} tok/s, "
        f"{c['affinity']['gossip_ticks']} gossip ticks) "
        f"vs round-robin {c['round_robin']['hit_rate']:.3f} "
        f"({c['round_robin']['tokens_per_busy_s']:.1f} tok/s) "
        f"-> {res['speedup']['cluster_hit_rate']:.2f}x hits, "
        f"{res['speedup']['cluster_tokens_per_s']:.2f}x tok/s"
    )
    h = res["workloads"]["chaos"]
    lines.append(
        f"chaos: tokens identical={h['token_identity']} under "
        f"{h['fetch_failures']} injected fetch failures "
        f"({h['fetch_retries']} retried, {h['degraded_requests']} degraded "
        f"to recompute, {h['replica_crashes']} replica crash) -> "
        f"cost x{h['cost_inflation']:.2f} vs clean "
        f"(ceiling x{h['cost_ceiling']:.1f}), "
        f"retry spend ${h['retry_dollars']:.6f}, "
        f"{h['jit_misses']} steady-state recompiles"
    )
    mw = res["workloads"]["market"]
    m = mw["market"]
    lines.append(
        f"market: cost-aware ${m['total_cost']:.6f} fleet "
        f"({m['purchases']} purchases, {m['corrupt_blocked']} corrupt "
        f"blocked, blacklisted={m['adversary_blacklisted']}) vs never-buy "
        f"${mw['never_buy']['total_cost']:.6f} "
        f"({res['speedup']['market_vs_never_cost']:.2f}x) and always-buy "
        f"${mw['always_buy']['total_cost']:.6f} "
        f"({res['speedup']['market_vs_always_cost']:.2f}x); tokens "
        f"identical={mw['token_identity']}, "
        f"{m['jit_misses']} steady-state recompiles, settlement residual "
        f"{m['settlement_residual']:.1e}"
    )
    for lane, snap_lane in telemetry.items():
        led = snap_lane["ledger"]
        lines.append(
            f"telemetry[{lane}]: ledger ${sum(led['totals'].values()):.4f} "
            f"({led['n_entries']} entries, "
            f"infra ${led['infrastructure']:.6f}), conservation residuals "
            f"all <= 1e-9"
        )
    for ln in lines:
        print(ln)

    # acceptance criteria (speedup floors, zero-steady-state-recompile,
    # cluster hit-rate floor, ledger conservation) live in
    # benchmarks/check_snapshot.py, which CI runs against the two artifacts
    # written here — keeping the measurement and the gate separable.
    print(f"wrote {args.out}")
    print(f"wrote {args.metrics_out}")
    if args.perfetto:
        print(f"wrote {args.perfetto}")
    return lines


if __name__ == "__main__":
    main()
