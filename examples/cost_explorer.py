"""Cost explorer: the paper's analytical model as a planning tool.

Sweeps workload parameters (context length, reuse count, output length)
across architectures / storage tiers / compression and prints when KV reuse
wins, by how much, and what drives the bill — the developer-facing artifact
the paper argues for ("an analytical model for developers to compare service
costs given their workload pattern and cloud pricing policy").

    PYTHONPATH=src python examples/cost_explorer.py --arch mistral-nemo-12b
"""
import argparse

from repro.configs import get_config, list_configs
from repro.core.cost_model import (
    Workload, break_even_reuses, cost_kv, cost_text, delay_kv, delay_text,
)
from repro.core.perf_model import PerfModel, V100_X4_HF, tpu_v5e
from repro.core.pricing import AWS_PAPER, tpu_v5e_pod


def explore(arch: str, platform: str):
    cfg = get_config(arch)
    if platform == "tpu":
        pm, pricing = PerfModel(tpu_v5e(8, hosts=1)), tpu_v5e_pod(8)
    else:
        pm, pricing = PerfModel(V100_X4_HF), AWS_PAPER

    print(f"=== {arch} on {pm.hw.name} ===")
    print(f"{'L_ctx':>8s} {'N':>4s} {'L_out':>6s} | {'C_text':>9s} {'C_KV':>9s} "
          f"{'ratio':>6s} | {'TTFT_text':>9s} {'TTFT_KV':>8s} | {'N*':>4s}")
    for L_ctx in (2_000, 10_000, 32_000, 100_000):
        if cfg.family not in ("ssm", "hybrid") and not cfg.sliding_window:
            if L_ctx > cfg.max_seq_len:
                continue
        for N in (2, 10, 100):
            for L_out in (16, 128):
                w = Workload(L_context=L_ctx, L_prompt=32, L_output=L_out, N=N)
                ct = cost_text(cfg, w, pricing, pm).total
                ck = cost_kv(cfg, w, pricing, pm).total
                dt = delay_text(cfg, w, pm).ttft_s
                dk = delay_kv(cfg, w, pm, tier=pricing.tier()).ttft_s
                ns = break_even_reuses(cfg, w, pricing, pm)
                print(f"{L_ctx:8d} {N:4d} {L_out:6d} | {ct:9.4f} {ck:9.4f} "
                      f"{ct/ck:6.2f} | {dt:9.3f} {dk:8.3f} | {str(ns):>4s}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b", choices=list_configs())
    ap.add_argument("--platform", default="paper", choices=["paper", "tpu"])
    ap.add_argument("--all", action="store_true", help="sweep every assigned arch")
    args = ap.parse_args()
    archs = list_configs(assigned_only=True) if args.all else [args.arch]
    for a in archs:
        explore(a, args.platform)
        print()


if __name__ == "__main__":
    main()
