"""Quickstart: the paper's idea in 60 lines.

Serves six requests that share two long contexts, once with stored-KV reuse
and once with plain recomputation, and shows: identical generations, lower
modeled cost and TTFT (economics modeled at full llama-7b scale while the
compute runs a reduced model on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER
from repro.models import registry
from repro.serving import AlwaysReusePlanner, EngineConfig, Request, ServingEngine


def main():
    cfg = reduced_config(get_config("llama-7b"))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    contexts = [list(map(int, rng.integers(0, cfg.vocab, 96))) for _ in range(2)]
    requests = [
        Request(
            req_id=i,
            context_tokens=contexts[i % 2],
            prompt_tokens=list(map(int, rng.integers(0, cfg.vocab, 16))),
            max_new_tokens=8,
            arrival_s=i * 0.05,
            expected_reuses=3,
        )
        for i in range(6)
    ]

    def serve(reuse: bool):
        eng = ServingEngine(
            cfg, params,
            engine_cfg=EngineConfig(
                max_slots=2, max_len=160, chunk_tokens=16,
                reuse_enabled=reuse,
                cost_arch="llama-7b",  # model $ and delays at paper scale
            ),
            planner=AlwaysReusePlanner(),  # the paper's Fig-2 pipeline
            pricing=AWS_PAPER,
            perf=PerfModel(V100_X4_HF),
        )
        for r in requests:
            eng.submit(r)
        summary = eng.run()
        return eng, summary

    eng_kv, s_kv = serve(reuse=True)
    eng_txt, s_txt = serve(reuse=False)

    print("request  action     tokens")
    for rec in sorted(eng_kv.records, key=lambda r: r.req_id):
        print(f"  #{rec.req_id}     {rec.action:10s} {rec.tokens}")
    same = all(
        a.tokens == b.tokens
        for a, b in zip(
            sorted(eng_kv.records, key=lambda r: r.req_id),
            sorted(eng_txt.records, key=lambda r: r.req_id),
        )
    )
    print(f"\ngenerations identical to recompute: {same}")
    print(f"KV reuse : ${s_kv.total_cost:.4f}  mean TTFT {s_kv.mean_ttft_s:.2f}s "
          f"(storage {100*s_kv.storage_cost/s_kv.total_cost:.2f}% of total)")
    print(f"recompute: ${s_txt.total_cost:.4f}  mean TTFT {s_txt.mean_ttft_s:.2f}s")
    print(f"savings  : {s_txt.total_cost/s_kv.total_cost:.2f}x cost, "
          f"{s_txt.mean_ttft_s/s_kv.mean_ttft_s:.2f}x TTFT")


if __name__ == "__main__":
    main()
