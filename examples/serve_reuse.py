"""End-to-end serving driver (the paper's evaluation, live).

Replays a TriviaQA-like context-sharing workload (many requests share long
contexts) through the continuous-batching engine in all three policies:

  recompute  — the paper's text-recomputation baseline
  paper      — cost-model-gated store/load (the paper's pipeline)
  beyond     — + int8 storage tier + prefetch overlap + hedged loads
               (the beyond-paper optimizations, DESIGN.md §3)

Real compute (reduced llama on CPU), paper-scale economics
(EngineConfig.cost_arch="llama-7b", V100/HF-MP perf model, AWS pricing).

    PYTHONPATH=src python examples/serve_reuse.py [--requests 24] [--arch llama-7b]
"""
import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER
from repro.data.synthetic import WorkloadSpec, serving_workload
from repro.models import registry
from repro.serving import CostAwarePlanner, EngineConfig, Request, ServingEngine
from repro.serving.scheduler import HedgePolicy


def build_engine(cfg, params, mode: str, cost_arch: str):
    common = dict(max_slots=4, max_len=256, chunk_tokens=16, cost_arch=cost_arch)
    if mode == "recompute":
        ec = EngineConfig(reuse_enabled=False, **common)
    elif mode == "paper":
        ec = EngineConfig(**common)
    elif mode == "beyond":
        ec = EngineConfig(
            compress_tier="io2", overlap_load=True,
            hedge=HedgePolicy(threshold_s=0.8, parallelism=2),
            prefetch_lookahead=4, **common,
        )
    else:
        raise ValueError(mode)
    return ServingEngine(
        cfg, params, engine_cfg=ec, planner=CostAwarePlanner(),
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b", help="economics arch (full size)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--contexts", type=int, default=6)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    spec = WorkloadSpec(
        n_contexts=args.contexts,
        reuses_per_context=max(1, args.requests // args.contexts),
        context_len=96, prompt_len=16, output_len=8,
        arrival_rate_per_s=2.0, seed=0,
    )
    reqs = serving_workload(cfg, spec)

    print(f"{len(reqs)} requests over {args.contexts} shared contexts "
          f"({spec.reuses_per_context}x reuse), economics at {args.arch} scale\n")
    print(f"{'policy':10s} {'hits':>5s} {'cost $':>9s} {'TTFT s':>8s} "
          f"{'p99 e2e s':>10s} {'storage %':>10s}")
    results = {}
    for mode in ("recompute", "paper", "beyond"):
        eng = build_engine(cfg, params, mode, args.arch)
        for r in reqs:
            eng.submit(Request(**r.__dict__))
        s = eng.run()
        results[mode] = (s, {rec.req_id: rec.tokens for rec in eng.records})
        frac = 100 * s.storage_cost / max(s.total_cost, 1e-12)
        print(f"{mode:10s} {s.reuse_hits:5d} {s.total_cost:9.4f} "
              f"{s.mean_ttft_s:8.3f} {s.p99_e2e_s:10.3f} {frac:10.3f}")

    base = results["recompute"][0]
    for mode in ("paper", "beyond"):
        s = results[mode][0]
        print(f"\n{mode}: {base.total_cost/s.total_cost:.2f}x cheaper, "
              f"{base.mean_ttft_s/s.mean_ttft_s:.2f}x faster TTFT vs recompute; "
              f"tokens identical: {results[mode][1] == results['recompute'][1]}")


if __name__ == "__main__":
    main()
