"""End-to-end serving driver (the paper's evaluation, live).

Replays a TriviaQA-like context-sharing workload (many requests share long
contexts) through the continuous-batching engine in four policies:

  recompute  — the paper's text-recomputation baseline
  paper      — cost-model-gated store/load (the paper's pipeline)
  beyond     — + int8 storage tier + prefetch overlap + hedged loads
               (the beyond-paper optimizations, DESIGN.md §3)
  hierarchy  — + the full tier hierarchy (host_dram -> local_nvme -> s3),
               write-backs land hot, break-even migration demotes cold
               entries, the s3 link is concurrency-limited

Real compute (reduced llama on CPU), paper-scale economics
(EngineConfig.cost_arch="llama-7b", V100/HF-MP perf model, AWS pricing).
Ends with the per-request SLO audit of the hierarchy run (serving/audit.py).
``--trace PATH`` exports every policy's typed event stream as JSONL (one
line per event, tagged with its ``mode``; serving/trace.py).

With ``--replicas N`` (N > 1) the same workload instead runs through a
``ServingCluster``: N engine replicas with private host_dram/local_nvme
tiers over ONE shared s3 core, requests placed by ``--router`` (affinity =
gossiped-digest cache-affinity routing, round_robin = cache-oblivious
baseline), ending with the per-replica SLO audit table.

``--telemetry`` attaches a ``Telemetry`` session (repro.obs) to the
hierarchy run (or the whole cluster) and prints the console dashboard:
headline cache-hit-rate, latency histograms, the cost ledger's "where did
the money go" tables, and the conservation check against the summary.
``--perfetto PATH`` additionally exports the telemetry span trees as
Chrome trace-event JSON (load it at https://ui.perfetto.dev).

    PYTHONPATH=src python examples/serve_reuse.py [--requests 24]
        [--arch llama-7b] [--trace events.jsonl]
        [--replicas 2 --router affinity]
        [--telemetry] [--perfetto trace.json]
"""
import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER
from repro.data.synthetic import WorkloadSpec, serving_workload
from repro.kvcache.hierarchy import TierSpec
from repro.models import registry
from repro.serving import (
    ClusterConfig,
    CostAwarePlanner,
    EngineConfig,
    Request,
    RoundRobinRouter,
    ServingCluster,
    ServingEngine,
)
from repro.serving import audit as audit_mod
from repro.serving import trace as trace_mod
from repro.serving.scheduler import HedgePolicy

MODES = ("recompute", "paper", "beyond", "hierarchy")


def build_engine(cfg, params, mode: str, cost_arch: str, telemetry=None):
    common = dict(max_slots=4, max_len=256, chunk_tokens=16, cost_arch=cost_arch)
    if mode == "recompute":
        ec = EngineConfig(reuse_enabled=False, **common)
    elif mode == "paper":
        ec = EngineConfig(**common)
    elif mode == "beyond":
        ec = EngineConfig(
            compress_tier="io2", overlap_load=True,
            hedge=HedgePolicy(threshold_s=0.8, parallelism=2),
            prefetch_lookahead=4, **common,
        )
    elif mode == "hierarchy":
        ec = EngineConfig(
            tier_specs=[
                TierSpec("host_dram", 64.0),
                TierSpec("local_nvme", 512.0),
                TierSpec("s3", 4096.0, concurrency=2),
            ],
            store_tier="host_dram",  # write-backs land hot...
            migration_interval_s=5.0,  # ...break-even math demotes the cold
            spill_on_pressure=True,
            overlap_load=True,
            hedge=HedgePolicy(threshold_s=0.8, parallelism=2),
            prefetch_lookahead=4, **common,
        )
    else:
        raise ValueError(mode)
    return ServingEngine(
        cfg, params, engine_cfg=ec, planner=CostAwarePlanner(),
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF), telemetry=telemetry,
    )


def run_cluster(cfg, params, reqs, args):
    """Cluster branch: the workload through N replicas behind one router,
    ending with the per-replica SLO audit (serving/audit.cluster_audit)."""
    ec = EngineConfig(
        max_slots=4, max_len=256, chunk_tokens=16, cost_arch=args.arch,
        tier_specs=[
            TierSpec("host_dram", 64.0),
            TierSpec("local_nvme", 512.0),
            TierSpec("s3", 4096.0, concurrency=2),
        ],
        store_tier="host_dram",
    )
    tracer = trace_mod.TraceWriter(args.trace) if args.trace else None
    tel = None
    if args.telemetry or args.perfetto:
        from repro import obs

        tel = obs.Telemetry()
    cl = ServingCluster(
        cfg, params,
        cluster_cfg=ClusterConfig(
            n_replicas=args.replicas, gossip_interval_s=0.5,
        ),
        engine_cfg=ec,
        router=RoundRobinRouter() if args.router == "round_robin" else None,
        planner_factory=CostAwarePlanner,
        pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF),
        trace=tracer, telemetry=tel,
    )
    requests = [Request(**r.__dict__) for r in reqs]
    for r in requests:
        cl.submit(r)
    s = cl.run()

    print(f"cluster: {args.replicas} replicas, {args.router} router, "
          f"economics at {args.arch} scale")
    print(f"requests {s.n_requests}, reuse hits {s.reuse_hits} "
          f"(hit rate {s.hit_rate:.3f}), total cost ${s.total_cost:.4f}, "
          f"mean TTFT {s.mean_ttft_s:.3f} s, "
          f"{s.tokens_generated} tokens over {s.horizon_s:.2f} s")
    stats = cl.stats()
    shared = stats.get("shared")
    print(f"gossip ticks {stats['gossip_ticks']}, "
          f"rebalances {stats['rebalances']}"
          + (f", shared tier: {shared['n_keys']} keys over "
             f"{shared['n_contents']} contents "
             f"({shared['dedup_hits']} dedup hits)" if shared else ""))

    print("\nSLO audit (per replica):")
    rows = audit_mod.cluster_audit(cl.events_by_replica, requests)
    print(audit_mod.format_cluster_table(rows))
    if tel is not None:
        from repro.obs import console, write_chrome_trace

        tel.collect_cluster(cl)
        print()
        print(console.render(tel))
        residuals = tel.check_cluster(s)
        worst = max(
            (r for rs in residuals.values() for r in rs.values()),
            default=0.0,
        )
        print(f"conservation per replica: OK "
              f"(max residual {worst:.2e} <= 1e-9)")
        if args.perfetto:
            p = write_chrome_trace(args.perfetto, tel.spans())
            print(f"wrote Perfetto trace to {p}")
    if tracer is not None:
        tracer.close()
        print(f"\nwrote {tracer.n_events} events to {tracer.path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b", help="economics arch (full size)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--contexts", type=int, default=6)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export every mode's typed event stream as JSONL")
    ap.add_argument("--replicas", type=int, default=1,
                    help="> 1 serves the workload through a ServingCluster")
    ap.add_argument("--router", choices=("affinity", "round_robin"),
                    default="affinity", help="cluster request placement")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach a Telemetry session to the hierarchy run "
                    "(or the cluster) and print the console dashboard")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="export telemetry span trees as Chrome trace-event "
                    "JSON (implies --telemetry)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    spec = WorkloadSpec(
        n_contexts=args.contexts,
        reuses_per_context=max(1, args.requests // args.contexts),
        context_len=96, prompt_len=16, output_len=8,
        arrival_rate_per_s=2.0, seed=0,
    )
    reqs = serving_workload(cfg, spec)

    if args.replicas > 1:
        run_cluster(cfg, params, reqs, args)
        return

    print(f"{len(reqs)} requests over {args.contexts} shared contexts "
          f"({spec.reuses_per_context}x reuse), economics at {args.arch} scale\n")
    print(f"{'policy':10s} {'hits':>5s} {'cost $':>9s} {'TTFT s':>8s} "
          f"{'p99 e2e s':>10s} {'storage %':>10s}")
    results = {}
    tracer = trace_mod.TraceWriter(args.trace) if args.trace else None
    tel = None
    if args.telemetry or args.perfetto:
        from repro import obs

        tel = obs.Telemetry()
    tel_engine = None
    for mode in MODES:
        # telemetry rides the hierarchy run only: the mode whose economics
        # (tiered storage, migration, write-backs) the ledger is about
        eng = build_engine(cfg, params, mode, args.arch,
                           telemetry=tel if mode == "hierarchy" else None)
        if mode == "hierarchy":
            tel_engine = eng
        requests = [Request(**r.__dict__) for r in reqs]
        for r in requests:
            eng.submit(r)
        events = []
        for e in eng.drain():  # live export: each event lands as it happens
            events.append(e)
            if tracer is not None:
                tracer.write(e, mode=mode)
        s = eng.summary()
        results[mode] = (s, {rec.req_id: rec.tokens for rec in eng.records},
                         events, requests)
        frac = 100 * s.storage_cost / max(s.total_cost, 1e-12)
        print(f"{mode:10s} {s.reuse_hits:5d} {s.total_cost:9.4f} "
              f"{s.mean_ttft_s:8.3f} {s.p99_e2e_s:10.3f} {frac:10.3f}")

    base = results["recompute"][0]
    for mode in MODES[1:]:
        s = results[mode][0]
        print(f"\n{mode}: {base.total_cost/s.total_cost:.2f}x cheaper, "
              f"{base.mean_ttft_s/s.mean_ttft_s:.2f}x faster TTFT vs recompute; "
              f"tokens identical: {results[mode][1] == results['recompute'][1]}")

    if tracer is not None:
        tracer.close()
        print(f"\nwrote {tracer.n_events} events to {tracer.path}")

    # fold the hierarchy run's event stream into the per-request SLO audit
    _, _, events, requests = results["hierarchy"]
    rows = audit_mod.audit(events, requests)
    print("\nSLO audit (hierarchy run):")
    print(audit_mod.format_table(rows))
    print(f"summary: {audit_mod.slo_summary(rows)}")

    if tel is not None:
        from repro.obs import console, write_chrome_trace

        tel.collect_engine(tel_engine)
        print()
        print(console.render(tel, results["hierarchy"][0]))
        if args.perfetto:
            p = write_chrome_trace(args.perfetto, tel.spans())
            print(f"wrote Perfetto trace to {p}")


if __name__ == "__main__":
    main()
