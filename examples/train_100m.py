"""Train a ~100M-parameter qwen2-family model with the full substrate:
AdamW + cosine schedule, grad accumulation, async checkpointing, auto-resume
and straggler tracking (ResilientLoop).

The default invocation is CPU-sized (a few minutes); ``--full`` selects the
real ~100M config — the same command a TPU host would run:

    PYTHONPATH=src python examples/train_100m.py                # smoke size
    PYTHONPATH=src python examples/train_100m.py --full --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.synthetic import token_batches
from repro.models import registry
from repro.training.fault import LoopConfig, ResilientLoop
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_step import make_train_step


def build_cfg(full: bool):
    base = get_config("qwen2-0.5b")
    if full:
        # ~100M params: 12 layers x d_model 640, vocab 32k
        return dataclasses.replace(
            base, name="qwen2-100m", n_layers=12, d_model=640, n_heads=10,
            n_kv_heads=2, d_ff=2560, vocab=32_000, head_dim=64,
            param_dtype="float32", dtype="float32", param_partition="dp",
            remat="none",
        )
    return reduced_config(base, n_layers=4, d_model=128, n_heads=4,
                          n_kv_heads=2, d_ff=512, vocab=2048, head_dim=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="~100M-param config")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_100m")
    args = ap.parse_args()

    cfg = build_cfg(args.full)
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    opt = AdamW(lr=3e-4, weight_decay=0.01,
                schedule=cosine_schedule(warmup=20, total=args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt))

    it = token_batches(cfg, batch=args.batch, seq_len=args.seq, seed=0)
    cache = {}

    def batch_fn(i):
        if i not in cache:
            cache[i] = {k: jnp.asarray(v) for k, v in next(it).items()}
        return cache[i]

    loop = ResilientLoop(
        step_fn, batch_fn,
        LoopConfig(total_steps=args.steps, ckpt_every=20, ckpt_dir=args.ckpt_dir),
    )
    out = loop.run(params, opt.init(params))
    print(f"finished at step {out['completed']}: "
          f"loss {float(out['metrics']['loss']):.3f}, "
          f"stragglers {out['stragglers']}, checkpoints in {args.ckpt_dir}")
    print("(re-running this command resumes from the newest checkpoint)")


if __name__ == "__main__":
    main()
