"""repro: TPU-native LLM serving/training framework with stored-KV-cache reuse.

Reproduction of "Towards More Economical Context-Augmented LLM Generation by
Reusing Stored KV Cache" (Li et al., UChicago, 2025) — see DESIGN.md.
"""
__version__ = "1.0.0"
