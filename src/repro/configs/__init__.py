"""Config registry: the 10 assigned architectures + the paper's Llama-7B."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, MoEConfig, SSMConfig, ShapeSpec, cell_is_runnable
from repro.configs import (
    granite_34b,
    internvl2_1b,
    jamba_1_5_large_398b,
    llama_7b,
    mamba2_1_3b,
    mistral_nemo_12b,
    mixtral_8x22b,
    olmoe_1b_7b,
    qwen2_0_5b,
    qwen2_1_5b,
    whisper_tiny,
)

# The 10 assigned architectures (dry-run/roofline matrix rows).
ASSIGNED: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_34b,
        mistral_nemo_12b,
        qwen2_1_5b,
        qwen2_0_5b,
        whisper_tiny,
        internvl2_1b,
        jamba_1_5_large_398b,
        olmoe_1b_7b,
        mixtral_8x22b,
        mamba2_1_3b,
    )
}

# Extra configs (not part of the assigned matrix): the paper's own model.
EXTRA: Dict[str, ArchConfig] = {llama_7b.CONFIG.name: llama_7b.CONFIG}

CONFIGS: Dict[str, ArchConfig] = {**ASSIGNED, **EXTRA}


def get_config(name: str) -> ArchConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_configs(assigned_only: bool = False) -> List[str]:
    return sorted(ASSIGNED if assigned_only else CONFIGS)


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A small same-family config for CPU smoke tests (spec: 'small layers /
    width, few experts, tiny embedding tables').  Keeps every structural
    feature (GQA ratios, MoE, SSD, hybrid period, biases) while shrinking
    dimensions."""
    small = dict(
        n_layers=len(cfg.hybrid_period) if cfg.hybrid_period else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        head_dim=16,
        max_seq_len=256,
        param_partition="dp",
        remat="none",
        param_dtype="float32",
        dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor >= n_experts/top_k guarantees zero token drops, so
        # reuse-vs-recompute equality checks are exact in smoke tests.
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), capacity_factor=4.0
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16
        )
    if cfg.family == "encdec":
        small["n_encoder_layers"] = 2
        small["encoder_seq_len"] = 32
        small["decoder_seq_len"] = 64
    if cfg.frontend_tokens:
        small["frontend_tokens"] = 8
    if cfg.sliding_window:
        small["sliding_window"] = 16
    small["name"] = cfg.name + "-smoke"
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
