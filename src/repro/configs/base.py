"""Architecture + shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeSpec` entries in :data:`SHAPES`.

Design notes
------------
* ``ArchConfig`` is a frozen dataclass so configs are hashable and usable as
  static jit arguments.
* ``head_dim`` may differ from ``d_model // n_heads`` (e.g. Mistral-Nemo uses
  head_dim=128 with d_model=5120, 32 heads).
* ``padded_vocab`` rounds the embedding table up to a multiple of 128 so the
  vocab dimension shards cleanly over a 16-wide model axis and aligns with the
  TPU lane width.
* ``hybrid_period`` describes one repeated period of layer kinds for hybrid
  stacks (Jamba): the model scans over periods and unrolls within a period.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for MoE / hybrid architectures."""

    n_experts: int
    top_k: int
    # MoE replaces the dense MLP on layers where ``layer_idx % every == offset``.
    every: int = 1
    offset: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings for SSM and hybrid architectures."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A complete, exact architecture description from the public literature."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention details -------------------------------------------------
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10_000.0  # None => no rotary embedding
    sliding_window: Optional[int] = None  # SWA window (Mixtral)

    # --- family-specific ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # One repeated period of layer kinds, e.g. Jamba:
    #   ("m", "m", "m", "m", "a", "m", "m", "m")  (attention at index 4)
    hybrid_period: Optional[Tuple[str, ...]] = None
    n_encoder_layers: int = 0  # enc-dec (Whisper): encoder depth
    encoder_seq_len: int = 1500  # Whisper: fixed 30 s => 1500 frames
    decoder_seq_len: int = 448  # Whisper: max decoder positions

    # --- frontend stubs (audio / vlm) — per spec the modality frontend is a
    # stub: input_specs() provides precomputed frame/patch embeddings. -------
    frontend: Optional[str] = None  # "audio" | "vision"
    frontend_tokens: int = 0  # number of stub embedding positions (vision)

    # --- misc ---------------------------------------------------------------
    tie_embeddings: bool = False
    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm" (Whisper)
    mlp_type: str = "swiglu"  # "swiglu" | "gelu" (Whisper)
    abs_pos_embed: bool = False  # sinusoidal absolute positions (Whisper)
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"  # activation dtype
    max_seq_len: int = 32_768

    # --- distribution policy ------------------------------------------------
    # "dp"    : params replicated over data axis (small models)
    # "fsdp"  : params additionally sharded over the data axis (big models)
    param_partition: str = "dp"
    # remat policy for the scanned layer body: none | dots | full
    remat: str = "none"
    # Fully unroll the scan over layers (used by the dry-run's depth
    # calibration: XLA cost_analysis counts a while-loop body ONCE, so the
    # roofline pipeline compiles unrolled 1- and 2-period variants and
    # extrapolates the linear-in-depth term; see benchmarks/roofline.py).
    scan_unroll: bool = False

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, VOCAB_PAD_MULTIPLE)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def n_attn_layers(self) -> int:
        """Number of self-attention layers in the decoder stack."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            assert self.hybrid_period is not None
            per = sum(1 for k in self.hybrid_period if k == "a")
            return per * (self.n_layers // len(self.hybrid_period))
        return self.n_layers

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            assert self.hybrid_period is not None
            per = sum(1 for k in self.hybrid_period if k == "m")
            return per * (self.n_layers // len(self.hybrid_period))
        return 0

    @property
    def supports_long_context(self) -> bool:
        """True if the arch can decode with a 500k context sub-quadratically
        and with bounded per-layer state (SSM, hybrid, or SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    # --- parameter counting (used by the cost model & roofline) ---------- #
    def param_count(self) -> int:
        """Exact parameter count of the implemented model (including biases,
        norms and the padded embedding table)."""
        from repro.models.registry import count_params  # late import, no cycle

        return count_params(self)

    def kv_bytes_per_token(self, kv_dtype_bytes: int = 2) -> int:
        """Bytes of *stored context state* per context token (the paper's
        ``S_storage(L) / L``).  For attention layers this is the classic
        2 * n_kv * head_dim * bytes; SSM layers contribute zero per-token
        bytes (their state is O(1), accounted separately)."""
        per_attn = 2 * self.n_kv_heads * self.resolved_head_dim * kv_dtype_bytes
        n_attn = self.n_attn_layers
        if self.family == "encdec":
            # decoder self-attn KV + decoder cross-attn KV over the encoder
            # output are both per-context-token state.
            n_attn = self.n_layers * 2
        return per_attn * n_attn

    def fixed_state_bytes(self, dtype_bytes: int = 2) -> int:
        """O(1)-in-L stored state: SSD state + conv state for SSM layers."""
        if self.ssm is None or self.n_ssm_layers == 0:
            return 0
        s = self.ssm
        d_in = s.d_inner(self.d_model)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        ssd = s.n_ssm_heads(self.d_model) * s.head_dim * s.d_state
        conv = (s.d_conv - 1) * conv_dim
        return self.n_ssm_layers * (ssd + conv) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable dry-run cell, and why not if not.

    Per the spec: ``long_500k`` needs sub-quadratic context handling — skip for
    pure full-attention archs (documented in DESIGN.md §6); run for
    SSM / hybrid / SWA archs.  No encoder-only archs are assigned, so decode
    shapes are never skipped.
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            f"{cfg.name} is a pure full-attention arch: a 524288-token dense KV "
            "decode is quadratic-cost/unbounded-KV (skip per DESIGN.md §6)"
        )
    return True, ""
