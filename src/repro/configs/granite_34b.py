"""granite-34b — IBM Granite Code 34B [arXiv:2405.04324; hf].

Llama-style attention stack with MQA (a single KV head) => the stored-KV
footprint per token is 48x smaller than MHA, which drops the paper's
break-even reuse frequency dramatically (DESIGN.md §6).

The 34B Granite Code model is GPTBigCode-derived: its MLP is the 2-matrix
GELU form (a SwiGLU d_ff=24576 MLP would give ~47B params, not 34B — we
checked via eval_shape; with GELU the implemented model is 33.6B ≈ 34B).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,  # MQA
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    rope_theta=10_000.0,
    mlp_type="gelu",
    tie_embeddings=False,
    param_partition="fsdp",
    remat="dots",
)
