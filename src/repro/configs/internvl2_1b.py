"""internvl2-1b — InternVL2-1B [arXiv:2404.16821; hf].

InternViT-300M + Qwen2-0.5B backbone.  Per the assignment the vision
frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(``frontend_tokens`` positions) which the LM prepends to the text tokens.
KV reuse applies to the image-context positions (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend="vision",
    frontend_tokens=256,
    param_partition="dp",
)
