"""jamba-1.5-large-398b — AI21 Jamba-1.5-Large [arXiv:2403.19887; hf].

Hybrid Mamba+attention at a 1:7 attn:mamba interleave (one attention layer
per 8-layer period), MoE (16 experts, top-2) on every other layer, no
positional embedding (the Mamba layers carry position).  Jamba's Mamba-1
layers are implemented in the SSD (Mamba-2) formulation — same O(1) state
semantics, TPU-friendlier chunked-matmul form (DESIGN.md §3).

Stored context state = 9 attention layers' KV + per-Mamba-layer (conv, SSD)
state => the paper's S_storage gains an L-independent term (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,  # 9 periods x 8 layers
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    rope_theta=None,  # Jamba uses no positional embedding
    moe=MoEConfig(n_experts=16, top_k=2, every=2, offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=128, n_groups=1, chunk=256),
    hybrid_period=("m", "m", "m", "m", "a", "m", "m", "m"),
    max_seq_len=262_144,
    param_partition="fsdp",
    remat="dots",
)
