"""llama-7b — the paper's own evaluation model (Llama-7B on 4xV100).

Used by the cost-model validation tests and the Fig-2 reproduction
benchmarks: 32 layers x 32 heads x 128 head_dim, MHA => KV bytes/token =
2*32*32*128*2 = 524,288 B; a 10K-token context stores ~5.2 GB, matching the
paper's number exactly.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
    rope_theta=10_000.0,
    param_partition="dp",
)
