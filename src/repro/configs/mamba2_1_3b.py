"""mamba2-1.3b — Mamba2-1.3B [arXiv:2405.21060; unverified tier].

Attention-free SSD (state-space duality).  d_inner = 2*d_model = 4096,
head_dim 64 => 64 SSD heads, d_state=128, chunk 256, no separate MLP
(d_ff=0): each block is norm + SSD mixer.

For the paper's technique the stored context state is (conv tail, SSD
state) — O(1) in context length — so KV-reuse economics are strictly more
favorable than for attention models (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=32,  # unused (attention-free); kept for API uniformity
    n_kv_heads=32,
    d_ff=0,
    vocab=50280,
    rope_theta=None,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    tie_embeddings=True,
    max_seq_len=1_048_576,
    param_partition="dp",
)
