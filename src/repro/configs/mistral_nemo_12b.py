"""mistral-nemo-12b — Mistral-Nemo-Base-2407 [hf:mistralai/Mistral-Nemo-Base-2407].

128k-context dense GQA model; head_dim=128 is explicit (d_model/n_heads=160
does NOT hold: Nemo decouples head width from d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    param_partition="fsdp",
    remat="dots",
)
