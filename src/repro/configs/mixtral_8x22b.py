"""mixtral-8x22b — Mixtral-8x22B [arXiv:2401.04088; hf].

8 experts top-2; sliding-window attention per the assignment (window 4096,
the Mistral-lineage default) => decode KV is bounded by the window, so the
``long_500k`` cell runs with a ring-buffer cache of 4096 slots and the
stored-context KV for the paper's technique is min(L, 4096) per layer
(DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,  # per-expert FFN width
    vocab=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    max_seq_len=65_536,
    param_partition="fsdp",
    remat="dots",
)
