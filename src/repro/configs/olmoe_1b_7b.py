"""olmoe-1b-7b — OLMoE-1B-7B [arXiv:2409.02060; hf].

64 experts, top-8, MoE on every layer; 1B active / 7B total parameters.
Expert count divides the 16-wide model axis => expert-parallel sharding
(DESIGN.md §7).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert FFN width
    vocab=50304,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=8),
    param_partition="dp",
)
