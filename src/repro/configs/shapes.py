"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns a :class:`CellSpec` describing which step
function the cell lowers (train_step / prefill_step / decode_step) and the
shape-only batch kwargs — the shannon/kernels pattern: weak-type-correct,
shardable, no device allocation (model state specs come from
``jax.eval_shape`` over ``init_state``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec, cell_is_runnable
from repro.models import registry
from repro.models.common import resolve_dtype


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    kind: str  # "train" | "prefill" | "decode"
    batch: Dict[str, Any]  # kwargs of ShapeDtypeStructs (excl. params)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _state_spec(cfg: ArchConfig, batch: int, max_len: int, enc_len=None):
    api = registry.get_model(cfg)
    if cfg.family == "encdec":
        fn = lambda: api.init_state(cfg, batch, max_len, enc_len=enc_len)
    else:
        fn = lambda: api.init_state(cfg, batch, max_len)
    return jax.eval_shape(fn)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> CellSpec:
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        raise ValueError(f"cell {cfg.name} x {shape.name} is skipped: {why}")

    gb, S = shape.global_batch, shape.seq_len
    act = resolve_dtype(cfg.dtype)
    i32 = jnp.int32

    if shape.kind == "train":
        if cfg.family == "encdec":
            dl = cfg.decoder_seq_len
            batch = {
                "frames": _sds((gb, S, cfg.d_model), act),
                "dec_tokens": _sds((gb, dl), i32),
                "labels": _sds((gb, dl), i32),
                "mask": _sds((gb, dl), jnp.float32),
            }
        elif cfg.family == "vlm":
            ft = cfg.frontend_tokens
            batch = {
                "tokens": _sds((gb, S - ft), i32),
                "embeds": _sds((gb, ft, cfg.d_model), act),
                "labels": _sds((gb, S), i32),
                "mask": _sds((gb, S), jnp.float32),
            }
        else:
            batch = {
                "tokens": _sds((gb, S), i32),
                "labels": _sds((gb, S), i32),
                "mask": _sds((gb, S), jnp.float32),
            }
        return CellSpec(cfg.name, shape.name, "train", batch)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            # Context = S audio frames (encoded now, cross-KV written to the
            # state); prefill the full decoder prompt window.
            dl = cfg.decoder_seq_len
            batch = {
                "tokens": _sds((gb, dl), i32),
                "embeds": _sds((gb, S, cfg.d_model), act),
                "state": _state_spec(cfg, gb, dl, enc_len=S),
            }
        elif cfg.family == "vlm":
            ft = cfg.frontend_tokens
            batch = {
                "tokens": _sds((gb, S - ft), i32),
                "embeds": _sds((gb, ft, cfg.d_model), act),
                "state": _state_spec(cfg, gb, S),
            }
        else:
            batch = {
                "tokens": _sds((gb, S), i32),
                "state": _state_spec(cfg, gb, S),
            }
        return CellSpec(cfg.name, shape.name, "prefill", batch)

    # decode: one new token against a cache of S context tokens.
    if cfg.family == "encdec":
        state = _state_spec(cfg, gb, S, enc_len=cfg.encoder_seq_len)
    else:
        state = _state_spec(cfg, gb, S)
    batch = {"tokens": _sds((gb, 1), i32), "state": state}
    return CellSpec(cfg.name, shape.name, "decode", batch)
