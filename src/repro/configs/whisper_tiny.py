"""whisper-tiny — [arXiv:2212.04356; unverified tier].

Encoder-decoder; the conv mel frontend is a STUB per the assignment —
``input_specs()`` provides precomputed frame embeddings.  LayerNorm + GELU,
sinusoidal encoder positions, learned decoder positions, tied embeddings.
Reusable context state = encoder output + decoder cross-attn KV (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # decoder depth
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    rope_theta=None,
    norm_type="layernorm",
    mlp_type="gelu",
    abs_pos_embed=True,
    tie_embeddings=True,
    frontend="audio",
    encoder_seq_len=1500,
    decoder_seq_len=448,
    param_partition="dp",
)
