"""The paper's contribution: cost/delay analytical model for KV-cache reuse,
its validation simulator, and the serving-time reuse policy built on it."""
from repro.core import cost_model, perf_model, policy, pricing, simulator  # noqa: F401
