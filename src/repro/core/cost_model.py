"""The paper's analytical cost/delay model (§2), exactly as published.

Pipelines compared for one context reused ``N`` times over a period ``T``:

  C_text = C_GPU * N * [ T_prefill(L_ctx + L_prompt) + T_decode(L_out) ]

  C_KV   = C_GPU * { N * [ T_decode(L_out) + T_prefill(L_prompt) ]
                     + T_prefill(L_ctx) }                      (compute)
         + C_storage * S_storage(L_ctx) * T                    (storage)
         + C_transmission(S_storage(L_ctx), SLO)               (transmission)

plus the simplified ratio the paper derives:

  C_text / C_KV ≈ 1 + (N-1)/N * T_prefill(L_ctx)
                          / ( T_decode(L_out) + T_prefill(L_prompt) )

Beyond-paper extensions (kept separate, clearly flagged):
  * int8 KV compression factor on S_storage (halves storage+transfer),
  * partial prefix reuse (suffix prefill of the unmatched tail),
  * prefetch overlap in the delay model,
  * O(1) SSM/hybrid stored state (``ArchConfig.fixed_state_bytes``),
  * fused non-prefix chunk reuse (CacheBlend-style): bytes move for all
    matched chunks, compute only for the selected recompute spans
    (``delay_fused`` / ``cost_fused_request``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core.perf_model import PerfModel
from repro.core.pricing import GB, Pricing, StorageTier


# --------------------------------------------------------------------------- #
# Workload description (the paper's parameters)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Workload:
    L_context: int
    L_prompt: int
    L_output: int
    N: int  # requests reusing the same context within the period
    period_hours: float = 1.0  # T
    slo_ttft_s: Optional[float] = None  # SLO for time-to-first-token
    decode_batch: int = 1


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute: float
    storage: float
    transmission: float

    @property
    def total(self) -> float:
        return self.compute + self.storage + self.transmission


@dataclasses.dataclass(frozen=True)
class DelayBreakdown:
    load_s: float  # KV fetch from storage (0 for recompute)
    prefill_s: float
    decode_s: float

    @property
    def ttft_s(self) -> float:
        return self.load_s + self.prefill_s

    @property
    def e2e_s(self) -> float:
        return self.ttft_s + self.decode_s


# --------------------------------------------------------------------------- #
# S_storage — stored context state size
# --------------------------------------------------------------------------- #
def s_storage_bytes(
    cfg: ArchConfig, L_context: int, *, dtype_bytes: int = 2, compression: float = 1.0
) -> float:
    """Bytes of stored context state for ``L_context`` tokens.

    Attention KV scales with min(L, window) per SWA layer; SSM/hybrid archs
    add an L-independent (conv, SSD) state term.  ``compression`` < 1 models
    the int8 tier (beyond-paper)."""
    l_eff = min(L_context, cfg.sliding_window) if cfg.sliding_window else L_context
    per_token = cfg.kv_bytes_per_token(dtype_bytes)
    return (per_token * l_eff + cfg.fixed_state_bytes(dtype_bytes)) * compression


# --------------------------------------------------------------------------- #
# The two pipelines
# --------------------------------------------------------------------------- #
def cost_text(
    cfg: ArchConfig, w: Workload, pricing: Pricing, perf: PerfModel
) -> CostBreakdown:
    """Text-recomputation pipeline cost over the period (paper's C_text)."""
    c_gpu = pricing.compute.cost_per_hour / 3600.0  # $/s
    per_req = perf.t_prefill(cfg, w.L_context + w.L_prompt) + perf.t_decode(
        cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
    )
    return CostBreakdown(compute=c_gpu * w.N * per_req, storage=0.0, transmission=0.0)


def cost_kv(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    tier: Optional[StorageTier] = None,
    compression: float = 1.0,
    reused_fraction: float = 1.0,
) -> CostBreakdown:
    """KV-reuse pipeline cost (paper's C_KV).

    ``reused_fraction`` < 1 models *partial* prefix reuse (beyond-paper): only
    that fraction of the context KV is loaded; the tail is suffix-prefilled.
    """
    tier = tier or pricing.tier()
    c_gpu = pricing.compute.cost_per_hour / 3600.0

    L_reused = int(w.L_context * reused_fraction)
    L_tail = w.L_context - L_reused

    # Compute: one context prefill for the period + per-request prompt(+tail)
    # prefill and decode.
    compute_s = perf.t_prefill(cfg, w.L_context)  # produce the stored KV once
    compute_s += w.N * (
        perf.t_prefill(cfg, w.L_prompt + L_tail)
        + perf.t_decode(cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch)
    )
    compute = c_gpu * compute_s

    # Storage: GB-hours over the period.
    s_bytes = s_storage_bytes(cfg, w.L_context, compression=compression)
    storage = tier.cost_per_gb_hour * (s_bytes / GB) * w.period_hours

    # Transmission: provisioned-bandwidth fee to meet the TTFT SLO + any
    # per-GB transfer fees for N loads (+ 1 store).
    loaded_bytes = s_bytes * reused_fraction
    required_bw = 0.0
    if w.slo_ttft_s:
        required_bw = loaded_bytes / GB / max(w.slo_ttft_s, 1e-9)  # GB/s
    extra_bw = max(0.0, required_bw - tier.read_bw_gbps * perf.hw.hosts)
    transmission = (
        extra_bw * tier.provisioned_bw_cost_per_gbps_hour * w.period_hours
        + tier.per_gb_transfer_fee * (loaded_bytes * w.N + s_bytes) / GB
    )
    return CostBreakdown(compute=compute, storage=storage, transmission=transmission)


def cost_ratio(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    **kv_kwargs,
) -> float:
    """C_text / C_KV — > 1 means KV reuse is more economical."""
    return (
        cost_text(cfg, w, pricing, perf).total
        / cost_kv(cfg, w, pricing, perf, **kv_kwargs).total
    )


def simplified_ratio(cfg: ArchConfig, w: Workload, perf: PerfModel) -> float:
    """The paper's closed-form approximation (§2, Insights)."""
    tp_ctx = perf.t_prefill(cfg, w.L_context)
    denom = perf.t_decode(
        cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
    ) + perf.t_prefill(cfg, w.L_prompt)
    return 1.0 + (w.N - 1) / w.N * tp_ctx / max(denom, 1e-12)


def break_even_reuses(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    tier: Optional[StorageTier] = None,
    compression: float = 1.0,
    max_n: int = 10_000,
) -> Optional[int]:
    """Smallest N with C_KV < C_text (the paper's 'more than once per hour'
    insight); None if reuse never wins within ``max_n``."""
    n = 1
    while n <= max_n:
        wn = dataclasses.replace(w, N=n)
        if cost_kv(cfg, wn, pricing, perf, tier=tier, compression=compression).total < (
            cost_text(cfg, wn, pricing, perf).total
        ):
            return n
        n = n + 1 if n < 16 else int(n * 1.5)
    return None


# --------------------------------------------------------------------------- #
# Fused-prefill pipeline term (CacheBlend-style non-prefix chunk reuse)
# --------------------------------------------------------------------------- #
def delay_fused(
    cfg: ArchConfig,
    w: Workload,
    perf: PerfModel,
    pricing: Pricing,
    *,
    bytes_by_tier: "dict[str, float]",
    n_recompute_ctx: int,
    overlap_load: bool = False,
    queue_wait_s: Optional["dict[str, float]"] = None,
) -> "DelayBreakdown":
    """Per-request delay under fused non-prefix reuse: the matched chunks'
    stored bytes move (possibly from several tiers — fetches issue
    concurrently, so the load term is the slowest tier's, including any
    predicted queueing delay on that tier's contended link), then one fused
    launch recomputes only ``n_recompute_ctx`` context tokens plus the
    prompt while attending the full assembled KV."""
    load = max(
        (
            perf.kv_load_time(b, pricing.tier(t))
            + (queue_wait_s or {}).get(t, 0.0)
            for t, b in bytes_by_tier.items()
            if b > 0
        ),
        default=0.0,
    )
    prefill = perf.t_prefill_fused(
        cfg, w.L_context + w.L_prompt, n_recompute_ctx + w.L_prompt
    )
    if overlap_load:
        load = max(0.0, load - prefill)
    return DelayBreakdown(
        load_s=load,
        prefill_s=prefill,
        decode_s=perf.t_decode(
            cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
        ),
    )


def cost_fused_request(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    bytes_by_tier: "dict[str, float]",
    n_recompute_ctx: int,
) -> float:
    """Marginal $ for one fused-reuse request: compute for only the
    recompute spans (fused launch + decode) plus per-GB transfer fees for
    the bytes fetched for ALL matched chunks."""
    c_gpu = pricing.compute.cost_per_hour / 3600.0
    compute_s = perf.t_prefill_fused(
        cfg, w.L_context + w.L_prompt, n_recompute_ctx + w.L_prompt
    ) + perf.t_decode(
        cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
    )
    cost = c_gpu * compute_s
    for tier_name, nbytes in bytes_by_tier.items():
        cost += pricing.tier(tier_name).per_gb_transfer_fee * nbytes / GB
    return cost


# --------------------------------------------------------------------------- #
# Cluster routing terms: expected TTFT + $ of sending a request to a replica
# --------------------------------------------------------------------------- #
def delay_routed(
    cfg: ArchConfig,
    w: Workload,
    perf: PerfModel,
    pricing: Pricing,
    *,
    matched_tokens: int,
    tier: Optional[str] = None,
    queue_s: float = 0.0,
    compression: float = 1.0,
) -> DelayBreakdown:
    """Expected per-request delay if a router sends this request to a replica
    believed to hold ``matched_tokens`` of its context in ``tier``: the
    replica's current queue/backlog delay, the fetch of the matched bytes,
    and a suffix prefill of the remaining context + prompt.  With
    ``matched_tokens == 0`` (or no tier) this is the full-recompute delay
    behind the same queue — the router's miss branch."""
    matched = min(max(matched_tokens, 0), w.L_context)
    load = 0.0
    if matched > 0 and tier is not None:
        nbytes = s_storage_bytes(cfg, w.L_context, compression=compression)
        load = perf.kv_load_time(
            nbytes * matched / max(w.L_context, 1), pricing.tier(tier)
        )
    prefill = perf.t_prefill(cfg, (w.L_context - matched) + w.L_prompt)
    return DelayBreakdown(
        load_s=queue_s + load,
        prefill_s=prefill,
        decode_s=perf.t_decode(
            cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
        ),
    )


def cost_routed_request(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    matched_tokens: int,
    tier: Optional[str] = None,
    queue_s: float = 0.0,
    compression: float = 1.0,
) -> float:
    """Marginal $ of routing one request to a replica with ``matched_tokens``
    of overlap: GPU time for the suffix prefill + decode PLUS the GPU-idle $
    of the load/queue delay (a routed request occupies its replica while it
    waits) plus per-GB fees on the fetched bytes.  Summing this with the
    delay's TTFT is the AffinityRouter's argmin objective — route to the
    cheapest expected (TTFT + $), not just the largest overlap."""
    d = delay_routed(
        cfg, w, perf, pricing, matched_tokens=matched_tokens, tier=tier,
        queue_s=queue_s, compression=compression,
    )
    c_gpu = pricing.compute.cost_per_hour / 3600.0
    cost = c_gpu * (d.load_s + d.prefill_s + d.decode_s)
    matched = min(max(matched_tokens, 0), w.L_context)
    if matched > 0 and tier is not None:
        nbytes = s_storage_bytes(cfg, w.L_context, compression=compression)
        loaded = nbytes * matched / max(w.L_context, 1)
        cost += pricing.tier(tier).per_gb_transfer_fee * loaded / GB
    return cost


# --------------------------------------------------------------------------- #
# Delay model (end-to-end, per request)
# --------------------------------------------------------------------------- #
def delay_text(cfg: ArchConfig, w: Workload, perf: PerfModel) -> DelayBreakdown:
    return DelayBreakdown(
        load_s=0.0,
        prefill_s=perf.t_prefill(cfg, w.L_context + w.L_prompt),
        decode_s=perf.t_decode(
            cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
        ),
    )


def delay_kv(
    cfg: ArchConfig,
    w: Workload,
    perf: PerfModel,
    *,
    tier: StorageTier,
    compression: float = 1.0,
    reused_fraction: float = 1.0,
    overlap_load: bool = False,
) -> DelayBreakdown:
    """Per-request delay under KV reuse.  ``overlap_load=True`` models the
    beyond-paper prefetch pipeline where the load overlaps queueing/prompt
    prefill (the paper's measured pipeline loads first, then prefills)."""
    s_bytes = s_storage_bytes(cfg, w.L_context, compression=compression)
    load = perf.kv_load_time(s_bytes * reused_fraction, tier)
    L_tail = w.L_context - int(w.L_context * reused_fraction)
    prefill = perf.t_prefill(cfg, w.L_prompt + L_tail)
    if overlap_load:
        # load hidden behind prefill of the prompt; only the excess shows up
        load = max(0.0, load - prefill)
    return DelayBreakdown(
        load_s=load,
        prefill_s=prefill,
        decode_s=perf.t_decode(
            cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
        ),
    )
