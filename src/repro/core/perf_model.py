"""Analytical performance model: T_prefill / T_decode / KV-load times.

The paper treats T_prefill(L) and T_decode(L) as measured black boxes; to make
the cost model predictive for arbitrary (arch, hardware) pairs we derive them
from a two-term roofline:

  t = max( FLOPs / (devices * peak_flops * mfu),
           bytes  / (devices * hbm_bw   * membw_eff) )

Calibration: with ``V100x4`` and Llama-7B this reproduces the paper's own
measured T_prefill(10K) ~= 0.7 s (tests/test_cost_model.py asserts it within
tolerance), so the analytic and the paper's empirical numbers agree before we
extrapolate beyond the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core.pricing import GB, StorageTier


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    devices: int
    peak_flops: float  # per device, FLOP/s at serving dtype
    hbm_bw: float  # per device, bytes/s
    hbm_bytes: float  # per device
    link_bw: float  # per-device interconnect, bytes/s (ICI/NVLink)
    host_read_bw: float = 32 * GB  # PCIe to one host
    hosts: int = 1  # hosts the instance spans (parallel storage mounts)
    mfu: float = 0.40  # achievable fraction of peak in prefill/training
    membw_eff: float = 0.70  # achievable fraction of HBM bandwidth in decode


V100_X4 = HardwareSpec(
    name="V100x4",
    devices=4,
    peak_flops=125e12,  # fp16 tensor core peak
    hbm_bw=900e9,
    hbm_bytes=16 * GB,
    link_bw=150e9,  # NVLink
    hosts=1,
    mfu=0.40,
    membw_eff=0.70,
)

# The paper's measured pipeline: Llama-7B under HuggingFace *naive* model
# parallelism on a p3.8xlarge — layers are spread across the 4 GPUs and run
# sequentially, so throughput ~= one V100 at low utilisation while the whole
# instance is billed.  mfu=0.18 calibrates T_prefill(10K) to the ~7 s implied
# by the paper's footnote 2 ($3/h / 3600 * T = $0.0058 => T ~= 7 s); the
# effective per-instance mfu is 0.18/4 because only one of the 4 billed GPUs
# computes at a time.
V100_X1_PAPER = HardwareSpec(
    name="V100x1-HF",
    devices=1,
    peak_flops=125e12,
    hbm_bw=900e9,
    hbm_bytes=16 * GB,
    link_bw=150e9,
    hosts=1,
    mfu=0.18,
    membw_eff=0.45,
)
V100_X4_HF = HardwareSpec(
    name="V100x4-HF-MP",
    devices=4,
    peak_flops=125e12,
    hbm_bw=900e9,
    hbm_bytes=16 * GB,
    link_bw=150e9,
    hosts=1,
    mfu=0.18 / 4,  # sequential layer placement: 1-of-4 GPUs active
    membw_eff=0.45 / 4,
)

# TPU v5e per the assignment's hardware constants:
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GB HBM.
def tpu_v5e(chips: int, hosts: Optional[int] = None) -> HardwareSpec:
    return HardwareSpec(
        name=f"TPUv5e-{chips}",
        devices=chips,
        peak_flops=197e12,
        hbm_bw=819e9,
        hbm_bytes=16 * GB,
        link_bw=50e9,
        hosts=hosts if hosts is not None else max(1, chips // 8),
        mfu=0.50,
        membw_eff=0.75,
    )


@dataclasses.dataclass(frozen=True)
class PerfModel:
    hw: HardwareSpec

    # ----------------------------------------------------------------- #
    # FLOP / byte accounting
    # ----------------------------------------------------------------- #
    def prefill_flops(self, cfg: ArchConfig, L: int) -> float:
        """2*N_active*L matmul FLOPs + quadratic attention score/value FLOPs
        (windowed for SWA archs)."""
        from repro.models.registry import count_active_params

        n_active = count_active_params(cfg)
        flops = 2.0 * n_active * L
        # attention: 2 * (QK^T + PV) = 4 * H * hd * L * L_att per layer
        if cfg.n_attn_layers:
            l_att = min(L, cfg.sliding_window) if cfg.sliding_window else L
            flops += 4.0 * cfg.n_attn_layers * cfg.n_heads * cfg.resolved_head_dim * L * (
                l_att / 2.0 if l_att == L else l_att
            )
        return flops

    def decode_flops_per_token(self, cfg: ArchConfig, context_len: int) -> float:
        from repro.models.registry import count_active_params

        flops = 2.0 * count_active_params(cfg)
        if cfg.n_attn_layers:
            l_att = (
                min(context_len, cfg.sliding_window)
                if cfg.sliding_window
                else context_len
            )
            flops += 4.0 * cfg.n_attn_layers * cfg.n_heads * cfg.resolved_head_dim * l_att
        return flops

    def decode_bytes_per_token(
        self, cfg: ArchConfig, context_len: int, dtype_bytes: int = 2
    ) -> float:
        """HBM traffic per decoded token: all active params + the KV cache."""
        from repro.models.registry import count_active_params

        param_bytes = count_active_params(cfg) * dtype_bytes
        l_att = (
            min(context_len, cfg.sliding_window) if cfg.sliding_window else context_len
        )
        kv = cfg.kv_bytes_per_token(dtype_bytes) * l_att + cfg.fixed_state_bytes(dtype_bytes)
        return param_bytes + kv

    # ----------------------------------------------------------------- #
    # Times (seconds) — the paper's T_prefill / T_decode
    # ----------------------------------------------------------------- #
    def _prefill_roofline(
        self, cfg: ArchConfig, flops: float, total_tokens: int
    ) -> float:
        """max(comp, mem) for one prefill launch: parameters stream from HBM
        once per launch regardless of how many requests' tokens it carries."""
        hw = self.hw
        comp = flops / (hw.devices * hw.peak_flops * hw.mfu)
        from repro.models.registry import count_active_params

        bytes_ = (
            count_active_params(cfg) * 2 + cfg.kv_bytes_per_token(2) * total_tokens
        )
        mem = bytes_ / (hw.devices * hw.hbm_bw * hw.membw_eff)
        return max(comp, mem)

    def t_prefill(self, cfg: ArchConfig, L: int, batch: int = 1) -> float:
        if L <= 0:
            return 0.0
        return self._prefill_roofline(
            cfg, self.prefill_flops(cfg, L) * batch, L * batch
        )

    def t_prefill_packed(self, cfg: ArchConfig, lens) -> float:
        """One packed ragged prefill over several requests' token runs.

        vs ``sum(t_prefill(L) for L in lens)``: FLOPs are additive (each
        segment still pays its own attention quadratic), but the roofline
        applies ONCE — parameters stream from HBM once for the whole packed
        sequence instead of once per request, and the launch takes
        max(comp, mem) of the totals rather than a sum of per-request maxes.
        Small-segment admission bursts are parameter-read-bound, so this is
        where batched admission's measured throughput win comes from.
        A single segment delegates to ``t_prefill(L)`` — exact equality is a
        contract (admit_batch=1 golden parity), not a numeric coincidence.
        """
        lens = [int(L) for L in lens if L > 0]
        if not lens:
            return 0.0
        if len(lens) == 1:
            return self.t_prefill(cfg, lens[0])
        return self._prefill_roofline(
            cfg, sum(self.prefill_flops(cfg, L) for L in lens), sum(lens)
        )

    def t_prefill_fused(self, cfg: ArchConfig, L_total: int, n_recompute: int) -> float:
        """One fused selective-recompute prefill launch (CacheBlend-style):
        reused chunk KV for ``L_total - n_recompute`` tokens is preloaded and
        only ``n_recompute`` tokens flow through the layer stack, each
        attending the full assembled buffer.

        vs ``t_prefill(L_total)``: matmul FLOPs scale with the recompute
        tokens only, attention FLOPs with ``n_recompute * L_total`` instead
        of the full quadratic, while the memory side is unchanged (parameters
        stream once, the whole assembled KV still moves through HBM) — so a
        small r turns a compute-bound long-context prefill into a
        parameter/KV-read-bound launch.  At ``n_recompute == L_total`` this
        delegates to ``t_prefill`` — exact equality is a contract (the r=1.0
        bit-exactness anchor's pricing analogue), not a numeric coincidence.
        """
        if L_total <= 0 or n_recompute <= 0:
            return 0.0
        n_recompute = min(int(n_recompute), int(L_total))
        if n_recompute == L_total:
            return self.t_prefill(cfg, L_total)
        from repro.models.registry import count_active_params

        flops = 2.0 * count_active_params(cfg) * n_recompute
        if cfg.n_attn_layers:
            l_att = min(L_total, cfg.sliding_window) if cfg.sliding_window else L_total
            flops += 4.0 * cfg.n_attn_layers * cfg.n_heads * cfg.resolved_head_dim * (
                n_recompute * (l_att / 2.0 if l_att == L_total else l_att)
            )
        return self._prefill_roofline(cfg, flops, L_total)

    def t_decode(
        self, cfg: ArchConfig, L_out: int, context_len: int, batch: int = 1
    ) -> float:
        """Total time to emit ``L_out`` tokens (sequential steps; ``batch``
        sequences decoded together amortise the parameter reads)."""
        if L_out <= 0:
            return 0.0
        hw = self.hw
        # per step: params read once for the whole batch, KV per sequence
        from repro.models.registry import count_active_params

        param_bytes = count_active_params(cfg) * 2
        l_att = (
            min(context_len, cfg.sliding_window) if cfg.sliding_window else context_len
        )
        kv_bytes = (
            cfg.kv_bytes_per_token(2) * l_att + cfg.fixed_state_bytes(2)
        ) * batch
        mem = (param_bytes + kv_bytes) / (hw.devices * hw.hbm_bw * hw.membw_eff)
        comp = (
            self.decode_flops_per_token(cfg, context_len)
            * batch
            / (hw.devices * hw.peak_flops * hw.mfu)
        )
        return L_out * max(comp, mem)

    def t_decode_paged(self, cfg: ArchConfig, lens) -> float:
        """One paged batched decode step over slots with live context lengths
        ``lens`` (the block-table layout of ``kernels/paged_decode.py``).

        vs ``t_decode(cfg, 1, max(lens), batch=n)`` — the dense slotted
        cache's pricing, where every slot is billed the longest slot's HBM
        stream: the paged kernel's table gather reads exactly each slot's
        live blocks, so the KV term prices ``sum(lens)`` and the parameter
        read still streams once per step for the whole batch.  Mixed-length
        batches get strictly cheaper; a UNIFORM batch delegates to
        ``t_decode`` — exact equality there is a contract (the dense/paged
        golden replay in tests/test_serving.py), not a numeric coincidence,
        mirroring ``t_prefill_packed``'s single-segment delegation.
        """
        lens = [int(L) for L in lens if L > 0]
        if not lens:
            return 0.0
        if len(set(lens)) == 1:
            return self.t_decode(cfg, 1, lens[0], batch=len(lens))
        hw = self.hw
        from repro.models.registry import count_active_params

        param_bytes = count_active_params(cfg) * 2
        kv_bytes = 0.0
        comp_flops = 0.0
        for L in lens:
            l_att = min(L, cfg.sliding_window) if cfg.sliding_window else L
            kv_bytes += cfg.kv_bytes_per_token(2) * l_att + cfg.fixed_state_bytes(2)
            comp_flops += self.decode_flops_per_token(cfg, L)
        mem = (param_bytes + kv_bytes) / (hw.devices * hw.hbm_bw * hw.membw_eff)
        comp = comp_flops / (hw.devices * hw.peak_flops * hw.mfu)
        return max(comp, mem)

    def decode_kv_bytes(self, cfg: ArchConfig, L: int) -> float:
        """Per-slot HBM bytes one decode step streams for a live context of
        ``L`` tokens — the KV term of ``t_decode_paged``'s sum, exposed so
        the engine can bill each slot of a shared step proportional to its
        own live-block traffic instead of an equal split."""
        l_att = min(L, cfg.sliding_window) if cfg.sliding_window else L
        return cfg.kv_bytes_per_token(2) * l_att + cfg.fixed_state_bytes(2)

    def _chunk_flops(self, cfg: ArchConfig, n_new: int, L_end: int) -> float:
        """FLOPs of one prefill chunk: ``n_new`` tokens at positions
        ``[L_end - n_new, L_end)``, each attending its full causal prefix
        (the token at position p reads p+1 KV rows)."""
        from repro.models.registry import count_active_params

        flops = 2.0 * count_active_params(cfg) * n_new
        if cfg.n_attn_layers:
            rows = n_new * (L_end - n_new) + n_new * (n_new + 1) / 2.0
            flops += (
                4.0 * cfg.n_attn_layers * cfg.n_heads * cfg.resolved_head_dim * rows
            )
        return flops

    def t_step_unified(self, cfg: ArchConfig, decode_lens, chunks) -> float:
        """One unified continuous-batching step: decode rows with live
        context lengths ``decode_lens`` co-scheduled with prefill chunks
        ``chunks`` (each ``(n_new, L_end)``: ``n_new`` tokens ending at total
        length ``L_end``) in a single launch over the block pool
        (``kernels/chunked_prefill.py``).

        FLOPs and KV bytes are additive across rows; parameters stream from
        HBM ONCE for the whole mixed launch — that sharing is why
        interleaving chunks with decode beats running admission and decode
        as separate launches.  With no chunks this delegates to
        ``t_decode_paged`` — exact equality is a contract (the unified
        engine's steady-state decode steps price identically to the legacy
        paged path, the golden-parity anchor), not a numeric coincidence.
        """
        decode_lens = [int(L) for L in decode_lens if L > 0]
        chunks = [(int(n), int(L)) for n, L in chunks if n > 0]
        if not chunks:
            return self.t_decode_paged(cfg, decode_lens)
        hw = self.hw
        from repro.models.registry import count_active_params

        param_bytes = count_active_params(cfg) * 2
        flops = 0.0
        kv_bytes = 0.0
        for L in decode_lens:
            flops += self.decode_flops_per_token(cfg, L)
            kv_bytes += self.decode_kv_bytes(cfg, L)
        for n, L_end in chunks:
            flops += self._chunk_flops(cfg, n, L_end)
            kv_bytes += cfg.kv_bytes_per_token(2) * L_end
        mem = (param_bytes + kv_bytes) / (hw.devices * hw.hbm_bw * hw.membw_eff)
        comp = flops / (hw.devices * hw.peak_flops * hw.mfu)
        return max(comp, mem)

    def step_unified_shares(self, cfg: ArchConfig, decode_lens, chunks):
        """Per-row cost-attribution shares for one unified step: each row's
        normalized standalone launch cost (what it would price alone under
        the same roofline).  Returns ``(decode_shares, chunk_shares)``
        aligned with the inputs; shares sum to 1, so billing
        ``share * step_s`` per row conserves the launch's dollars exactly.
        """
        w_dec = [self.t_decode(cfg, 1, int(L), batch=1) for L in decode_lens]
        hw = self.hw
        from repro.models.registry import count_active_params

        param_bytes = count_active_params(cfg) * 2
        w_chk = []
        for n, L_end in chunks:
            comp = self._chunk_flops(cfg, int(n), int(L_end)) / (
                hw.devices * hw.peak_flops * hw.mfu
            )
            mem = (param_bytes + cfg.kv_bytes_per_token(2) * int(L_end)) / (
                hw.devices * hw.hbm_bw * hw.membw_eff
            )
            w_chk.append(max(comp, mem))
        total = sum(w_dec) + sum(w_chk)
        if total <= 0.0:
            n = max(len(w_dec) + len(w_chk), 1)
            return [1.0 / n] * len(w_dec), [1.0 / n] * len(w_chk)
        return [w / total for w in w_dec], [w / total for w in w_chk]

    # ----------------------------------------------------------------- #
    # KV movement (the paper's transmission delay)
    # ----------------------------------------------------------------- #
    def kv_load_time(self, nbytes: float, tier: StorageTier) -> float:
        """Storage -> host -> device, per-host-parallel mounts (DESIGN.md §3)."""
        storage = nbytes / (tier.read_bw_gbps * GB * self.hw.hosts)
        pcie = nbytes / (self.hw.host_read_bw * self.hw.hosts)
        return tier.latency_s + storage + pcie

    def kv_store_time(self, nbytes: float, tier: StorageTier) -> float:
        storage = nbytes / (tier.write_bw_gbps * GB * self.hw.hosts)
        pcie = nbytes / (self.hw.host_read_bw * self.hw.hosts)
        return tier.latency_s + storage + pcie
