"""Reuse decision policy: recompute vs load-from-tier, per request.

The paper's pipelines are the two extremes (always recompute / always load).
In the serving engine we generalise: for each admitted request the policy
evaluates, via the analytical model, every option available for its context —

  * RECOMPUTE        — full prefill (no stored state / not worth loading),
  * LOAD(tier)       — fetch stored context state, prefill only the prompt,
  * PARTIAL(tier, f) — longest-prefix match covers a fraction f of the
                       context; load that and suffix-prefill the tail,

and picks the cheapest that satisfies the TTFT SLO.  Write-back is decided by
the break-even rule (store iff expected reuses make C_KV < C_text).

This module is the *analytical* layer: pure functions of (arch, workload,
pricing, perf).  The serving-side wrapper that turns a Decision into an
executable per-request ``ReusePlan`` lives in ``repro.serving.planner``
(``CostAwarePlanner`` binds ``decide`` + ``should_store``; planner variants
swap this policy without touching the engine).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core import cost_model
from repro.core.cost_model import Workload
from repro.core.perf_model import PerfModel
from repro.core.pricing import GB, Pricing, StorageTier


@dataclasses.dataclass(frozen=True)
class Decision:
    action: str  # "recompute" | "load" | "partial"
    tier: Optional[str]
    reused_fraction: float
    est_ttft_s: float
    est_cost: float  # marginal $ for this request

    @property
    def loads_kv(self) -> bool:
        return self.action in ("load", "partial")


def _marginal_request_cost(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    tier: Optional[StorageTier],
    reused_fraction: float,
) -> float:
    c_gpu = pricing.compute.cost_per_hour / 3600.0
    L_tail = w.L_context - int(w.L_context * reused_fraction)
    compute_s = perf.t_prefill(cfg, w.L_prompt + L_tail) + perf.t_decode(
        cfg, w.L_output, w.L_context + w.L_prompt, batch=w.decode_batch
    )
    cost = c_gpu * compute_s
    if tier is not None and reused_fraction > 0:
        s_bytes = cost_model.s_storage_bytes(cfg, w.L_context) * reused_fraction
        cost += tier.per_gb_transfer_fee * s_bytes / GB
    return cost


def decide(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    available: Dict[str, float],  # tier name -> matched prefix fraction [0,1]
    compression: float = 1.0,
    # tier name -> predicted queueing delay on that tier's contended link;
    # folded into the tier's TTFT estimate (empty/absent = uncontended).
    queue_wait_s: Optional[Dict[str, float]] = None,
) -> Decision:
    """Choose the cheapest SLO-satisfying plan for one request."""
    options: List[Decision] = []

    d = cost_model.delay_text(cfg, w, perf)
    options.append(
        Decision(
            action="recompute",
            tier=None,
            reused_fraction=0.0,
            est_ttft_s=d.ttft_s,
            est_cost=_marginal_request_cost(
                cfg, w, pricing, perf, tier=None, reused_fraction=0.0
            )
            + pricing.compute.cost_per_hour / 3600.0 * perf.t_prefill(cfg, w.L_context),
        )
    )
    for tier_name, frac in available.items():
        if frac <= 0:
            continue
        tier = pricing.tier(tier_name)
        dk = cost_model.delay_kv(
            cfg, w, perf, tier=tier, compression=compression, reused_fraction=frac
        )
        wait = (queue_wait_s or {}).get(tier_name, 0.0)
        options.append(
            Decision(
                action="load" if frac >= 1.0 else "partial",
                tier=tier_name,
                reused_fraction=frac,
                est_ttft_s=dk.ttft_s + wait,
                est_cost=_marginal_request_cost(
                    cfg, w, pricing, perf, tier=tier, reused_fraction=frac
                ),
            )
        )

    feasible = [
        o for o in options if w.slo_ttft_s is None or o.est_ttft_s <= w.slo_ttft_s
    ]
    pool = feasible or options  # SLO-infeasible workload: degrade to cheapest
    return min(pool, key=lambda o: (o.est_cost, o.est_ttft_s))


def should_store(
    cfg: ArchConfig,
    w: Workload,
    pricing: Pricing,
    perf: PerfModel,
    *,
    expected_reuses: float,
    tier: Optional[StorageTier] = None,
    compression: float = 1.0,
) -> bool:
    """Write-back policy: store the context KV iff the expected reuse count
    clears the analytical break-even."""
    n_star = cost_model.break_even_reuses(
        cfg, w, pricing, perf, tier=tier, compression=compression
    )
    return n_star is not None and expected_reuses >= n_star
