"""Cloud pricing catalogs (the paper's cost-model inputs).

Two catalogs ship by default:
  * ``AWS_PAPER``   — the paper's own setting: V100 GPUs at $3/h (p3 family),
    EBS io2 at $0.125/GB-month with 4 GB/s provisioned throughput [paper §2].
  * ``TPU_V5E``     — the target platform for this framework: v5e chips with
    per-host remote storage (io2-equivalent pricing) — used by the serving
    engine and the beyond-paper analyses.

All prices are USD; times are hours unless suffixed ``_s``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

HOURS_PER_MONTH = 730.0
# Cloud pricing uses decimal GB (the paper: a 10K-token Llama-7B context =
# 2*32*32*128*10240*2 B = 5.24e9 B, quoted as "5.2 GB").
GB = 1e9


@dataclasses.dataclass(frozen=True)
class StorageTier:
    """A storage service a KV cache can live in."""

    name: str
    cost_per_gb_month: float
    read_bw_gbps: float  # sustained GB/s available to one reader
    write_bw_gbps: float
    latency_s: float  # first-byte latency
    # Fee to provision extra throughput above the baseline (the paper's
    # C_transmission knob); $/ (GB/s) / hour.  0 for locally mounted EBS at
    # the paper's (infrequent) IO rates.
    provisioned_bw_cost_per_gbps_hour: float = 0.0
    per_gb_transfer_fee: float = 0.0  # e.g. S3 egress-like fees

    @property
    def cost_per_gb_hour(self) -> float:
        return self.cost_per_gb_month / HOURS_PER_MONTH


@dataclasses.dataclass(frozen=True)
class ComputePrice:
    name: str
    cost_per_device_hour: float
    devices: int  # devices in the serving instance

    @property
    def cost_per_hour(self) -> float:
        return self.cost_per_device_hour * self.devices


@dataclasses.dataclass(frozen=True)
class Pricing:
    compute: ComputePrice
    tiers: Dict[str, StorageTier]
    default_tier: str = "io2"

    def tier(self, name: Optional[str] = None) -> StorageTier:
        return self.tiers[name or self.default_tier]


# --------------------------------------------------------------------------- #
# The paper's catalog (AWS, 2024 pricing as cited)
# --------------------------------------------------------------------------- #
IO2 = StorageTier(
    name="io2",
    cost_per_gb_month=0.125,  # [Amazon EBS pricing, paper ref 1]
    read_bw_gbps=4.0,  # io2 Block Express, highest tier (paper §2)
    write_bw_gbps=4.0,
    latency_s=0.001,
)
GP3 = StorageTier(
    name="gp3",
    cost_per_gb_month=0.08,
    read_bw_gbps=1.0,
    write_bw_gbps=1.0,
    latency_s=0.002,
    provisioned_bw_cost_per_gbps_hour=0.040 / HOURS_PER_MONTH * 1024,  # $0.040/MBps-month
)
S3_STANDARD = StorageTier(
    name="s3",
    cost_per_gb_month=0.023,
    read_bw_gbps=0.78,  # ~100 Gbit instance NIC shared, conservative single-stream
    write_bw_gbps=0.78,
    latency_s=0.05,
    per_gb_transfer_fee=0.0,  # same-region
)
HOST_DRAM = StorageTier(
    # Host memory of the serving instance itself: priced as the marginal
    # DRAM cost share; effectively PCIe-bandwidth "storage" (beyond-paper tier).
    name="host_dram",
    cost_per_gb_month=2.0,
    read_bw_gbps=32.0,  # PCIe gen4 x16 effective
    write_bw_gbps=32.0,
    latency_s=1e-5,
)
LOCAL_NVME = StorageTier(
    # Instance-store NVMe (i4i-class): bundled with the instance, priced at
    # the marginal $/GB share of the instance-store premium.  The hierarchy's
    # spill tier between host DRAM and provisioned cloud block storage.
    name="local_nvme",
    cost_per_gb_month=0.054,
    read_bw_gbps=7.0,
    write_bw_gbps=5.0,
    latency_s=1e-4,
)
PEER_DRAM = StorageTier(
    # DRAM of a peer serving instance reached over the datacenter network
    # (the "Can I Buy Your KV Cache?" setting): DRAM-priced capacity behind a
    # 100 GbE NIC; RpcBackend adds per-call RPC round trips on top.
    name="peer_dram",
    cost_per_gb_month=2.0,
    read_bw_gbps=12.5,
    write_bw_gbps=12.5,
    latency_s=2e-4,
)

_ALL_TIERS = {
    "io2": IO2, "gp3": GP3, "s3": S3_STANDARD, "host_dram": HOST_DRAM,
    "local_nvme": LOCAL_NVME, "peer_dram": PEER_DRAM,
}

AWS_PAPER = Pricing(
    compute=ComputePrice(name="V100(p3.8xlarge)", cost_per_device_hour=3.0, devices=4),
    tiers=dict(_ALL_TIERS),
    default_tier="io2",
)

# --------------------------------------------------------------------------- #
# TPU v5e catalog (target platform; DESIGN.md §3)
# --------------------------------------------------------------------------- #
TPU_V5E = Pricing(
    compute=ComputePrice(name="TPUv5e-8", cost_per_device_hour=1.20, devices=8),
    tiers=dict(_ALL_TIERS),
    default_tier="io2",
)


def tpu_v5e_pod(chips: int) -> Pricing:
    return Pricing(
        compute=ComputePrice(
            name=f"TPUv5e-{chips}", cost_per_device_hour=1.20, devices=chips
        ),
        tiers=dict(AWS_PAPER.tiers),
        default_tier="io2",
    )
