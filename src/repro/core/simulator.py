"""Discrete-event simulator for context-augmented LLM serving.

Validates the analytical model the way the paper does (§3 "we validate this
result by simulation under various workloads"): a GPU/TPU instance serves a
trace of requests that share contexts (TriviaQA-like: 200 contexts, each
reused ~5x); we simulate both pipelines and report end-to-end delay and cloud
cost — reproducing Fig 2(a)/(b).

The simulator is intentionally first-principles: a heapq event loop, a FIFO
compute resource, a bandwidth-limited storage link, and the PerfModel for
service times — no closed-form shortcuts from cost_model.py, so agreement
between the two is a real validation.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost_model import s_storage_bytes
from repro.core.perf_model import PerfModel
from repro.core.pricing import GB, Pricing, StorageTier


@dataclasses.dataclass(frozen=True)
class SimRequest:
    arrival_s: float
    context_id: int
    L_context: int
    L_prompt: int
    L_output: int


@dataclasses.dataclass
class RequestResult:
    arrival_s: float
    start_s: float
    load_s: float
    prefill_s: float
    decode_s: float
    finish_s: float
    reused: bool

    @property
    def queue_s(self) -> float:
        return self.start_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        return self.start_s + self.load_s + self.prefill_s - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class SimResult:
    results: List[RequestResult]
    gpu_busy_s: float
    storage_gb_hours: float
    transferred_bytes: float
    horizon_s: float

    def cost(self, pricing: Pricing, tier: StorageTier) -> float:
        c = pricing.compute.cost_per_hour / 3600.0 * self.gpu_busy_s
        c += tier.cost_per_gb_hour * self.storage_gb_hours
        c += tier.per_gb_transfer_fee * self.transferred_bytes / GB
        return c

    @property
    def mean_ttft_s(self) -> float:
        return float(np.mean([r.ttft_s for r in self.results]))

    @property
    def mean_e2e_s(self) -> float:
        return float(np.mean([r.e2e_s for r in self.results]))

    @property
    def p99_e2e_s(self) -> float:
        return float(np.percentile([r.e2e_s for r in self.results], 99))


# --------------------------------------------------------------------------- #
# Trace generation (TriviaQA-like context sharing, the paper's workload)
# --------------------------------------------------------------------------- #
def make_trace(
    *,
    n_contexts: int = 200,
    reuses_per_context: int = 5,
    L_context: int = 10_000,
    L_prompt: int = 32,
    L_output: int = 32,
    arrival_rate_per_s: float = 1.0,
    seed: int = 0,
    shuffle: bool = True,
) -> List[SimRequest]:
    rng = np.random.default_rng(seed)
    ids = np.repeat(np.arange(n_contexts), reuses_per_context)
    if shuffle:
        rng.shuffle(ids)
    gaps = rng.exponential(1.0 / arrival_rate_per_s, size=len(ids))
    arrivals = np.cumsum(gaps)
    return [
        SimRequest(float(t), int(cid), L_context, L_prompt, L_output)
        for t, cid in zip(arrivals, ids)
    ]


# --------------------------------------------------------------------------- #
# Simulation
# --------------------------------------------------------------------------- #
def simulate(
    cfg: ArchConfig,
    trace: List[SimRequest],
    perf: PerfModel,
    *,
    reuse_kv: bool,
    tier: StorageTier,
    compression: float = 1.0,
    overlap_load: bool = False,
    host_cache_gb: float = 0.0,
) -> SimResult:
    """Run one pipeline over the trace.

    reuse_kv=False — the text-recomputation pipeline.
    reuse_kv=True  — store each context's KV on first use, load thereafter.
    ``host_cache_gb`` > 0 adds a beyond-paper host-DRAM LRU cache in front of
    the storage tier (hits load at PCIe speed)."""
    # context_id -> (store time, stored bytes); bytes recorded at store time
    # so wrap-up GB-hour accounting is O(contexts), not O(contexts x trace).
    stored_at: Dict[int, Tuple[float, float]] = {}
    host_cache: Dict[int, float] = {}  # context_id -> last-use (LRU)
    host_cache_bytes = 0.0

    gpu_free = 0.0
    gpu_busy = 0.0
    transferred = 0.0
    results: List[RequestResult] = []

    for req in sorted(trace, key=lambda r: r.arrival_s):
        s_bytes = s_storage_bytes(cfg, req.L_context, compression=compression)
        start = max(req.arrival_s, gpu_free)
        load_s = 0.0
        reused = False

        if not reuse_kv:
            prefill_s = perf.t_prefill(cfg, req.L_context + req.L_prompt)
        elif req.context_id not in stored_at:
            # first use: full prefill, then store (async write; charged to
            # the link, not the GPU).
            prefill_s = perf.t_prefill(cfg, req.L_context + req.L_prompt)
            stored_at[req.context_id] = (start + prefill_s, s_bytes)
            transferred += s_bytes
        else:
            reused = True
            from_host = req.context_id in host_cache
            if from_host:
                load_s = s_bytes / (perf.hw.host_read_bw * perf.hw.hosts)
            else:
                load_s = perf.kv_load_time(s_bytes, tier)
                transferred += s_bytes
            prefill_s = perf.t_prefill(cfg, req.L_prompt)
            if overlap_load:
                load_s = max(0.0, load_s - prefill_s)

        # host-cache admission (LRU by bytes; beyond-paper tier)
        if reuse_kv and host_cache_gb > 0:
            host_cache[req.context_id] = start
            while len(host_cache) * s_bytes > host_cache_gb * GB and len(host_cache) > 1:
                victim = min(host_cache, key=host_cache.get)
                if victim == req.context_id:
                    break
                del host_cache[victim]

        decode_s = perf.t_decode(cfg, req.L_output, req.L_context + req.L_prompt)
        service = load_s + prefill_s + decode_s
        finish = start + service
        gpu_free = finish
        # GPU-$ accounting follows the paper's C_KV: only compute seconds are
        # GPU cost; the load contributes to *delay* and is priced as
        # storage/transmission.  (An idle-while-loading reservation surcharge
        # would be a beyond-paper refinement; see EXPERIMENTS.md.)
        gpu_busy += prefill_s + decode_s
        results.append(
            RequestResult(
                arrival_s=req.arrival_s,
                start_s=start,
                load_s=load_s,
                prefill_s=prefill_s,
                decode_s=decode_s,
                finish_s=finish,
                reused=reused,
            )
        )

    horizon = max((r.finish_s for r in results), default=0.0)
    storage_gb_hours = sum(
        (horizon - t0) / 3600.0 * nbytes / GB for t0, nbytes in stored_at.values()
    )
    return SimResult(
        results=results,
        gpu_busy_s=gpu_busy,
        storage_gb_hours=storage_gb_hours,
        transferred_bytes=transferred,
        horizon_s=horizon,
    )


def compare_pipelines(
    cfg: ArchConfig,
    trace: List[SimRequest],
    perf: PerfModel,
    pricing: Pricing,
    *,
    tier: Optional[StorageTier] = None,
    compression: float = 1.0,
    overlap_load: bool = False,
) -> Dict[str, float]:
    """Run both pipelines; return the paper's headline metrics."""
    tier = tier or pricing.tier()
    text = simulate(cfg, trace, perf, reuse_kv=False, tier=tier)
    kv = simulate(
        cfg, trace, perf, reuse_kv=True, tier=tier, compression=compression,
        overlap_load=overlap_load,
    )
    return {
        "text_cost": text.cost(pricing, tier),
        "kv_cost": kv.cost(pricing, tier),
        "cost_saving_x": text.cost(pricing, tier) / kv.cost(pricing, tier),
        "text_e2e_s": text.mean_e2e_s,
        "kv_e2e_s": kv.mean_e2e_s,
        "delay_saving_x": text.mean_e2e_s / kv.mean_e2e_s,
        "text_ttft_s": text.mean_ttft_s,
        "kv_ttft_s": kv.mean_ttft_s,
    }
