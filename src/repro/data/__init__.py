"""Synthetic data + context-sharing serving workloads."""
