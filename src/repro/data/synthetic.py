"""Synthetic data: token streams for training + context-sharing serving
workloads (the paper's TriviaQA-like pattern: many requests share long
contexts)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.request import Request


def token_batches(
    cfg: ArchConfig, *, batch: int, seq_len: int, seed: int = 0
) -> Iterator[dict]:
    """Infinite stream of LM training batches with a learnable structure
    (a noisy modular-bigram language, so loss demonstrably falls)."""
    rng = np.random.default_rng(seed)
    v = cfg.vocab
    while True:
        start = rng.integers(0, v, size=(batch, 1))
        steps = rng.integers(1, 7, size=(batch, 1))
        pos = np.arange(seq_len + 1)[None, :]
        seq = (start + steps * pos) % v
        noise = rng.random((batch, seq_len + 1)) < 0.05
        seq = np.where(noise, rng.integers(0, v, size=seq.shape), seq)
        yield {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
            "mask": np.ones((batch, seq_len), np.float32),
        }


@dataclasses.dataclass
class WorkloadSpec:
    """The paper's evaluation workload (§3): n_contexts contexts, each reused
    ~reuses times, with Poisson arrivals."""

    n_contexts: int = 200
    reuses_per_context: int = 5
    context_len: int = 10_000
    prompt_len: int = 32
    output_len: int = 32
    arrival_rate_per_s: float = 1.0
    seed: int = 0


def serving_workload(
    cfg: ArchConfig, spec: WorkloadSpec, *, vocab: Optional[int] = None
) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    v = vocab or cfg.vocab
    contexts = [
        list(map(int, rng.integers(0, v, spec.context_len)))
        for _ in range(spec.n_contexts)
    ]
    order = np.repeat(np.arange(spec.n_contexts), spec.reuses_per_context)
    rng.shuffle(order)
    arrivals = np.cumsum(rng.exponential(1.0 / spec.arrival_rate_per_s, len(order)))
    reqs = []
    for i, (cid, t) in enumerate(zip(order, arrivals)):
        reqs.append(
            Request(
                req_id=i,
                context_tokens=contexts[cid],
                prompt_tokens=list(map(int, rng.integers(0, v, spec.prompt_len))),
                max_new_tokens=spec.output_len,
                arrival_s=float(t),
                expected_reuses=spec.reuses_per_context,
            )
        )
    return reqs
