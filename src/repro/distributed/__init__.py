"""Mesh/sharding rules and collective helpers (DP/TP/EP/ZeRO/FSDP)."""
