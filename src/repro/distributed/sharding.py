"""Sharding rules: parameter + context-state PartitionSpecs per architecture.

Mesh axes (DESIGN.md §7):
  * ``pod``   — pure data parallel across pods (gradients cross DCI once).
  * ``data``  — data parallel; additionally FSDP (param/optimizer sharding)
                for ``param_partition == "fsdp"`` archs.
  * ``model`` — tensor parallel: attention heads / FFN width / experts /
                SSD heads / vocab.

Every rule is divisibility-guarded: a dim shards only if the axis size
divides it (e.g. MQA's single KV head replicates; qwen2's 12 Q heads don't
split 16 ways so the head_dim shards instead).
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import blocks, lm
from repro.models.attention import KVCache
from repro.models.blocks import BlockCache
from repro.models.encdec import EncDecState
from repro.models.lm import LMState
from repro.models.ssm import MambaState

POD, DATA, MODEL = "pod", "data", "model"

# Attention sharding strategy when head counts don't divide the model axis
# (§Perf hillclimb A, EXPERIMENTS.md):
#   "hd"        — BASELINE: fall back to sharding head_dim (partial-sum
#                 contractions => per-layer all-reduces/resharding).
#   "replicate" — OPTIMIZED: replicate the indivisible projection (classic
#                 GQA TP: KV heads replicated when kv < tp; whole attention
#                 replicated when H < tp) — removes the attention-induced
#                 collectives at a small redundant-compute/memory cost.
def attn_fallback() -> str:
    return os.environ.get("REPRO_ATTN_SHARDING", "hd")


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


# --------------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------------- #
def param_specs(cfg: ArchConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree parallel to ``params`` (works on real arrays or
    ShapeDtypeStructs)."""
    m = axis_size(mesh, MODEL)
    d = axis_size(mesh, DATA)
    fsdp = cfg.param_partition == "fsdp"

    def fs(dim: int) -> Optional[str]:
        return DATA if (fsdp and _div(dim, d)) else None

    def md(dim: int) -> Optional[str]:
        return MODEL if _div(dim, m) else None

    def leaf_spec(path, x) -> P:
        names = [
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in path
        ]
        name = names[-1] if names else ""
        stacked = any(n in ("layers", "encoder", "decoder") for n in names)
        shape = tuple(x.shape)
        if stacked:
            shape = shape[1:]  # leading scan (layer/period) dim — never sharded

        def out(*spec):
            spec = list(spec) + [None] * (len(shape) - len(spec))
            if stacked:
                spec = [None] + spec
            return P(*spec)

        # ---- embeddings ------------------------------------------------ #
        # vocab-sharded only: model-axis sharding already leaves ~67 MB/dev
        # at 65k x 8192; adding FSDP on d_model (the matmul contraction dim)
        # costs a 17 GB logits all-reduce per step (EXPERIMENTS.md §Perf).
        if name == "table":
            return out(md(shape[0]), None)
        if name == "head":
            return out(None, md(shape[1]))
        if name == "dec_pos":
            return out(None, fs(shape[1]))
        # ---- attention --------------------------------------------------#
        replicate_odd = attn_fallback() == "replicate"

        def head_fb(hd):
            return None if replicate_odd else md(hd)

        if name in ("wq",):
            h, hd = shape[1], shape[2]
            return out(fs(shape[0]), md(h), None if _div(h, m) else head_fb(hd))
        if name in ("wk", "wv"):
            kv, hd = shape[1], shape[2]
            return out(fs(shape[0]), md(kv), None if _div(kv, m) else head_fb(hd))
        if name == "wo":
            h, hd = shape[0], shape[1]
            return out(md(h), None if _div(h, m) else head_fb(hd), fs(shape[2]))
        if name == "bq":
            h, hd = shape
            return out(md(h), None if _div(h, m) else head_fb(hd))
        if name in ("bk", "bv"):
            kv, hd = shape
            return out(md(kv), None if _div(kv, m) else head_fb(hd))
        # ---- MoE --------------------------------------------------------#
        if name == "router":
            return out(fs(shape[0]), None)
        # Expert weights: FSDP goes on the OUTPUT dim, never the contraction
        # dim — fsdp-on-contraction makes XLA partial-sum every expert matmul
        # into a 32 GB f32 all-reduce over the data axis (jamba train_4k;
        # EXPERIMENTS.md §Perf hillclimb C).
        if name in ("w_gate", "w_up") and len(shape) == 3:  # [E, D, F]
            e = shape[0]
            return out(md(e), None, fs(shape[2]) if _div(e, m) else md(shape[2]))
        if name == "w_down" and len(shape) == 3:  # [E, F, D]
            e = shape[0]
            return out(md(e), None if _div(e, m) else md(shape[1]), fs(shape[2]))
        # ---- dense MLP ---------------------------------------------------#
        if name in ("w_gate", "w_up", "w1"):
            return out(fs(shape[0]), md(shape[1]))
        if name in ("w_down", "w2"):
            return out(md(shape[0]), fs(shape[1]))
        if name == "b1":
            return out(md(shape[0]))
        # ---- Mamba/SSD ----------------------------------------------------#
        if name in ("in_proj", "in_proj_z", "in_proj_x", "in_proj_dt"):
            return out(fs(shape[0]), md(shape[1]))
        if name == "out_proj":
            return out(md(shape[0]), fs(shape[1]))
        if name == "conv_w":
            return out(None, md(shape[1]))
        if name in ("conv_b", "norm_w"):
            return out(md(shape[0]))
        if name in ("A_log", "D_skip", "dt_bias"):
            return out(md(shape[0]))
        # ---- norms / everything else: replicated ------------------------ #
        return out()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# --------------------------------------------------------------------------- #
# Context-state specs (mirrors models.lm.init_state structure exactly)
# --------------------------------------------------------------------------- #
def state_specs(cfg: ArchConfig, batch: int, mesh: Mesh) -> Any:
    m = axis_size(mesh, MODEL)
    baxes = batch_axes(mesh)
    bsize = int(np.prod([axis_size(mesh, a) for a in baxes])) if baxes else 1
    b = baxes if (baxes and _div(batch, bsize)) else None

    def md(dim: int) -> Optional[str]:
        return MODEL if _div(dim, m) else None

    if cfg.family == "encdec":
        kv = cfg.n_kv_heads
        kv_spec = KVCache(
            P(None, b, None, md(kv), None), P(None, b, None, md(kv), None)
        )
        return EncDecState(pos=P(b), self_kv=kv_spec, cross_kv=kv_spec)

    kinds, _ = lm._layout(cfg)

    def per_kind(kind: blocks.BlockKind) -> BlockCache:
        if kind.mixer == "a":
            kv = cfg.n_kv_heads
            hd = cfg.resolved_head_dim
            # cache fallback is a separate knob from the weight fallback: a
            # replicated KV cache can exceed HBM for long-context decode, so
            # "hd" stays the default even under REPRO_ATTN_SHARDING=replicate.
            if os.environ.get("REPRO_ATTN_KV_SHARD") == "1":
                # length-sharded cache matching the shard_map flash attention
                # (kernels/ops.py _kv_sharded_attention)
                spec = P(None, b, MODEL, None, None)
                return BlockCache(KVCache(spec, spec), None)
            cache_fb = os.environ.get("REPRO_KV_CACHE_SHARDING", "hd")
            tail = None if cache_fb == "replicate" else md(hd)
            spec = P(None, b, None, md(kv), None if _div(kv, m) else tail)
            return BlockCache(KVCache(spec, spec), None)
        s = cfg.ssm
        d_in = s.d_inner(cfg.d_model)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        h = s.n_ssm_heads(cfg.d_model)
        return BlockCache(
            None,
            MambaState(
                conv=P(None, b, None, md(conv_dim)),
                ssd=P(None, b, md(h), None, None),
            ),
        )

    return LMState(pos=P(b), caches=tuple(per_kind(k) for k in kinds))


# --------------------------------------------------------------------------- #
# Batch (token/embed/label) specs
# --------------------------------------------------------------------------- #
def data_specs(cfg: ArchConfig, batch_kwargs: Any, batch: int, mesh: Mesh) -> Any:
    baxes = batch_axes(mesh)
    bsize = int(np.prod([axis_size(mesh, a) for a in baxes])) if baxes else 1
    b = baxes if (baxes and _div(batch, bsize)) else None

    out = {}
    for k, v in batch_kwargs.items():
        if k == "state":
            out[k] = state_specs(cfg, batch, mesh)
        else:
            out[k] = P(b, *([None] * (len(v.shape) - 1)))
    return out


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
