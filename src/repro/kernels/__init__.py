"""Pallas TPU kernels (+ jnp oracles) for the serving hot paths."""
