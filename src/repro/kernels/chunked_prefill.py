"""Chunked-prefill Pallas kernel: multi-token rows over the shared KV block
pool — the unified continuous-batching launch.

This is ``paged_decode`` generalised from one query per sequence to a chunk
of up to ``C`` new tokens per sequence, all attending through the same
block-table indirection.  One launch therefore serves a MIXED batch: decode
rows (1 valid token at the live length), prefill-chunk rows (up to ``C``
block-aligned new tokens whose K/V the caller has already scattered into the
pool), and idle rows (all padding).  That mix is what lets the serving
engine interleave long suffix-prefills with in-flight decode instead of
stalling decode behind admission (Sarathi-style chunked prefill).

Grid (B, KV, nb) exactly as in ``paged_decode``: the block table rides in as
a scalar-prefetch operand so the k/v BlockSpec index maps DMA pool block
``table[b, j]`` directly, and the G grouped query heads of a KV head are
processed together.  The flash running softmax in VMEM scratch simply gains
a leading chunk axis ([C, G] stats, [C, G, hd] accumulator).  Validity is
purely positional per query: row ``r`` of table entry ``j`` holds sequence
position ``j*block + r``, so ``pos <= q_pos[c]`` covers causality within the
chunk, the boundary block's tail, AND 0-padded table entries (dump-block
positions exceed every valid query); padding queries (``q_pos`` = -2^30)
mask every key and emit zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_prefill import _scratch

NEG_INF = -1e30


def supported(q, k_pool, v_pool, block: int) -> bool:
    B, C, H, hd = q.shape
    KV = k_pool.shape[1]
    return (
        C >= 1
        and C <= block
        and H % KV == 0
        and hd <= 256
        and k_pool.shape[0] % block == 0
        and q.dtype in (jnp.float32, jnp.bfloat16)
    )


def _kernel(
    tbl_ref,  # scalar-prefetch: [B, nb] int32
    q_ref, k_ref, v_ref, qp_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch
    *, nb: int, block: int, chunk: int, window: Optional[int], scale: float,
):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    C, G, hd = chunk, q_ref.shape[3], q_ref.shape[4]
    qg = q_ref[0, 0].astype(jnp.float32).reshape(C * G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0].astype(jnp.int32)  # [C]

    s = jax.lax.dot_general(
        qg, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(C, G, block) * scale

    # sequence position of each row of this table entry (by construction)
    kp = ib * block + jax.lax.broadcasted_iota(jnp.int32, (C, block), 1)
    mask = kp <= qp[:, None]  # [C, block]
    if window is not None:
        mask &= kp > qp[:, None] - window
    s = jnp.where(mask[:, None, :], s, NEG_INF)

    m_prev = m_ref[...]  # [C, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask[:, None, :], jnp.exp(s - m_new[..., None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        p.reshape(C * G, block), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(C, G, hd)
    m_ref[...] = m_new

    @pl.when(ib == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "window", "interpret")
)
def chunked_prefill_attention(
    q: jax.Array,  # [B, C, H, hd]
    k_pool: jax.Array,  # [N_rows, KV, hd] (N_rows = n_blocks * block)
    v_pool: jax.Array,
    *,
    block_table: jax.Array,  # [B, nb] int32
    q_pos: jax.Array,  # [B, C] (-2^30 padding)
    block: int = 128,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    B, C, H, hd = q.shape
    KV = k_pool.shape[1]
    G = H // KV
    nb = block_table.shape[1]

    kb = k_pool.reshape(-1, block, KV, hd)  # [n_blocks, block, KV, hd]
    vb = v_pool.reshape(-1, block, KV, hd)
    # [B, C, H, hd] -> [B, KV, C, G, hd]: one grid step covers a KV head
    # group across the whole chunk.
    qg = q.reshape(B, C, KV, G, hd).transpose(0, 2, 1, 3, 4)
    tbl = block_table.astype(jnp.int32)

    kernel = functools.partial(
        _kernel, nb=nb, block=block, chunk=C, window=window,
        scale=1.0 / (hd**0.5),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, C, G, hd), lambda b, h, ib, t: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, block, 1, hd), lambda b, h, ib, t: (t[b, ib], 0, h, 0)),
            pl.BlockSpec((1, block, 1, hd), lambda b, h, ib, t: (t[b, ib], 0, h, 0)),
            pl.BlockSpec((1, C), lambda b, h, ib, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C, G, hd), lambda b, h, ib, t: (b, h, 0, 0, 0)),
        scratch_shapes=[
            _scratch((C, G), jnp.float32),
            _scratch((C, G), jnp.float32),
            _scratch((C, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, C, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, qg, kb, vb, q_pos)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, hd)
