"""Decode attention Pallas kernel (one query token per sequence).

Memory-bound by design: each step streams the sequence's KV cache once
(the roofline term the serving engine lives on).  Grid (B, KV, nKV) with the
G grouped query heads of each KV head processed together so the cache is
read exactly once; flash-style running softmax across kv blocks in VMEM
scratch.

Ring-buffer (SWA) caches work unchanged: slot validity and window masking
are position-based (kv_pos carries the absolute position per slot, -1 for
never-written).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_prefill import _scratch

NEG_INF = -1e30


def supported(q, k, v) -> bool:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    return Sq == 1 and H % KV == 0 and hd <= 256


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref, valid_ref,
    o_ref,
    m_ref, l_ref, acc_ref,
    *, window: Optional[int], n_kv: int, scale: float, use_valid: bool,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qg = q_ref[0, 0, :, :].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bkv, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, 0].astype(jnp.int32)  # scalar
    kp = kp_ref[0, :].astype(jnp.int32)  # [bkv]

    s = jax.lax.dot_general(
        qg, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, bkv]

    mask = (kp >= 0) & (kp <= qp)
    if window is not None:
        mask &= kp > qp - window
    if use_valid:
        mask &= valid_ref[0, :]
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret", "block_kv")
)
def decode_attention(
    q: jax.Array,  # [B, 1, H, hd]
    k: jax.Array,  # [B, L, KV, hd]
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, 1]
    kv_pos: jax.Array,  # [B, L]
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,  # [B, L] bool
    interpret: bool = False,
    block_kv: int = 128,
) -> jax.Array:
    B, _, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    G = H // KV

    bkv = min(block_kv, max(L, 8))
    pad = (-L) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
    Lp = L + pad
    n_kv = Lp // bkv
    use_valid = kv_valid is not None
    if kv_valid is None:
        kv_valid = jnp.ones((B, Lp), jnp.bool_)

    # [B, 1, H, hd] -> [B, KV, G, hd] so one grid step covers a KV group.
    qg = q[:, 0].reshape(B, KV, G, hd)

    kernel = functools.partial(
        _kernel, window=window, n_kv=n_kv, scale=1.0 / (hd**0.5), use_valid=use_valid
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0)),
            pl.BlockSpec((1, bkv), lambda b, h, ik: (b, ik)),
            pl.BlockSpec((1, bkv), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            _scratch((G,), jnp.float32),
            _scratch((G,), jnp.float32),
            _scratch((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, q_pos, kv_pos, kv_valid)
    return out.reshape(B, 1, H, hd)
