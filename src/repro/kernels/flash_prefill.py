"""Flash attention Pallas kernel for (suffix-)prefill.

The paper's hot path: with KV reuse, prefill runs the *new* tokens' queries
against [stored-prefix KV ++ new KV].  This kernel implements the
generalised position-masked attention of ``ref.attention_ref`` (causal
offsets via q_pos/kv_pos, sliding windows, invalid slots as kv_pos < 0) in
the canonical TPU flash pattern:

  grid = (B, H, nQ, nKV), kv innermost (sequential on TPU);
  running (m, l, acc) in VMEM scratch; output block revisited across the kv
  axis and finalised on the last kv step.

BlockSpec tiling keeps the working set in VMEM:
  q/out (1, bq, 1, hd) + k/v (1, bkv, 1, hd) + scores (bq, bkv) f32
  = bq*hd*(2+4) + 2*bkv*hd*2 + 4*bq*bkv  bytes
  ~= 128*128*6 + 2*128*128*2 + 4*128*128 ~= 0.23 MB  (bq=bkv=128, hd=128)
MXU alignment: bq, bkv multiples of 128; hd is the lane dim (pad to 128 on
real TPU for hd<128 heads — interpret mode is exact for any hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def supported(q, k, v, window: Optional[int] = None) -> bool:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    return H % KV == 0 and hd <= 256 and q.dtype in (jnp.float32, jnp.bfloat16)


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch
    *, causal: bool, window: Optional[int], n_kv: int, scale: float,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bkv, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, :].astype(jnp.int32)  # [bq]
    kp = kp_ref[0, :].astype(jnp.int32)  # [bkv]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bkv]

    mask = (kp >= 0)[None, :]
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "interpret", "block_q", "block_kv"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, Sq]
    kv_pos: jax.Array,  # [B, Skv]
    causal: bool = True,
    window: Optional[int] = None,
    interpret: bool = False,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV

    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        # padded queries mask everything out; final rows are dropped below
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-(2**30))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    n_q, n_kv = Sq_p // bq, Skv_p // bkv

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, n_kv=n_kv, scale=1.0 / (hd**0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bkv), lambda b, h, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq,), jnp.float32),
            _scratch((bq,), jnp.float32),
            _scratch((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
    return out[:, :Sq]


def _scratch(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY  # type: ignore
