"""Selective-recompute fused prefill flash kernel (Pallas).

CacheBlend-style non-prefix reuse assembles one KV buffer per request out of
stored chunk spans (preloaded, possibly from several source entries) plus
the fresh K/V of the tokens chosen for recompute, then runs attention for
ONLY those recompute tokens against the full buffer.  The query side is a
*gappy* subset of positions — not a suffix — so this is
``flash_prefill._kernel`` with position-based masking generalised to
arbitrary (ascending) query positions:

    keep(p, s)  iff  kv_pos[s] >= 0  and  kv_pos[s] <= q_pos[p]   (and window)

plus a block-level early-out: a kv block whose smallest valid position lies
beyond the q block's largest position is fully masked, and a fully-masked
block is an exact no-op of the online-softmax recurrence (alpha == 1,
p == 0), so skipping its arithmetic changes nothing.  With a small recompute
fraction most (q, kv) tiles are in the strictly-causal region anyway — the
compute saving of selective recompute comes from the short q side.

Grid/BlockSpec layout is inherited unchanged from ``flash_prefill``:
  grid = (B, H, nQ, nKV), kv innermost; running (m, l, acc) in VMEM scratch.
Exactness contract: ``ref.fused_prefill_ref`` bitwise at r=1.0 against plain
full prefill (tests/test_fusion.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_prefill import _scratch

NEG_INF = -1e30


def supported(q, k, v, window: Optional[int] = None) -> bool:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    return H % KV == 0 and hd <= 256 and q.dtype in (jnp.float32, jnp.bfloat16)


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch
    *, window: Optional[int], n_kv: int, scale: float,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qp_ref[0, :].astype(jnp.int32)  # [bq]
    kp = kp_ref[0, :].astype(jnp.int32)  # [bkv]

    # Early-out: every kv position in this block is invalid or beyond the
    # q block's causal reach -> the whole tile is masked, an exact no-op.
    kp_min = jnp.min(jnp.where(kp >= 0, kp, 2**30))
    q_max = jnp.max(qp)

    @pl.when(kp_min <= q_max)
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bkv, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bkv]

        mask = (kp >= 0)[None, :]
        mask &= kp[None, :] <= qp[:, None]
        if window is not None:
            mask &= kp[None, :] > qp[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "interpret", "block_q", "block_kv"),
)
def fused_flash_attention(
    q: jax.Array,  # [B, Sq, H, hd] — recompute tokens only
    k: jax.Array,  # [B, Skv, KV, hd] — assembled context buffer
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, Sq] absolute (gappy) query positions
    kv_pos: jax.Array,  # [B, Skv] row positions (-1 invalid)
    window: Optional[int] = None,
    interpret: bool = False,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV

    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-(2**30))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    n_q, n_kv = Sq_p // bq, Skv_p // bkv

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, window=window, n_kv=n_kv, scale=1.0 / (hd**0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bkv), lambda b, h, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq,), jnp.float32),
            _scratch((bq,), jnp.float32),
            _scratch((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
    return out[:, :Sq]
