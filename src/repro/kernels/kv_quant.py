"""Int8 KV (de)quantisation Pallas kernels — the storage-tier hot path.

Layout contract: quantisation is symmetric per-(row) over the trailing
channel dim (head_dim), matching ``ref.kv_quant_ref``.  The dequant kernel
runs on load (storage -> HBM) fused over row blocks so reused KV never
round-trips through fp32 HBM tensors.

VMEM: row-block x hd x (1B int8 + 2-4B float) — e.g. 256 rows x 128 ch
~= 0.16 MB per buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_prefill import _scratch  # noqa: F401 (shared helper)


def supported(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] >= 8


def _flatten(x):
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return x.reshape(rows, x.shape[-1]), x.shape


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def kv_quant(x: jax.Array, *, interpret: bool = False, block_rows: int = 256):
    xf, orig_shape = _flatten(x)
    rows, hd = xf.shape
    br = min(block_rows, max(rows, 1))
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)), constant_values=1.0)
    n = (rows + pad) // br

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((br, hd), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, hd), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows + pad, hd), jnp.int8),
            jax.ShapeDtypeStruct((rows + pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xf)
    q = q[:rows].reshape(orig_shape)
    s = s[:rows].reshape(orig_shape[:-1] + (1,))
    return q, s


@functools.partial(jax.jit, static_argnames=("dtype", "interpret", "block_rows"))
def kv_dequant(
    q: jax.Array, scale: jax.Array, *, dtype=jnp.bfloat16, interpret: bool = False,
    block_rows: int = 256,
):
    qf, orig_shape = _flatten(q)
    sf = scale.reshape(qf.shape[0], 1)
    rows, hd = qf.shape
    br = min(block_rows, max(rows, 1))
    pad = (-rows) % br
    if pad:
        qf = jnp.pad(qf, ((0, pad), (0, 0)))
        sf = jnp.pad(sf, ((0, pad), (0, 0)))
    n = (rows + pad) // br

    out = pl.pallas_call(
        _dequant_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, hd), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, hd), dtype),
        interpret=interpret,
    )(qf, sf)
    return out[:rows].reshape(orig_shape)
