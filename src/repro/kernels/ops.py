"""Public jit-safe kernel entry points with backend dispatch.

Dispatch policy (``REPRO_KERNEL_MODE`` env var or :func:`set_kernel_mode`):
  * ``auto`` (default)      — Pallas kernels on TPU, jnp reference elsewhere.
  * ``ref``                 — always the pure-jnp oracle (CPU dry-run path).
  * ``pallas_interpret``    — Pallas kernels in interpret mode (CPU kernel
                              validation; used by the kernel test suite).
  * ``pallas``              — Pallas compiled (TPU).

The chunked SSD implementation lives here (it is jnp-level and runs on every
backend); its exactness oracle is ``ref.ssd_scan_ref``.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

_MODE = None


def set_kernel_mode(mode: Optional[str]) -> None:
    """Override dispatch mode globally (None restores env/auto)."""
    global _MODE
    _MODE = mode


def kernel_mode() -> str:
    if _MODE is not None:
        return _MODE
    return os.environ.get("REPRO_KERNEL_MODE", "auto")


def _use_pallas() -> Tuple[bool, bool]:
    """Returns (use_pallas, interpret)."""
    mode = kernel_mode()
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, False
    if mode == "pallas_interpret":
        return True, True
    # auto
    return jax.default_backend() == "tpu", False


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #
# Peak-memory guard: route big attention through the q-chunked (flash-style)
# jnp path so the dry-run never materialises an O(Sq*Skv) score tensor.
CHUNKED_THRESHOLD = 2048 * 8192


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Generalised GQA attention — see ``ref.attention_ref`` for semantics."""
    use_pallas, interpret = _use_pallas()
    if use_pallas and kv_valid is None and q.shape[1] >= 128:
        from repro.kernels import flash_prefill

        if flash_prefill.supported(q, k, v, window=window):
            return flash_prefill.flash_attention(
                q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, window=window,
                interpret=interpret,
            )
    if kv_shard_enabled() and kv_valid is None:
        out = _kv_sharded_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, window=window
        )
        if out is not None:
            return out
    if q.shape[1] * k.shape[1] >= CHUNKED_THRESHOLD:
        return ref.attention_ref_chunked(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, window=window,
            kv_valid=kv_valid,
        )
    return ref.attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, window=window, kv_valid=kv_valid
    )


def packed_attention(
    q: jax.Array,  # [B, Sq, H, hd] — packed token runs from several requests
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, Sq] segment-local positions
    kv_pos: jax.Array,  # [B, Skv]
    q_seg: jax.Array,  # [B, Sq] segment (request) id per query token
    kv_seg: jax.Array,  # [B, Skv] segment id per kv row
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Segment-masked attention over a packed ragged batch — the shared
    suffix-prefill kernel of batched admission.  See
    ``ref.packed_attention_ref`` for semantics."""
    use_pallas, interpret = _use_pallas()
    if use_pallas and q.shape[1] >= 128:
        from repro.kernels import packed_prefill

        if packed_prefill.supported(q, k, v, window=window):
            return packed_prefill.packed_flash_attention(
                q, k, v, q_pos=q_pos, kv_pos=kv_pos, q_seg=q_seg, kv_seg=kv_seg,
                causal=causal, window=window, interpret=interpret,
            )
    return ref.packed_attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, q_seg=q_seg, kv_seg=kv_seg,
        causal=causal, window=window,
    )


def fused_prefill(
    q: jax.Array,  # [B, Sq, H, hd] — selectively-recomputed tokens only
    k: jax.Array,  # [B, Skv, KV, hd] — assembled context buffer
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, Sq] absolute (gappy) query positions
    kv_pos: jax.Array,  # [B, Skv] row positions (-1 invalid)
    window: Optional[int] = None,
) -> jax.Array:
    """Selective-recompute attention over an assembled KV buffer — the
    CacheBlend-style fused prefill of non-prefix chunk reuse.  See
    ``ref.fused_prefill_ref`` for semantics and the r=1.0 bit-exactness
    contract vs plain full prefill."""
    use_pallas, interpret = _use_pallas()
    if use_pallas and q.shape[1] >= 128:
        from repro.kernels import fused_prefill as fpk

        if fpk.supported(q, k, v, window=window):
            return fpk.fused_flash_attention(
                q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
                interpret=interpret,
            )
    return ref.fused_prefill_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window
    )


def decode_attention(
    q: jax.Array,  # [B, 1, H, hd]
    k: jax.Array,  # [B, L, KV, hd]
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import decode_attention as dk

        if dk.supported(q, k, v):
            return dk.decode_attention(
                q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window, kv_valid=kv_valid,
                interpret=interpret,
            )
    if kv_shard_enabled() and kv_valid is None:
        out = _kv_sharded_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True, window=window
        )
        if out is not None:
            return out
    return ref.attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True, window=window, kv_valid=kv_valid
    )


def paged_decode(
    q: jax.Array,  # [B, 1, H, hd]
    k_pool: jax.Array,  # [N_rows, KV, hd] — shared block pool, flat rows
    v_pool: jax.Array,
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    q_pos: jax.Array,  # [B, 1]
    block: int = 128,
    window: Optional[int] = None,
) -> jax.Array:
    """Decode attention gathering each sequence's live KV blocks from the
    shared pool via its block table — see ``ref.paged_decode_ref`` for
    semantics and the bit-exactness contract vs dense decode."""
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import paged_decode as pdk

        if pdk.supported(q, k_pool, v_pool, block):
            return pdk.paged_decode_attention(
                q, k_pool, v_pool, block_table=block_table, q_pos=q_pos,
                block=block, window=window, interpret=interpret,
            )
    return ref.paged_decode_ref(
        q, k_pool, v_pool, block_table=block_table, q_pos=q_pos, block=block,
        window=window,
    )


def chunked_prefill(
    q: jax.Array,  # [B, C, H, hd] — up to C new tokens per sequence
    k_pool: jax.Array,  # [N_rows, KV, hd] — shared block pool, flat rows
    v_pool: jax.Array,
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    q_pos: jax.Array,  # [B, C] (-2^30 padding)
    block: int = 128,
    window: Optional[int] = None,
) -> jax.Array:
    """Chunked-prefill attention over the shared block pool — the unified
    continuous-batching launch mixing prefill-chunk rows with decode rows.
    See ``ref.chunked_prefill_ref`` for semantics and the bit-exactness
    contract vs dense suffix prefill."""
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import chunked_prefill as cpk

        if cpk.supported(q, k_pool, v_pool, block):
            return cpk.chunked_prefill_attention(
                q, k_pool, v_pool, block_table=block_table, q_pos=q_pos,
                block=block, window=window, interpret=interpret,
            )
    return ref.chunked_prefill_ref(
        q, k_pool, v_pool, block_table=block_table, q_pos=q_pos, block=block,
        window=window,
    )


# --------------------------------------------------------------------------- #
# KV-sequence-sharded flash attention (shard_map over the model axis)
# --------------------------------------------------------------------------- #
# Beyond-paper distribution strategy (EXPERIMENTS.md §Perf): shard the KV
# length over the model axis and combine per-shard online-softmax pieces
#   m* = pmax(m_i);  l* = psum(l_i e^{m_i-m*});  o* = psum(o_i e^{m_i-m*}) / l*
# Collectives shrink from score-tensor all-reduces (O(Sq*Skv)) to stats+output
# (O(Sq*H*hd)); attention FLOPs and the score working set divide by the axis
# size; the KV cache stays length-sharded (HBM-safe for 32k-128k contexts
# with few KV heads).  Enable with REPRO_ATTN_KV_SHARD=1 (dry-run/TPU meshes).
def kv_shard_enabled() -> bool:
    return os.environ.get("REPRO_ATTN_KV_SHARD") == "1"


def _mesh_axes_for_kv_shard(batch: int, skv: int):
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover
        return None
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None
    m = mesh.shape["model"]
    if m <= 1 or skv % m != 0:
        return None
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bspec = baxes if (baxes and batch % bsize == 0) else None
    return mesh, bspec


def _kv_sharded_attention(q, k, v, *, q_pos, kv_pos, causal, window):
    from jax.sharding import PartitionSpec as P

    got = _mesh_axes_for_kv_shard(q.shape[0], k.shape[1])
    if got is None:
        return None
    mesh, b = got

    def local(q, k, v, qp, kp):
        m_loc, l_loc, o_loc = _flash_pieces(
            q, k, v, qp, kp, causal=causal, window=window
        )
        m_glob = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m_glob)  # [B, Sq, H]
        l_glob = jax.lax.psum(l_loc * corr, "model")
        o_glob = jax.lax.psum(o_loc * corr[..., None], "model")
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.astype(q.dtype)

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(b, None, None, None),
            P(b, "model", None, None),
            P(b, "model", None, None),
            P(b, None),
            P(b, "model"),
        ),
        out_specs=P(b, None, None, None),
        check_vma=False,
    )
    return fn(q, k, v, q_pos, kv_pos)


def _flash_pieces(q, k, v, qp, kp, *, causal, window, q_chunk: int = 1024):
    """Unnormalised local softmax pieces over this shard's KV slice.

    Returns (m [B,Sq,H], l [B,Sq,H], o [B,Sq,H,hd]) with
    o = sum_s e^{score - m} v_s, computed in q chunks for O(c*Skv) memory."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def chunk(args):
        qi, qpi = args  # [B, c, H, hd], [B, c]
        qg = qi.reshape(B, -1, KV, G, hd).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / jnp.sqrt(jnp.float32(hd))
        qpos = qpi[:, None, None, :, None].astype(jnp.int32)
        spos = kp[:, None, None, None, :].astype(jnp.int32)
        mask = spos >= 0
        if causal:
            mask &= spos <= qpos
        if window is not None:
            mask &= spos > qpos - window
        s = jnp.where(mask, s, ref.NEG_INF)
        m = jnp.max(s, axis=-1)  # [B,KV,G,c]
        p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p, vf)
        c = qi.shape[1]
        return (
            m.transpose(0, 3, 1, 2).reshape(B, c, H),
            l.transpose(0, 3, 1, 2).reshape(B, c, H),
            o.transpose(0, 3, 1, 2, 4).reshape(B, c, H, hd),
        )

    cq = min(q_chunk, Sq)
    pad = (-Sq) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pad)), constant_values=-(2**30))
    nc = (Sq + pad) // cq
    if nc == 1:
        m, l, o = chunk((q, qp))
    else:
        qc = q.reshape(B, nc, cq, H, hd).transpose(1, 0, 2, 3, 4)
        qpc = qp.reshape(B, nc, cq).transpose(1, 0, 2)
        ms, ls, os_ = jax.lax.map(chunk, (qc, qpc))
        m = ms.transpose(1, 0, 2, 3).reshape(B, Sq + pad, H)
        l = ls.transpose(1, 0, 2, 3).reshape(B, Sq + pad, H)
        o = os_.transpose(1, 0, 2, 3, 4).reshape(B, Sq + pad, H, hd)
    return m[:, :Sq], l[:, :Sq], o[:, :Sq]


# --------------------------------------------------------------------------- #
# Chunked SSD (Mamba2) — linear-time, matmul-dominant formulation
# --------------------------------------------------------------------------- #
def ssd_chunked(
    x: jax.Array,  # [B, L, H, P]
    dt: jax.Array,  # [B, L, H] (softplus'd, >= 0)
    A: jax.Array,  # [H] (negative)
    B_: jax.Array,  # [B, L, G, S]
    C: jax.Array,  # [B, L, G, S]
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, S]
) -> Tuple[jax.Array, jax.Array]:
    """State-space-dual chunked scan: within-chunk quadratic (MXU-friendly
    matmuls) + cross-chunk state recurrence.  Exactly equal (fp32 math) to the
    sequential oracle ``ref.ssd_scan_ref``.

    Returns (y [B,L,H,P], final_state [B,H,P,S]).
    """
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import ssd_scan

        if ssd_scan.supported(x, dt, A, B_, C, chunk=chunk):
            return ssd_scan.ssd_chunked(
                x, dt, A, B_, C, chunk=chunk, initial_state=initial_state,
                interpret=interpret,
            )
    return ssd_chunked_jnp(x, dt, A, B_, C, chunk=chunk, initial_state=initial_state)


def ssd_chunked_jnp(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B_: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    Bsz, L, H, P = x.shape
    G, S = B_.shape[2], B_.shape[3]
    rep = H // G

    pad = (-L) % chunk
    if pad:
        # dt = 0 on padding => decay exp(0)=1 and zero update: state-safe.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, chunk, H, S)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2).reshape(Bsz, nc, chunk, H, S)
    Af = A.astype(jnp.float32)

    a = dtf * Af[None, None, None, :]  # [B,nc,Q,H], <= 0
    cum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk

    # Within-chunk ("diagonal") term: y[t] += sum_{s<=t} (C_t.B_s) e^{cum_t-cum_s} dt_s x_s
    CB = jnp.einsum("bnqhs,bnkhs->bnhqk", Cf, Bf)  # [B,nc,H,Q,Q]
    # decay[t, s] = exp(cum_t - cum_s), masked to s <= t
    ct = cum.transpose(0, 1, 3, 2)  # [B,nc,H,Q]
    dmat = ct[:, :, :, :, None] - ct[:, :, :, None, :]  # cum_t - cum_s
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    dmat = jnp.where(tri[None, None, None], dmat, -jnp.inf)
    decay = jnp.exp(dmat)  # [B,nc,H,Q,Q]
    M = CB * decay * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]  # * dt_s
    y_diag = jnp.einsum("bnhqk,bnkhp->bnqhp", M, xf)

    # Per-chunk end-state contribution: sum_s e^{cum_{Q-1}-cum_s} dt_s x_s ⊗ B_s
    end_decay = jnp.exp(ct[:, :, :, -1:] - ct)  # [B,nc,H,Q]
    weighted_x = xf * (dtf * end_decay.transpose(0, 1, 3, 2))[..., None]  # [B,nc,Q,H,P]
    chunk_states = jnp.einsum("bnqhp,bnqhs->bnhps", weighted_x, Bf)

    # Cross-chunk recurrence over nc chunks.
    chunk_decay = jnp.exp(ct[:, :, :, -1])  # [B,nc,H] total decay of each chunk
    h0 = (
        jnp.zeros((Bsz, H, P, S), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def chunk_step(h, inp):
        st, dec = inp  # [B,H,P,S], [B,H]
        h_in = h  # state BEFORE this chunk
        h = h * dec[:, :, None, None] + st
        return h, h_in

    hT, h_inits = jax.lax.scan(
        chunk_step, h0, (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_inits = jnp.moveaxis(h_inits, 0, 1)  # [B,nc,H,P,S]

    # Off-diagonal term: y[t] += e^{cum_t} * (C_t · h_init)
    y_off = jnp.einsum("bnqhs,bnhps->bnqhp", Cf, h_inits)
    y_off = y_off * jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(x.dtype), hT


def ssd_decode(
    state: jax.Array,
    x_t: jax.Array,
    dt_t: jax.Array,
    A: jax.Array,
    B_t: jax.Array,
    C_t: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """O(1) single-token SSD update (see ``ref.ssd_decode_ref``)."""
    return ref.ssd_decode_ref(state, x_t, dt_t, A, B_t, C_t)


# --------------------------------------------------------------------------- #
# KV int8 (de)quantisation for the storage/transfer tier
# --------------------------------------------------------------------------- #
def kv_quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import kv_quant as kq

        if kq.supported(x):
            return kq.kv_quant(x, interpret=interpret)
    return ref.kv_quant_ref(x)


def kv_dequant(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        from repro.kernels import kv_quant as kq

        if kq.supported(q):
            return kq.kv_dequant(q, scale, dtype=dtype, interpret=interpret)
    return ref.kv_dequant_ref(q, scale, dtype=dtype)
