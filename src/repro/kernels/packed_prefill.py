"""Packed ragged suffix-prefill flash kernel (Pallas).

The batched admission path concatenates token runs from several requests into
ONE sequence: each request contributes a kv span ``[stored-prefix KV ++ new
KV]`` and a q span of its new (non-reused) tokens.  This kernel is
``flash_prefill._kernel`` plus one mask term — a segment id per q token and
per kv row, with cross-segment attention masked out — so many requests share
a single kernel launch instead of one launch each.

Positions stay segment-local (what each request would see alone), which keeps
the causal/sliding-window masking and the RoPE applied upstream identical to
the per-request path.  Exactness contract: with every segment's kv span
aligned to ``block_kv``, a fully-masked kv block is an exact no-op of the
online-softmax recurrence (alpha == 1, p == 0), so the packed output is
bit-identical to running each request alone — asserted by
``tests/test_packed.py``.

Grid/BlockSpec layout is inherited unchanged from ``flash_prefill``:
  grid = (B, H, nQ, nKV), kv innermost; running (m, l, acc) in VMEM scratch.
VMEM adds only the two int32 id blocks (bq + bkv ints) on top of
flash_prefill's ~0.23 MB working set.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_prefill import _scratch

NEG_INF = -1e30


def supported(q, k, v, window: Optional[int] = None) -> bool:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    return H % KV == 0 and hd <= 256 and q.dtype in (jnp.float32, jnp.bfloat16)


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref, qs_ref, ks_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch
    *, causal: bool, window: Optional[int], n_kv: int, scale: float,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bkv, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, :].astype(jnp.int32)  # [bq]
    kp = kp_ref[0, :].astype(jnp.int32)  # [bkv]
    qs = qs_ref[0, :].astype(jnp.int32)
    ks = ks_ref[0, :].astype(jnp.int32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bkv]

    mask = (kp >= 0)[None, :]
    mask &= qs[:, None] == ks[None, :]  # segment isolation
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "interpret", "block_q", "block_kv"),
)
def packed_flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [B, Sq] segment-local positions
    kv_pos: jax.Array,  # [B, Skv]
    q_seg: jax.Array,  # [B, Sq] segment id per query token
    kv_seg: jax.Array,  # [B, Skv] segment id per kv row
    causal: bool = True,
    window: Optional[int] = None,
    interpret: bool = False,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV

    bq = min(block_q, max(Sq, 8))
    bkv = min(block_kv, max(Skv, 8))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-(2**30))
        q_seg = jnp.pad(q_seg, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad_kv)), constant_values=-2)
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    n_q, n_kv = Sq_p // bq, Skv_p // bkv

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, n_kv=n_kv, scale=1.0 / (hd**0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, iq, ik, G=G: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bkv), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bkv), lambda b, h, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq,), jnp.float32),
            _scratch((bq,), jnp.float32),
            _scratch((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos, q_seg, kv_seg)
    return out[:, :Sq]
