"""Paged decode-attention Pallas kernel: block-table gather over the shared
KV block pool.

Decode under the paged layout reads, per sequence, exactly the live
``block``-token blocks its block table names — nothing else leaves HBM.  The
pool is ONE array shared by every batch slot ([n_blocks, block, KV, hd]);
``block_table[b, j]`` is the pool block holding sequence ``b``'s tokens
``[j*block, (j+1)*block)``.  The table rides in as a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``) so the k/v BlockSpec index maps can
dereference it — the DMA for grid step (b, h, j) fetches pool block
``table[b, j]`` directly; no gathered copy of the cache is ever
materialised.

Grid (B, KV, nb) with the G grouped query heads of a KV head processed
together (the cache block is read once per head group), flash-style running
softmax across the table axis in VMEM scratch — structurally
``decode_attention`` with the kv axis indirected through the table.
Validity is positional: row ``r`` of table entry ``j`` holds sequence
position ``j*block + r``, so masking ``pos > q_pos`` covers the boundary
block's tail AND the 0-padded table entries (they point at the reserved dump
block, whose positions all exceed the query's) — no separate valid-bitmap
input is needed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_prefill import _scratch

NEG_INF = -1e30


def supported(q, k_pool, v_pool, block: int) -> bool:
    B, Sq, H, hd = q.shape
    KV = k_pool.shape[1]
    return (
        Sq == 1
        and H % KV == 0
        and hd <= 256
        and k_pool.shape[0] % block == 0
        and q.dtype in (jnp.float32, jnp.bfloat16)
    )


def _kernel(
    tbl_ref,  # scalar-prefetch: [B, nb] int32
    q_ref, k_ref, v_ref, qp_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch
    *, nb: int, block: int, window: Optional[int], scale: float,
):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qg = q_ref[0, 0, :, :].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, 0].astype(jnp.int32)  # scalar

    s = jax.lax.dot_general(
        qg, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, block]

    # sequence position of each row of this table entry (by construction)
    kp = ib * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    mask = kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ib == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "window", "interpret")
)
def paged_decode_attention(
    q: jax.Array,  # [B, 1, H, hd]
    k_pool: jax.Array,  # [N_rows, KV, hd] (N_rows = n_blocks * block)
    v_pool: jax.Array,
    *,
    block_table: jax.Array,  # [B, nb] int32
    q_pos: jax.Array,  # [B, 1]
    block: int = 128,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, hd = q.shape
    KV = k_pool.shape[1]
    G = H // KV
    nb = block_table.shape[1]

    kb = k_pool.reshape(-1, block, KV, hd)  # [n_blocks, block, KV, hd]
    vb = v_pool.reshape(-1, block, KV, hd)
    # [B, 1, H, hd] -> [B, KV, G, hd]: one grid step covers a KV head group.
    qg = q[:, 0].reshape(B, KV, G, hd)
    tbl = block_table.astype(jnp.int32)

    kernel = functools.partial(
        _kernel, nb=nb, block=block, window=window, scale=1.0 / (hd**0.5)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ib, t: (b, h, 0, 0)),
            pl.BlockSpec((1, block, 1, hd), lambda b, h, ib, t: (t[b, ib], 0, h, 0)),
            pl.BlockSpec((1, block, 1, hd), lambda b, h, ib, t: (t[b, ib], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ib, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ib, t: (b, h, 0, 0)),
        scratch_shapes=[
            _scratch((G,), jnp.float32),
            _scratch((G,), jnp.float32),
            _scratch((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, qg, kb, vb, q_pos)
    return out.reshape(B, 1, H, hd)
