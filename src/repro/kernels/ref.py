"""Pure-jnp reference oracles for every Pallas kernel.

These are the semantics contract: each Pallas kernel in this package must be
allclose to the corresponding function here across shape/dtype sweeps
(``tests/test_kernels_*.py``).  The model zoo also dispatches to these
implementations on non-TPU backends so the dry-run HLO stays faithful.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite "minus infinity": avoids NaN from (-inf) - (-inf)


# --------------------------------------------------------------------------- #
# Generalised (flash-)attention: one signature for train / prefill /
# suffix-prefill / decode / sliding-window ring buffers.
# --------------------------------------------------------------------------- #
def attention_ref(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,  # [B, Skv, KV, hd]
    *,
    q_pos: jax.Array,  # [B, Sq] absolute positions of the query tokens
    kv_pos: jax.Array,  # [B, Skv] absolute positions of the cached kv tokens
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window width (None => full)
    kv_valid: Optional[jax.Array] = None,  # [B, Skv] bool (ring-buffer slots)
) -> jax.Array:
    """Grouped-query attention with position-based masking.

    Masking rule for query position p and key position s:
      keep iff (not causal or s <= p) and (window is None or s > p - window)
               and kv_valid[s]
    ``kv_pos < 0`` marks an invalid (never-written) cache slot.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))

    qp = q_pos[:, None, None, :, None].astype(jnp.int32)  # [B,1,1,Sq,1]
    sp = kv_pos[:, None, None, None, :].astype(jnp.int32)  # [B,1,1,1,Skv]
    mask = sp >= 0
    if causal:
        mask &= sp <= qp
    if window is not None:
        mask &= sp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, None, :]

    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_ref_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
    q_chunk: int = 512,
) -> jax.Array:
    """attention_ref computed in query chunks (lax.map over q blocks).

    Identical numerics; peak memory O(q_chunk * Skv) instead of O(Sq * Skv)
    — the jnp analogue of the Pallas flash kernel's tiling, used for long
    sequences so the dry-run's memory footprint matches the TPU execution
    plan instead of a materialised S^2 score tensor."""
    B, Sq, H, hd = q.shape
    pad = (-Sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-(2**30))
    nc = (Sq + pad) // q_chunk
    qc = q.reshape(B, nc, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(B, nc, q_chunk).transpose(1, 0, 2)

    def one(args):
        qi, qpi = args
        return attention_ref(
            qi, k, v, q_pos=qpi, kv_pos=kv_pos, causal=causal, window=window,
            kv_valid=kv_valid,
        )

    out = jax.lax.map(one, (qc, qp))  # [nc, B, c, H, hd]
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sq + pad, H, hd)
    return out[:, :Sq]


def packed_attention_ref(
    q: jax.Array,  # [B, Sq, H, hd] — token runs from several requests, packed
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,  # [B, Skv, KV, hd]
    *,
    q_pos: jax.Array,  # [B, Sq] segment-local positions of the query tokens
    kv_pos: jax.Array,  # [B, Skv] segment-local positions (-1 = invalid slot)
    q_seg: jax.Array,  # [B, Sq] segment (request) id per query token
    kv_seg: jax.Array,  # [B, Skv] segment id per kv row
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """``attention_ref`` over a *packed ragged* batch: several requests'
    suffix-prefills concatenated into one sequence.  Identical arithmetic to
    ``attention_ref`` plus one extra mask term — a query may only attend kv
    rows of its own segment (``q_seg == kv_seg``), so cross-request attention
    is structurally impossible.  Positions are segment-local, which keeps
    RoPE and causal/window masking exactly what each request would see alone.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))

    qp = q_pos[:, None, None, :, None].astype(jnp.int32)  # [B,1,1,Sq,1]
    sp = kv_pos[:, None, None, None, :].astype(jnp.int32)  # [B,1,1,1,Skv]
    qs = q_seg[:, None, None, :, None].astype(jnp.int32)
    ss = kv_seg[:, None, None, None, :].astype(jnp.int32)
    mask = sp >= 0
    mask &= qs == ss
    if causal:
        mask &= sp <= qp
    if window is not None:
        mask &= sp > qp - window

    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def fused_prefill_ref(
    q: jax.Array,  # [B, Sq, H, hd] — the selectively-recomputed tokens only
    k: jax.Array,  # [B, Skv, KV, hd] — the ASSEMBLED context buffer
    v: jax.Array,  # [B, Skv, KV, hd]
    *,
    q_pos: jax.Array,  # [B, Sq] absolute positions of the recompute tokens
    kv_pos: jax.Array,  # [B, Skv] row positions (-1 = invalid/padding row)
    window: Optional[int] = None,
) -> jax.Array:
    """Selective-recompute fused prefill attention (CacheBlend-style).

    ``k``/``v`` hold one query-ordered KV buffer assembled from reused
    chunk spans (preloaded from storage) plus the recompute tokens' fresh
    K/V (scattered in by the caller at their ``q_pos`` rows).  The queries
    are only the recompute tokens — a *gappy* subset of positions, unlike
    suffix prefill — and each attends causally over the FULL assembled
    buffer at its absolute position.  Masking rule for query position p and
    kv row position s: keep iff ``s >= 0 and s <= p`` (and the window).
    With every position recomputed (r=1.0) this is exactly full-prefill
    attention — the bit-exactness anchor of ``tests/test_fusion.py``.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))

    qp = q_pos[:, None, None, :, None].astype(jnp.int32)  # [B,1,1,Sq,1]
    sp = kv_pos[:, None, None, None, :].astype(jnp.int32)  # [B,1,1,1,Skv]
    mask = (sp >= 0) & (sp <= qp)
    if window is not None:
        mask &= sp > qp - window

    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_decode_ref(
    q: jax.Array,  # [B, 1, H, hd] — one query token per sequence
    k_pool: jax.Array,  # [N_rows, KV, hd] — the SHARED block pool, flat rows
    v_pool: jax.Array,  # [N_rows, KV, hd]
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    q_pos: jax.Array,  # [B, 1] position of the query token (== live len - 1)
    block: int = 128,
    window: Optional[int] = None,
) -> jax.Array:
    """Decode attention over a paged KV layout: each sequence's cache is the
    concatenation of the ``block``-token pool blocks its ``block_table`` row
    names, in table order.  Sequence position of row ``r`` of table entry
    ``j`` is ``j*block + r`` by construction, so validity is purely
    positional: rows past ``q_pos`` (tail of the boundary block, 0-padded
    table entries pointing at the reserved dump block) mask out exactly as a
    dense cache's unwritten tail does.  Gathering the live blocks into
    sequence order and running ``attention_ref`` is therefore bit-identical
    to dense decode over a slotted cache of the same padded length — the
    exactness contract ``tests/test_paged_decode.py`` pins at every level.
    """
    B = q.shape[0]
    nb = block_table.shape[1]
    rows = (
        block_table[:, :, None].astype(jnp.int32) * block
        + jnp.arange(block, dtype=jnp.int32)[None, None, :]
    ).reshape(B, nb * block)
    k = k_pool[rows]  # [B, nb*block, KV, hd]
    v = v_pool[rows]
    idx = jnp.arange(nb * block, dtype=jnp.int32)[None]
    kv_pos = jnp.where(idx <= q_pos.astype(jnp.int32), idx, -1)
    return attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True, window=window
    )


def chunked_prefill_ref(
    q: jax.Array,  # [B, C, H, hd] — up to C new tokens per sequence (a chunk)
    k_pool: jax.Array,  # [N_rows, KV, hd] — the SHARED block pool, flat rows
    v_pool: jax.Array,  # [N_rows, KV, hd]
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    q_pos: jax.Array,  # [B, C] positions of the chunk tokens (-2^30 padding)
    block: int = 128,
    window: Optional[int] = None,
) -> jax.Array:
    """Chunked-prefill attention over a paged KV layout: the multi-query
    generalisation of ``paged_decode_ref``.  Each row carries a chunk of up
    to ``C`` new tokens whose K/V have already been scattered into the pool
    blocks named by ``block_table`` (the caller lands the chunk before
    attending), so every query at position ``p`` attends exactly the pool
    rows at sequence positions ``[0, p]`` — its reused/previously-landed
    context plus the chunk's own causal prefix.  A decode row is the C=1
    degenerate case (one valid query at the live length); an idle row is all
    padding (``q_pos`` = -2^30 masks every key, output 0).  Validity is
    purely positional: rows past the last valid query (boundary-block tail,
    0-padded table entries pointing at the reserved dump block) carry
    positions exceeding every query's and mask out causally — bit-identical
    to dense suffix prefill over the same context, the contract
    ``tests/test_chunked_prefill.py`` pins at every level.
    """
    B, C = q.shape[0], q.shape[1]
    nb = block_table.shape[1]
    rows = (
        block_table[:, :, None].astype(jnp.int32) * block
        + jnp.arange(block, dtype=jnp.int32)[None, None, :]
    ).reshape(B, nb * block)
    k = k_pool[rows]  # [B, nb*block, KV, hd]
    v = v_pool[rows]
    # Row r of table entry j holds sequence position j*block + r; the causal
    # mask (kv <= q_pos) is the entire validity rule, exactly as the Pallas
    # kernel computes it.
    kv_pos = jnp.broadcast_to(
        jnp.arange(nb * block, dtype=jnp.int32)[None], (B, nb * block)
    )
    return attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True, window=window
    )


def causal_positions(batch: int, seq: int, offset=0) -> jax.Array:
    """[B, S] positions ``offset + arange(S)``; offset scalar or [B]."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :]
    off = jnp.asarray(offset, jnp.int32)
    off = off[:, None] if off.ndim == 1 else off
    return jnp.broadcast_to(pos + off, (batch, seq))


# --------------------------------------------------------------------------- #
# Mamba2 / SSD: sequential state-space scan (exact oracle)
# --------------------------------------------------------------------------- #
def ssd_scan_ref(
    x: jax.Array,  # [B, L, H, P]
    dt: jax.Array,  # [B, L, H]   (already softplus'd, > 0)
    A: jax.Array,  # [H]          (negative)
    B_: jax.Array,  # [B, L, G, S]
    C: jax.Array,  # [B, L, G, S]
    *,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, S]
) -> Tuple[jax.Array, jax.Array]:
    """Selective state-space recurrence
        h_t = exp(dt_t * A) * h_{t-1} + dt_t * (x_t ⊗ B_t)
        y_t = h_t · C_t
    computed with a plain sequential scan over time — the exactness oracle for
    the chunked SSD kernel.  Returns (y [B,L,H,P], final_state [B,H,P,S]).
    """
    Bsz, L, H, P = x.shape
    G, S = B_.shape[2], B_.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)  # [B, L, H, S]
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    h0 = (
        jnp.zeros((Bsz, H, P, S), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,H,S], [B,H,S]
        decay = jnp.exp(dtt * Af[None, :])[:, :, None, None]  # [B,H,1,1]
        upd = (dtt[:, :, None] * xt)[..., None] * bt[:, :, None, :]  # [B,H,P,S]
        h = h * decay + upd
        y = jnp.einsum("bhps,bhs->bhp", h, ct)
        return h, y

    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # [B, L, H, P]
    return y, hT


def ssd_decode_ref(
    state: jax.Array,  # [B, H, P, S]
    x_t: jax.Array,  # [B, H, P]
    dt_t: jax.Array,  # [B, H]
    A: jax.Array,  # [H]
    B_t: jax.Array,  # [B, G, S]
    C_t: jax.Array,  # [B, G, S]
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update (O(1) decode step)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bf = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)  # [B,H,S]
    Cf = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    upd = (dt_t.astype(jnp.float32)[:, :, None] * x_t.astype(jnp.float32))[..., None] * Bf[
        :, :, None, :
    ]
    new_state = state.astype(jnp.float32) * decay[:, :, None, None] + upd
    y = jnp.einsum("bhps,bhs->bhp", new_state, Cf).astype(x_t.dtype)
    return y, new_state


# --------------------------------------------------------------------------- #
# KV-cache int8 compression (storage / transfer tier)
# --------------------------------------------------------------------------- #
def kv_quant_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-(token, head) int8 quantisation over the channel dim.

    x: [..., hd]  ->  (q int8 [..., hd], scale f32 [..., 1])
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequant_ref(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# MoE: dense loop-over-experts oracle (tests only — O(E) compute)
# --------------------------------------------------------------------------- #
def moe_ref(
    x: jax.Array,  # [T, D]
    router_w: jax.Array,  # [D, E]
    w_gate: jax.Array,  # [E, D, F]
    w_up: jax.Array,  # [E, D, F]
    w_down: jax.Array,  # [E, F, D]
    top_k: int,
) -> jax.Array:
    """Exact dropless top-k MoE: every token is processed by each of its
    top-k experts (computed densely over all experts, then masked)."""
    xf = x.astype(jnp.float32)
    logits = xf @ router_w.astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    def one_expert(e):
        g = xf @ w_gate[e].astype(jnp.float32)
        u = xf @ w_up[e].astype(jnp.float32)
        return (jax.nn.silu(g) * u) @ w_down[e].astype(jnp.float32)  # [T, D]

    all_out = jax.vmap(one_expert)(jnp.arange(router_w.shape[1]))  # [E, T, D]
    sel = jax.nn.one_hot(top_i, router_w.shape[1], dtype=jnp.float32)  # [T, k, E]
    weight_e = jnp.einsum("tke,tk->et", sel, top_p)  # [E, T]
    out = jnp.einsum("etd,et->td", all_out, weight_e)
    return out.astype(x.dtype)
