"""Chunked SSD (Mamba2) scan Pallas kernel.

State-space duality turns the selective-scan recurrence into, per chunk of Q
tokens: two MXU matmuls (C·Bᵀ masked-decay score and score·X) plus an O(1)
cross-chunk state update — the TPU-native adaptation of the CUDA selective
scan (DESIGN.md §3).

Grid (B, H, n_chunks), chunk axis innermost/sequential; the [P, S] running
state lives in VMEM scratch across chunk steps.  VMEM per step:
  x (Q,P) + B/C (Q,S) + score (Q,Q) + state (P,S) f32
  ~= 256*64*4 + 2*256*128*4 + 256*256*4 + 64*128*4 ~= 0.6 MB.
Alignment: Q=256, S=128, P=64 are MXU/lane friendly.

The kernel is exact vs the sequential oracle ``ref.ssd_scan_ref`` (fp32).
Gotcha honoured: padding tokens carry dt=0 => decay=1, zero update.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_prefill import _scratch


def supported(x, dt, A, B_, C, *, chunk: int = 256) -> bool:
    Bsz, L, H, P = x.shape
    G = B_.shape[2]
    return H % G == 0 and P <= 256 and B_.shape[3] <= 256


def _kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
    y_ref, hT_ref,
    state_ref,
    *, n_chunks: int, Q: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [Q]
    a = a_ref[0, 0]  # scalar A_h
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)  # [Q, S]
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)  # [Q, S]

    adt = dt * a  # [Q], <= 0
    cum = jnp.cumsum(adt)  # inclusive
    # decay[t, s] = exp(cum_t - cum_s) for s <= t else 0
    dmat = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, dmat, 0.0)), 0.0)

    # within-chunk: y_diag = ((C Bᵀ) ⊙ decay ⊙ dt_s) X
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    m = cb * decay * dt[None, :]
    y = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]

    # off-diagonal: y += e^{cum_t} * C_t · h_in
    h_in = state_ref[...]  # [P, S]
    y_off = jax.lax.dot_general(
        cmat, h_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]
    y = y + y_off * jnp.exp(cum)[:, None]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h_out = e^{cum_Q} h_in + Σ_s e^{cum_Q - cum_s} dt_s x_s ⊗ B_s
    end_decay = jnp.exp(cum[-1] - cum) * dt  # [Q]
    upd = jax.lax.dot_general(
        x * end_decay[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [P, S]
    state_ref[...] = h_in * jnp.exp(cum[-1]) + upd

    @pl.when(ic == n_chunks - 1)
    def _fin():
        hT_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(
    x: jax.Array,  # [B, L, H, P]
    dt: jax.Array,  # [B, L, H]
    A: jax.Array,  # [H]
    B_: jax.Array,  # [B, L, G, S]
    C: jax.Array,  # [B, L, G, S]
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, S]
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    Bsz, L, H, P = x.shape
    G, S = B_.shape[2], B_.shape[3]
    rep = H // G

    Q = min(chunk, max(L, 8))
    pad = (-L) % Q
    if pad:  # dt=0 on padding: no decay, no update (see module docstring)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    h0 = (
        jnp.zeros((Bsz, H, P, S), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    Af = A.astype(jnp.float32).reshape(H, 1)

    kernel = functools.partial(_kernel, n_chunks=nc, Q=Q)
    y, hT = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1, 1), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, Q, 1, S), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, S), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, 1, P, S), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, P, S), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Lp, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, S), jnp.float32),
        ],
        scratch_shapes=[_scratch((P, S), jnp.float32)],
        interpret=interpret,
    )(x, dt, Af, B_, C, h0)
    return y[:, :L], hT
