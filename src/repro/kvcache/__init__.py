"""Tiered, content-addressed KV/context-state cache (the paper's storage half)."""
from repro.kvcache import backend, chunks, compression, paged, store, transfer  # noqa: F401
from repro.kvcache.backend import (  # noqa: F401
    HostMemoryBackend,
    ObjectStoreBackend,
    StorageBackend,
    default_backends,
)
from repro.kvcache.transfer import TransferHandle  # noqa: F401
