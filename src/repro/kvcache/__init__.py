"""Tiered, content-addressed KV/context-state cache (the paper's storage half)."""
from repro.kvcache import chunks, compression, paged, store, transfer  # noqa: F401
