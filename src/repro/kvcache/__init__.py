"""Tiered, content-addressed KV/context-state cache (the paper's storage half)."""
from repro.kvcache import (  # noqa: F401
    backend, chunks, compression, faults, hierarchy, paged, store, transfer,
)
from repro.kvcache.faults import (  # noqa: F401
    Brownout,
    CorruptPayload,
    CrashPlan,
    FaultInjector,
    KeyNotFound,
    RetryPolicy,
    StorageError,
    TierUnavailable,
    payload_checksum,
)
from repro.kvcache.backend import (  # noqa: F401
    HostMemoryBackend,
    ObjectStoreBackend,
    StorageBackend,
    default_backends,
)
from repro.kvcache.hierarchy import (  # noqa: F401
    BreakEvenMigrator,
    ConcurrencyLimitedBackend,
    DiskSpillBackend,
    RpcBackend,
    TieredStore,
    TierMigration,
    TierSpec,
    build_backends,
)
from repro.kvcache.transfer import TransferHandle  # noqa: F401
