"""Pluggable byte-level storage backends behind the ContextStore.

The paper's storage half splits into two concerns: *what* is stored (tier
metadata, the content-addressed trie, eviction economics — ContextStore) and
*where the bytes live and how long they take to move* (this module).  A
``StorageBackend`` owns opaque payloads keyed by entry id and returns a
``TransferHandle`` per movement, carrying the modeled delay and SimClock
completion time.  Straggler hedging (tail-at-scale duplicate reads) is a
backend property: the engine no longer special-cases it.

Two implementations ship:

  * ``HostMemoryBackend``  — host-DRAM tier; PCIe-speed loads.
  * ``ObjectStoreBackend`` — remote cloud tier (the paper's EBS/S3); delays
    flow through the TransferModel and reads may be hedged.

Both hold payloads in process memory (this container has no storage fabric);
the distinction is purely the delay/pricing model, which is the paper's
entire subject.  A real deployment would back ``ObjectStoreBackend`` with an
actual object store client behind the same protocol.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, Tuple, runtime_checkable

from repro.kvcache.faults import (
    CorruptPayload,
    FaultInjector,
    KeyNotFound,
    TierUnavailable,
    payload_checksum,
)
from repro.kvcache.transfer import SimClock, TransferHandle, TransferModel


@runtime_checkable
class StorageBackend(Protocol):
    """Byte-level payload storage with modeled transfer times."""

    name: str

    def put(
        self, key: str, payload: Any, nbytes: float, *, charge: bool = True
    ) -> TransferHandle:
        """Store ``payload`` under ``key``.  ``charge=False`` moves bytes
        without billing the link (tier migration, not a serving write)."""
        ...

    def get(
        self, key: str, *, nbytes: Optional[float] = None, charge: bool = True
    ) -> Tuple[Any, TransferHandle]:
        """Fetch the payload.  ``nbytes`` overrides the billed byte count for
        partial (prefix-fraction) reads; None reads the full payload."""
        ...

    def delete(self, key: str) -> bool: ...

    def contains(self, key: str) -> bool: ...

    def peek(self, key: str) -> Any:
        """Payload access with no transfer accounting (introspection only)."""
        ...

    def estimate_load_delay(self, nbytes: float) -> float:
        """Modeled read delay for ``nbytes`` (hedged), charging nothing."""
        ...


class _MemoryBackend:
    """Shared mechanics for the in-process backends: payload storage behind
    four overridable primitives (``_write``/``_read``/``_drop``/``_has``) plus
    modeled delays from the TransferModel (zero when none is attached).
    Subclasses that keep bytes elsewhere (e.g. ``hierarchy.DiskSpillBackend``)
    override only the primitives; the protocol surface and all transfer
    accounting stay here."""

    #: hedged duplicate reads enabled for this backend class
    hedgeable = False
    #: fixed per-call link overhead (e.g. an RPC round trip), applied to every
    #: modeled transfer; only meaningful when a TransferModel is attached
    link_overhead_s = 0.0

    def __init__(
        self,
        name: str,
        *,
        transfer: Optional[TransferModel] = None,
        clock: Optional[SimClock] = None,
        hedge: Optional["HedgePolicy"] = None,
        faults: Optional[FaultInjector] = None,
    ):
        self.name = name
        self.transfer = transfer
        self.clock = clock or SimClock()
        self.hedge = hedge
        self.faults = faults
        self._data: Dict[str, Tuple[Any, float]] = {}
        self._checksums: Dict[str, str] = {}

    # -- storage primitives (override to move bytes elsewhere) ----------- #
    def _write(self, key: str, payload: Any, nbytes: float) -> None:
        self._data[key] = (payload, nbytes)

    def _read(self, key: str) -> Tuple[Any, float]:
        try:
            return self._data[key]
        except KeyError:
            raise KeyNotFound(
                f"{type(self).__name__} tier {self.name!r} has no payload "
                f"under key {key!r}",
                tier=self.name, key=key, reason="not_found",
            ) from None

    def _drop(self, key: str) -> bool:
        return self._data.pop(key, None) is not None

    def _has(self, key: str) -> bool:
        return key in self._data

    # -- protocol ------------------------------------------------------- #
    def put(
        self, key: str, payload: Any, nbytes: float, *, charge: bool = True
    ) -> TransferHandle:
        if nbytes < 0:
            raise ValueError(
                f"nbytes must be >= 0, got {nbytes!r} "
                f"(tier {self.name!r}, key {key!r})"
            )
        self._check_brownout(key)
        # stamp the content checksum before the bytes land so get() can
        # verify corruption is detected, never served
        self._checksums[key] = payload_checksum(payload)
        self._write(key, payload, nbytes)
        delay = 0.0
        if self.transfer is not None and charge:
            delay = self.transfer.store_delay(nbytes, self.name) + self.link_overhead_s
        return TransferHandle(
            key=key, tier=self.name, kind="store", nbytes=nbytes,
            delay_s=delay, issued_at_s=self.clock.now,
        )

    def get(
        self, key: str, *, nbytes: Optional[float] = None, charge: bool = True
    ) -> Tuple[Any, TransferHandle]:
        self._check_brownout(key)
        payload, stored_nbytes = self._read(key)
        n = stored_nbytes if nbytes is None else nbytes
        delay = 0.0
        if self.transfer is not None:
            delay = (
                self.transfer.load_delay(n, self.name)
                if charge
                else self.transfer.estimate_load_delay(n, self.name)
            ) + self.link_overhead_s
        delay = self._hedged(delay)
        # injected transient faults fire *after* the transfer was charged:
        # the wasted bytes and delay are real dollars the failure burned
        if self.faults is not None and self.faults.should_fail(self.name, key):
            raise TierUnavailable(
                f"tier {self.name!r} dropped fetch of {key!r} (injected)",
                tier=self.name, key=key, delay_s=delay, wasted_bytes=n,
                reason="unavailable",
            )
        if self.faults is not None and self.faults.should_corrupt(self.name, key):
            raise CorruptPayload(
                f"tier {self.name!r} served corrupt bytes for {key!r} "
                f"(injected in-flight corruption)",
                tier=self.name, key=key, delay_s=delay, wasted_bytes=n,
                reason="corrupt", at_rest=False,
            )
        self._verify(key, payload, delay_s=delay, nbytes=n)
        handle = TransferHandle(
            key=key, tier=self.name, kind="load", nbytes=n,
            delay_s=delay, issued_at_s=self.clock.now,
        )
        return payload, handle

    def delete(self, key: str) -> bool:
        self._checksums.pop(key, None)
        return self._drop(key)

    def contains(self, key: str) -> bool:
        return self._has(key)

    def peek(self, key: str) -> Any:
        return self._read(key)[0]

    def estimate_load_delay(self, nbytes: float) -> float:
        if self.transfer is None:
            return 0.0
        return self._hedged(
            self.transfer.estimate_load_delay(nbytes, self.name)
            + self.link_overhead_s
        )

    # -- internals ------------------------------------------------------ #
    def _check_brownout(self, key: str) -> None:
        """Fail fast (uncharged — no bytes ever moved) while this tier is
        inside an injected brownout window."""
        if self.faults is not None and self.faults.browned_out(
            self.name, self.clock.now
        ):
            raise TierUnavailable(
                f"tier {self.name!r} is browned out at t={self.clock.now:.3f}s "
                f"(key {key!r})",
                tier=self.name, key=key, reason="brownout",
            )

    def _verify(self, key: str, payload: Any, *, delay_s: float,
                nbytes: float) -> None:
        """Compare the served payload against the checksum stamped at put
        time; a mismatch means the stored copy itself rotted (at rest)."""
        want = self._checksums.get(key)
        if want is not None and payload_checksum(payload) != want:
            raise CorruptPayload(
                f"tier {self.name!r} checksum mismatch for {key!r}: stored "
                f"copy is corrupt",
                tier=self.name, key=key, delay_s=delay_s,
                wasted_bytes=nbytes, reason="corrupt_at_rest", at_rest=True,
            )

    def _hedged(self, delay_s: float) -> float:
        if self.hedge is None:
            return delay_s
        return self.hedge.effective_delay(delay_s)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{type(self).__name__}({self.name!r}, {len(self._data)} entries)"


class HostMemoryBackend(_MemoryBackend):
    """Host-DRAM tier of the serving instance itself (PCIe-speed loads)."""

    def __init__(self, name: str = "host_dram", **kw):
        super().__init__(name, **kw)


class ObjectStoreBackend(_MemoryBackend):
    """Remote cloud tier (the paper's EBS io2 / gp3 / S3): delays are
    bandwidth+latency modeled and reads may be hedged against stragglers."""

    hedgeable = True

    def __init__(self, name: str = "io2", **kw):
        super().__init__(name, **kw)


def default_backends(
    tier_names,
    *,
    transfer: Optional[TransferModel] = None,
    clock: Optional[SimClock] = None,
    hedge: Optional["HedgePolicy"] = None,
    faults: Optional[FaultInjector] = None,
) -> Dict[str, StorageBackend]:
    """One backend per tier: host_dram -> HostMemoryBackend (never hedged —
    local reads have no straggler tail), anything else -> ObjectStoreBackend."""
    out: Dict[str, StorageBackend] = {}
    for name in tier_names:
        cls = HostMemoryBackend if name == "host_dram" else ObjectStoreBackend
        out[name] = cls(
            name, transfer=transfer, clock=clock,
            hedge=hedge if cls.hedgeable else None, faults=faults,
        )
    return out
