"""Content-addressed context chunking + prefix trie.

Contexts are identified by a *hash chain* over fixed-size token chunks
(CacheGen/SGLang-style):  ``h_0 = H(chunk_0)``, ``h_i = H(h_{i-1} || chunk_i)``.
Two requests share a stored prefix iff their chain hashes agree — chain
hashing makes a chunk's identity depend on everything before it, which is
exactly the validity condition for reusing attention KV (K/V at position t
depend on all tokens <= t).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_CHUNK_TOKENS = 256


def chunk_hash_chain(tokens: Sequence[int], chunk_tokens: int) -> List[str]:
    """Chain hashes for every *complete* chunk of ``tokens``."""
    toks = np.asarray(tokens, dtype=np.int32)
    n = len(toks) // chunk_tokens
    chain: List[str] = []
    h_prev = b""
    for i in range(n):
        chunk = toks[i * chunk_tokens : (i + 1) * chunk_tokens].tobytes()
        h = hashlib.sha256(h_prev + chunk).hexdigest()[:32]
        chain.append(h)
        h_prev = h.encode()
    return chain


@dataclasses.dataclass
class PrefixMatch:
    entry_id: Optional[str]
    matched_chunks: int
    matched_tokens: int
    total_chunks: int


class ChunkTrie:
    """Maps chain-hash prefixes to stored entries.

    ``insert`` registers a stored context's chain; ``longest_prefix`` walks a
    query's chain and returns the deepest stored node.  O(depth) per lookup,
    no token content retained (privacy: only salted hashes)."""

    def __init__(self, chunk_tokens: int = DEFAULT_CHUNK_TOKENS):
        self.chunk_tokens = chunk_tokens
        # chain hash -> (entry_id, chunk_index within that entry)
        self._nodes: Dict[str, Tuple[str, int]] = {}

    def insert(self, tokens: Sequence[int], entry_id: str) -> List[str]:
        chain = chunk_hash_chain(tokens, self.chunk_tokens)
        for i, h in enumerate(chain):
            # keep the first owner; identical chains are identical content
            self._nodes.setdefault(h, (entry_id, i))
        return chain

    def remove(self, tokens_or_chain: Sequence, entry_id: str) -> None:
        chain = (
            list(tokens_or_chain)
            if tokens_or_chain and isinstance(tokens_or_chain[0], str)
            else chunk_hash_chain(tokens_or_chain, self.chunk_tokens)
        )
        for h in chain:
            if self._nodes.get(h, (None,))[0] == entry_id:
                del self._nodes[h]

    def longest_prefix(self, tokens: Sequence[int]) -> PrefixMatch:
        chain = chunk_hash_chain(tokens, self.chunk_tokens)
        best: Optional[Tuple[str, int]] = None
        depth = 0
        for i, h in enumerate(chain):
            node = self._nodes.get(h)
            if node is None:
                break
            best, depth = node, i + 1
        if best is None:
            return PrefixMatch(None, 0, 0, len(chain))
        return PrefixMatch(best[0], depth, depth * self.chunk_tokens, len(chain))

    def __len__(self) -> int:
        return len(self._nodes)
