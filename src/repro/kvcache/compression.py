"""KV-cache compression for the storage/transfer tier (int8, per-(token,head)).

The paper names KV compression as open design space; we implement one point:
symmetric int8 over the channel dim (2x smaller stored KV => 2x cheaper
storage and 2x faster loads) with a Pallas dequant kernel on the hot load
path (kernels/kv_quant.py).  SSD/conv states stay fp32/bf16 — they are O(1)
sized and numerically load-bearing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass
class CompressedArray:
    q: np.ndarray  # int8 [..., hd]
    scale: np.ndarray  # f32   [..., 1]
    orig_dtype: str

    @property
    def nbytes(self) -> int:
        return self.q.nbytes + self.scale.nbytes


def _is_kv_leaf(x) -> bool:
    # KV tensors are >=2D floating arrays; tiny int/pos leaves pass through.
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2


def compress_tree(tree: Any) -> Any:
    """Quantise every KV-like leaf of a context-state pytree to int8."""

    def leaf(x):
        if not _is_kv_leaf(x):
            return np.asarray(x)
        q, s = ops.kv_quant(jnp.asarray(x))
        return CompressedArray(
            q=np.asarray(q), scale=np.asarray(s), orig_dtype=str(x.dtype)
        )

    return jax.tree_util.tree_map(leaf, tree)


def decompress_tree(tree: Any) -> Any:
    def leaf(x):
        if isinstance(x, CompressedArray):
            return np.asarray(
                ops.kv_dequant(jnp.asarray(x.q), jnp.asarray(x.scale), dtype=x.orig_dtype)
            )
        return x

    return jax.tree_util.tree_map(
        leaf, tree, is_leaf=lambda l: isinstance(l, CompressedArray)
    )


def tree_nbytes(tree: Any) -> int:
    total = 0
    for l in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, CompressedArray)
    ):
        total += l.nbytes if isinstance(l, CompressedArray) else np.asarray(l).nbytes
    return int(total)


def max_abs_error_bound(x: jax.Array) -> jax.Array:
    """Per-row worst-case quantisation error: scale/2 (tested property)."""
    _, s = ops.kv_quant(x)
    return (s / 2.0)[..., 0]
