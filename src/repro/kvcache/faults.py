"""Deterministic fault injection and failure taxonomy for the KV store.

The paper's break-even math assumes every stored-KV fetch succeeds, but the
cloud tiers it prices (gp3/io2/S3/peer RPC) fail, time out, and serve corrupt
bytes in practice — and in this system's own terms failure handling is an
*economics* decision: every retry spends transfer dollars and wall-clock,
every degradation spends compute dollars.  This module supplies the three
pieces the serving stack needs to reason about that:

  * a typed error taxonomy (``KeyNotFound`` / ``TierUnavailable`` /
    ``CorruptPayload``, all under ``StorageError``) so planner and engine can
    branch on *what* failed instead of catching bare ``KeyError``;
  * ``payload_checksum`` — a content checksum every backend ``put`` stamps
    and every ``get`` verifies, so corruption is detected, never served
    (integrity groundwork the KV-marketplace direction needs);
  * ``FaultInjector`` — a seeded, schedule-driven injector that backends
    consult.  Fault draws are *hash-based* (seed × tier × key × op-count),
    not a shared RNG stream, so outcomes are independent of call
    interleaving: the same workload under the same schedule fails at the
    same operations no matter how replicas' steps interleave.
  * ``RetryPolicy`` — per-tier exponential backoff with a cost-aware gate:
    retry only while the expected retry cost (backoff + estimated reload,
    priced at GPU-seconds plus per-GB fees) still beats the marginal cost of
    just recomputing the matched prefix.

Everything here is host-side and optional: with no injector configured the
only behavioral change anywhere is the checksum stamp/verify on put/get.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

_GB = 1024.0 ** 3


# --------------------------------------------------------------------------- #
# Typed storage errors
# --------------------------------------------------------------------------- #
class StorageError(Exception):
    """Base for all typed storage failures.

    Carries enough context to account for the failure honestly: which tier
    and key failed, how much simulated delay the failed attempt consumed
    (already charged to the transfer model where applicable), and how many
    bytes of transfer were wasted.
    """

    def __init__(self, msg: str, *, tier: Optional[str] = None,
                 key: Optional[str] = None, delay_s: float = 0.0,
                 wasted_bytes: float = 0.0, reason: str = ""):
        super().__init__(msg)
        self.tier = tier
        self.key = key
        self.delay_s = float(delay_s)
        self.wasted_bytes = float(wasted_bytes)
        self.reason = reason or type(self).__name__


class KeyNotFound(StorageError, KeyError):
    """The tier has no payload under the key — permanent, not retryable.

    Subclasses ``KeyError`` so pre-existing ``except KeyError`` call sites
    keep working; new code should catch the typed error.
    """


class TierUnavailable(StorageError):
    """Transient tier failure: dropped fetch, RPC timeout, or a brownout
    window.  Retryable — the payload is still intact at the tier."""


class CorruptPayload(StorageError):
    """Checksum mismatch between stored and served bytes.

    ``at_rest=False`` means the corruption happened in flight (a reread can
    succeed → retryable); ``at_rest=True`` means the stored copy itself is
    bad (torn write) — not retryable, the entry must be discarded.
    """

    def __init__(self, msg: str, *, at_rest: bool = False, **kw):
        super().__init__(msg, **kw)
        self.at_rest = at_rest


def retryable(exc: BaseException) -> bool:
    """Whether a retry of the same operation can possibly succeed."""
    if isinstance(exc, KeyNotFound):
        return False
    if isinstance(exc, CorruptPayload) and exc.at_rest:
        return False
    return isinstance(exc, StorageError)


# --------------------------------------------------------------------------- #
# Content checksum
# --------------------------------------------------------------------------- #
def payload_checksum(payload: Any) -> str:
    """Stable content checksum over an arbitrary KV payload pytree.

    Walks tuples/lists/dicts (namedtuples included) and hashes each leaf's
    dtype, shape, and raw bytes; jax arrays are pulled to host first.  Two
    payloads with identical contents hash identically regardless of
    container identity, so dedup'd shared-tier writes agree on the stamp.
    """
    import numpy as np

    h = hashlib.blake2b(digest_size=16)

    def _walk(x: Any) -> None:
        if x is None:
            h.update(b"\x00N")
        elif isinstance(x, dict):
            h.update(b"\x00D%d" % len(x))
            for k in sorted(x, key=repr):
                h.update(repr(k).encode())
                _walk(x[k])
        elif isinstance(x, (tuple, list)):
            h.update(b"\x00T%d" % len(x))
            for v in x:
                _walk(v)
        elif isinstance(x, (bytes, bytearray)):
            h.update(b"\x00B")
            h.update(bytes(x))
        elif isinstance(x, str):
            h.update(b"\x00S")
            h.update(x.encode())
        else:
            a = np.asarray(x)
            if a.dtype == object:
                # opaque leaf: tobytes() would hash memory addresses, which
                # don't survive a pickle round-trip — hash the type instead
                # (content changes inside such leaves are not detectable)
                h.update(b"\x00O")
                h.update(type(x).__qualname__.encode())
            else:
                h.update(b"\x00A")
                h.update(str(a.dtype).encode())
                h.update(repr(a.shape).encode())
                h.update(a.tobytes())

    _walk(payload)
    return h.hexdigest()


# --------------------------------------------------------------------------- #
# Fault schedule pieces
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Brownout:
    """A window during which every operation against ``tier`` fails fast
    with ``TierUnavailable`` (no bytes move, nothing is charged)."""

    tier: str
    start_s: float
    end_s: float

    def active(self, now: float) -> bool:
        return self.start_s <= now < self.end_s


@dataclasses.dataclass(frozen=True)
class CrashPlan:
    """Kill ``replica`` at simulated time ``at_s`` (cluster-level)."""

    replica: int
    at_s: float


class FaultInjector:
    """Seeded, deterministic fault schedule that storage backends consult.

    Rates are probabilities per *operation* (a retry is a fresh draw).  The
    draw for the n-th operation of a given (tier, key, kind) is a pure hash
    of ``(seed, tier, key, kind, n)`` — no shared RNG stream — so whether an
    operation fails does not depend on what other tiers or replicas did in
    between.  Rates can be global floats or per-tier dicts.
    """

    def __init__(self, seed: int = 0, *,
                 fail_rate: Any = 0.0,
                 corrupt_rate: Any = 0.0,
                 brownouts: Sequence[Brownout] = (),
                 crashes: Sequence[CrashPlan] = ()):
        self.seed = int(seed)
        self._fail_rate = fail_rate
        self._corrupt_rate = corrupt_rate
        self.brownouts: List[Brownout] = list(brownouts)
        self._crashes: List[CrashPlan] = sorted(crashes, key=lambda c: c.at_s)
        self._counts: Dict[Tuple[str, str, str], int] = {}
        # observability: what was actually injected
        self.injected_failures = 0
        self.injected_corruptions = 0
        self.brownout_rejections = 0
        self.crashes_fired = 0

    # -- schedule construction -------------------------------------------- #
    def add_brownout(self, tier: str, start_s: float, end_s: float) -> None:
        self.brownouts.append(Brownout(tier, start_s, end_s))

    def schedule_crash(self, replica: int, at_s: float) -> None:
        self._crashes.append(CrashPlan(replica, at_s))
        self._crashes.sort(key=lambda c: c.at_s)

    def arm(self, *, fail_rate: Any = None, corrupt_rate: Any = None) -> None:
        """Swap rates mid-run — e.g. zero through a jit warm wave, then armed
        for the measured wave (the chaos bench's pattern).  Draw counters are
        untouched: each (tier, key, kind) schedule stays deterministic."""
        if fail_rate is not None:
            self._fail_rate = fail_rate
        if corrupt_rate is not None:
            self._corrupt_rate = corrupt_rate

    # -- draws ------------------------------------------------------------- #
    def _rate(self, table: Any, tier: str) -> float:
        if isinstance(table, dict):
            return float(table.get(tier, table.get("*", 0.0)))
        return float(table)

    def _draw(self, tier: str, key: str, kind: str) -> float:
        """Uniform [0, 1) draw for this operation, advancing the per-(tier,
        key, kind) op counter so repeated attempts redraw independently."""
        k = (tier, key, kind)
        n = self._counts.get(k, 0)
        self._counts[k] = n + 1
        msg = f"{self.seed}|{tier}|{key}|{kind}|{n}".encode()
        h = hashlib.blake2b(msg, digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    # -- queries backends make -------------------------------------------- #
    def browned_out(self, tier: str, now: float) -> bool:
        hit = any(b.tier == tier and b.active(now) for b in self.brownouts)
        if hit:
            self.brownout_rejections += 1
        return hit

    def should_fail(self, tier: str, key: str) -> bool:
        p = self._rate(self._fail_rate, tier)
        if p > 0.0 and self._draw(tier, key, "fail") < p:
            self.injected_failures += 1
            return True
        return False

    def should_corrupt(self, tier: str, key: str) -> bool:
        p = self._rate(self._corrupt_rate, tier)
        if p > 0.0 and self._draw(tier, key, "corrupt") < p:
            self.injected_corruptions += 1
            return True
        return False

    # -- crash schedule (cluster polls this each step) --------------------- #
    def due_crashes(self, now: float) -> List[CrashPlan]:
        """Pop and return every scheduled crash with ``at_s <= now``."""
        due = [c for c in self._crashes if c.at_s <= now]
        if due:
            self._crashes = [c for c in self._crashes if c.at_s > now]
            self.crashes_fired += len(due)
        return due

    def stats(self) -> Dict[str, int]:
        return {
            "injected_failures": self.injected_failures,
            "injected_corruptions": self.injected_corruptions,
            "brownout_rejections": self.brownout_rejections,
            "crashes_fired": self.crashes_fired,
        }


# --------------------------------------------------------------------------- #
# Cost-aware retry policy
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-tier exponential backoff with a cost-aware stop rule.

    ``max_attempts`` bounds total tries (first attempt included).  Before
    attempt ``n+1`` the engine waits ``backoff(n)`` and — when ``cost_aware``
    — retries only while the expected retry cost (backoff + estimated
    reload delay at GPU-second pricing, plus the per-GB refetch fee) still
    beats the marginal cost of recomputing the matched prefix.  Permanent
    failures (``KeyNotFound``, at-rest ``CorruptPayload``) never retry.
    """

    max_attempts: int = 3
    backoff_s: float = 0.005
    backoff_factor: float = 2.0
    cost_aware: bool = True
    tier_max_attempts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def attempts_for(self, tier: Optional[str]) -> int:
        if tier is not None and tier in self.tier_max_attempts:
            return self.tier_max_attempts[tier]
        return self.max_attempts

    def backoff(self, attempt: int) -> float:
        """Wait before attempt ``attempt + 1`` (attempt is 1-based)."""
        return self.backoff_s * self.backoff_factor ** max(0, attempt - 1)

    def retry_cost(self, *, backoff_s: float, est_load_s: float,
                   nbytes: float, gpu_cost_per_s: float,
                   per_gb_fee: float) -> float:
        """Expected dollars spent if we try again: the time the accelerator
        sits idle through backoff + reload, plus the refetch's transfer fee."""
        return gpu_cost_per_s * (backoff_s + est_load_s) \
            + per_gb_fee * nbytes / _GB

    def should_retry(self, exc: BaseException, attempt: int, *,
                     tier: Optional[str] = None,
                     retry_cost: float = 0.0,
                     recompute_cost: float = float("inf")) -> bool:
        if not retryable(exc):
            return False
        if attempt >= self.attempts_for(tier if tier is not None
                                        else getattr(exc, "tier", None)):
            return False
        if self.cost_aware and retry_cost >= recompute_cost:
            return False
        return True
