"""Fused non-prefix reuse: chunk-composite KV matching + selective recompute.

The chain-hash trie (``kvcache.chunks``) only reuses *prefix* matches: a RAG
request that retrieves the same document chunks in a different order shares
no chain prefix and recomputes everything.  CacheBlend's observation is that
the stored KV of a text chunk is *approximately* position- and
context-independent — reusing it out of place and selectively recomputing a
small fraction of high-deviation tokens recovers almost all of the quality at
a fraction of the prefill compute.

This module is the content side of that subsystem:

  * ``content_hashes`` / ``ChunkIndex`` — a position-independent per-chunk
    content index maintained alongside the chain-hash trie: each complete
    chunk is keyed by a hash of its *own* tokens only, so a stored chunk is
    findable at any offset of any query.
  * ``CompositeMatch`` — the index's answer for one query context: a span
    partition into maximal reused runs (with their source entry + source row
    offset) and recompute gaps.
  * ``select_recompute`` — CacheBlend's r-fraction knob: picks exactly
    ``ceil(r * matched_tokens)`` tokens inside the reused spans to recompute
    (the *head* of each span — the cross-chunk boundary tokens whose KV
    deviates most), yielding a ``FusedSchedule`` of execution spans.
  * ``fused_layout`` / ``fused_arrays`` / ``build_fused_caches`` — the
    host-side assembly for the selective-recompute prefill launch
    (``kernels/fused_prefill.py``): one KV buffer in query order with reused
    rows preloaded (K re-aligned to its target position by delta-RoPE) and
    index arrays for the scattered recompute queries.

At ``recompute_frac=1.0`` every reused token is recomputed, so the fused
launch degenerates to an ordinary full prefill — the bit-exactness anchor
``tests/test_fusion.py`` pins at kernel/model/engine level.  At r < 1 the
output is an approximation (the reused KV misses cross-chunk attention), the
same contract as the lossy int8 storage tier.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kvcache.chunks import DEFAULT_CHUNK_TOKENS


def content_hashes(tokens: Sequence[int], chunk_tokens: int) -> List[str]:
    """Position-independent hash for every *complete* chunk of ``tokens``
    (cf. ``chunks.chunk_hash_chain``, whose hashes chain over everything
    before the chunk — here a chunk's identity is its own content only)."""
    toks = np.asarray(tokens, dtype=np.int32)
    n = len(toks) // chunk_tokens
    return [
        hashlib.sha256(
            b"chunk:" + toks[i * chunk_tokens : (i + 1) * chunk_tokens].tobytes()
        ).hexdigest()[:32]
        for i in range(n)
    ]


# --------------------------------------------------------------------------- #
# Spans / match
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FusedSpan:
    """One token range of a query context: either served from a stored
    entry's rows (``reuse``) or prefilled from scratch (``recompute``)."""

    start: int  # query-context token range [start, end)
    end: int
    kind: str  # "reuse" | "recompute"
    entry_id: Optional[str] = None  # reuse spans: the source entry...
    src_start: int = -1  # ...and the row offset inside it
    chunk_hashes: Tuple[str, ...] = ()  # content hashes (chunk-aligned spans)

    @property
    def n_tokens(self) -> int:
        return self.end - self.start


def rows_by_entry(spans: Sequence[FusedSpan]) -> Dict[str, int]:
    """entry_id -> total reused rows it sources across ``spans`` — the one
    aggregation planners (fetch-byte pricing) and the engine (fetch billing)
    both consume."""
    out: Dict[str, int] = {}
    for s in spans:
        if s.kind == "reuse":
            out[s.entry_id] = out.get(s.entry_id, 0) + s.n_tokens
    return out


@dataclasses.dataclass(frozen=True)
class CompositeMatch:
    """The chunk index's view of one query context: an ordered span
    partition of ``[0, total_tokens)`` into maximal reused runs (adjacent
    matched chunks from the same entry at consecutive source rows merge)
    and recompute gaps (unmatched chunks + the trailing partial chunk)."""

    spans: Tuple[FusedSpan, ...]
    total_tokens: int
    chunk_tokens: int

    @property
    def matched_tokens(self) -> int:
        return sum(s.n_tokens for s in self.spans if s.kind == "reuse")

    @property
    def reuse_spans(self) -> Tuple[FusedSpan, ...]:
        return tuple(s for s in self.spans if s.kind == "reuse")

    @property
    def source_entries(self) -> Tuple[str, ...]:
        return tuple(rows_by_entry(self.spans))

    def rows_by_entry(self) -> Dict[str, int]:
        return rows_by_entry(self.spans)

    @property
    def coverage(self) -> float:
        return self.matched_tokens / max(self.total_tokens, 1)

    @staticmethod
    def miss(total_tokens: int, chunk_tokens: int) -> "CompositeMatch":
        spans = (
            (FusedSpan(0, total_tokens, "recompute"),) if total_tokens else ()
        )
        return CompositeMatch(spans, total_tokens, chunk_tokens)


class ChunkIndex:
    """Content-hash -> owner list map over stored contexts.

    The position-independent sibling of ``chunks.ChunkTrie``: ``insert``
    registers every complete chunk of a stored context under its content
    hash, ``match`` walks a query's chunks and assembles a
    :class:`CompositeMatch`.  Identical content may live in several entries;
    every owner is kept (matches use the earliest-registered one) so
    evicting one entry does not orphan a chunk another live entry still
    holds.  O(chunks) per call, token content never retained."""

    def __init__(self, chunk_tokens: int = DEFAULT_CHUNK_TOKENS):
        self.chunk_tokens = chunk_tokens
        # content hash -> [(entry_id, chunk index within that entry), ...]
        # in registration order; [0] is the owner served by ``match``
        self._nodes: Dict[str, List[Tuple[str, int]]] = {}

    def insert(self, tokens: Sequence[int], entry_id: str) -> List[str]:
        hashes = content_hashes(tokens, self.chunk_tokens)
        for i, h in enumerate(hashes):
            self._nodes.setdefault(h, []).append((entry_id, i))
        return hashes

    def remove(self, hashes_or_tokens: Sequence, entry_id: str) -> None:
        hashes = (
            list(hashes_or_tokens)
            if hashes_or_tokens and isinstance(hashes_or_tokens[0], str)
            else content_hashes(hashes_or_tokens, self.chunk_tokens)
        )
        for h in hashes:
            owners = self._nodes.get(h)
            if owners is None:
                continue
            owners[:] = [o for o in owners if o[0] != entry_id]
            if not owners:
                del self._nodes[h]

    def match(self, tokens: Sequence[int]) -> CompositeMatch:
        c = self.chunk_tokens
        total = len(tokens)
        hashes = content_hashes(tokens, c)
        spans: List[FusedSpan] = []

        def add_recompute(start: int, end: int) -> None:
            if end <= start:
                return
            if spans and spans[-1].kind == "recompute":
                spans[-1] = dataclasses.replace(spans[-1], end=end)
            else:
                spans.append(FusedSpan(start, end, "recompute"))

        for i, h in enumerate(hashes):
            owners = self._nodes.get(h)
            start = i * c
            if not owners:
                add_recompute(start, start + c)
                continue
            eid, src_chunk = owners[0]
            prev = spans[-1] if spans else None
            if (
                prev is not None
                and prev.kind == "reuse"
                and prev.entry_id == eid
                and prev.end == start
                and prev.src_start + prev.n_tokens == src_chunk * c
            ):
                # consecutive source chunks: extend the maximal run
                spans[-1] = dataclasses.replace(
                    prev, end=start + c, chunk_hashes=prev.chunk_hashes + (h,)
                )
            else:
                spans.append(
                    FusedSpan(
                        start, start + c, "reuse", entry_id=eid,
                        src_start=src_chunk * c, chunk_hashes=(h,),
                    )
                )
        add_recompute(len(hashes) * c, total)  # trailing partial chunk
        return CompositeMatch(tuple(spans), total, c)

    def __len__(self) -> int:
        return len(self._nodes)


# --------------------------------------------------------------------------- #
# Selective recompute: the r-fraction schedule
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FusedSchedule:
    """A :class:`CompositeMatch` refined by the chosen recompute fraction:
    the execution span partition (reused tails + recompute heads/gaps), with
    exactly ``ceil(r * matched_tokens)`` tokens selected for recompute
    inside the match's reused spans."""

    match: CompositeMatch
    recompute_frac: float
    spans: Tuple[FusedSpan, ...]  # execution spans, still a partition
    reused_tokens: int  # context tokens served from stored KV
    recompute_tokens: int  # context tokens prefilled (selected + unmatched)
    selected_tokens: int  # == ceil(r * match.matched_tokens)

    @property
    def source_entries(self) -> Tuple[str, ...]:
        return tuple(rows_by_entry(self.spans))

    def rows_by_entry(self) -> Dict[str, int]:
        return rows_by_entry(self.spans)


def select_recompute(match: CompositeMatch, recompute_frac: float) -> FusedSchedule:
    """Pick ``ceil(r * matched_tokens)`` tokens of the reused spans to
    recompute and return the execution schedule.

    Selection is deterministic: the budget is apportioned across reused
    spans proportionally (floor + largest-remainder, ties to earlier spans)
    and each span recomputes its *head* — the tokens right after a content
    discontinuity, whose KV deviates most from the stored values (the
    CacheBlend heuristic, made deterministic).  At r=1.0 every reused token
    is selected and the schedule is one big recompute span: the fused launch
    is then an ordinary full prefill (the bit-exactness anchor)."""
    r = min(max(float(recompute_frac), 0.0), 1.0)
    reuse_spans = match.reuse_spans
    m_total = match.matched_tokens
    budget = int(math.ceil(r * m_total))

    heads = {id(s): int(math.floor(r * s.n_tokens)) for s in reuse_spans}
    rem = budget - sum(heads.values())
    if rem > 0:
        by_frac = sorted(
            enumerate(reuse_spans),
            key=lambda t: (-(r * t[1].n_tokens - heads[id(t[1])]), t[0]),
        )
        for _, s in by_frac[:rem]:
            heads[id(s)] += 1

    out: List[FusedSpan] = []

    def add(span: FusedSpan) -> None:
        if span.n_tokens <= 0:
            return
        if (
            out
            and span.kind == "recompute"
            and out[-1].kind == "recompute"
            and out[-1].end == span.start
        ):
            out[-1] = dataclasses.replace(out[-1], end=span.end)
        else:
            out.append(span)

    for s in match.spans:
        if s.kind == "recompute":
            add(s)
            continue
        k = heads[id(s)]
        if k > 0:
            add(FusedSpan(s.start, s.start + k, "recompute"))
        if k < s.n_tokens:
            # chunk hashes no longer line up with a head-trimmed span
            add(
                FusedSpan(
                    s.start + k, s.end, "reuse",
                    entry_id=s.entry_id, src_start=s.src_start + k,
                )
            )
    reused = sum(s.n_tokens for s in out if s.kind == "reuse")
    return FusedSchedule(
        match=match,
        recompute_frac=r,
        spans=tuple(out),
        reused_tokens=reused,
        recompute_tokens=match.total_tokens - reused,
        selected_tokens=budget,
    )


# --------------------------------------------------------------------------- #
# Launch assembly: layout, index arrays, KV buffers
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FusedLayout:
    """Geometry of one fused prefill launch (context + prompt)."""

    total: int  # context + prompt tokens == valid kv rows after the launch
    n_q: int  # recompute context tokens + prompt tokens (query side)
    q_len: int  # bucketed q length (power-of-two jit bucket)
    kv_len: int  # bucketed kv length (align-multiple, whole-block landable)


def fused_layout(
    schedule: FusedSchedule,
    n_prompt: int,
    *,
    align: int = 128,
    bucket_min: int = 16,
) -> FusedLayout:
    from repro.kvcache.paged import pack_bucket

    total = schedule.match.total_tokens + n_prompt
    n_q = schedule.recompute_tokens + n_prompt
    assert n_q >= 1, "fused launch needs at least one query token"
    kv_needed = -(-total // align) * align
    return FusedLayout(
        total=total,
        n_q=n_q,
        q_len=pack_bucket(n_q, bucket_min),
        kv_len=pack_bucket(kv_needed, max(align, bucket_min)),
    )


def fused_arrays(
    schedule: FusedSchedule,
    ctx_tokens: Sequence[int],
    prompt_tokens: Sequence[int],
    layout: FusedLayout,
) -> dict:
    """Host-side int32 index arrays for the fused launch: the recompute
    tokens (context gaps/heads in order, then the whole prompt), their
    absolute positions (``q_pos`` — also the buffer row each token's new KV
    lands in, ``q_rows``; padding lands on the dropped scratch row), and the
    kv-row validity positions (``kv_pos = 0..total``, -1 beyond)."""
    Sq, Skv = layout.q_len, layout.kv_len
    tokens = np.zeros((1, Sq), np.int32)
    q_pos = np.full((1, Sq), -(2**30), np.int32)
    q_rows = np.full((1, Sq), Skv, np.int32)  # padding -> scratch row
    kv_pos = np.full((1, Skv), -1, np.int32)
    kv_pos[0, : layout.total] = np.arange(layout.total, dtype=np.int32)

    n_ctx = schedule.match.total_tokens
    off = 0
    for s in schedule.spans:
        if s.kind != "recompute":
            continue
        n = s.n_tokens
        tokens[0, off : off + n] = np.asarray(
            ctx_tokens[s.start : s.end], np.int32
        )
        q_pos[0, off : off + n] = np.arange(s.start, s.end, dtype=np.int32)
        off += n
    n_p = len(prompt_tokens)
    tokens[0, off : off + n_p] = np.asarray(prompt_tokens, np.int32)
    q_pos[0, off : off + n_p] = np.arange(n_ctx, n_ctx + n_p, dtype=np.int32)
    off += n_p
    assert off == layout.n_q, (off, layout)
    q_rows[0, : layout.n_q] = q_pos[0, : layout.n_q]
    return {
        "tokens": tokens, "q_pos": q_pos, "q_rows": q_rows, "kv_pos": kv_pos,
        "last_idx": np.asarray([layout.n_q - 1], np.int32),
    }


def _delta_rope(k_rows: np.ndarray, delta: int, theta: float) -> np.ndarray:
    """Re-align stored (already-RoPE'd) K rows from their source position to
    their target position: RoPE rotations compose, so applying RoPE at the
    constant position *delta* rotates K(src) into K(src + delta) == K(dst).
    V carries no positional encoding and moves as-is."""
    import jax.numpy as jnp

    from repro.models.layers import apply_rope

    P, n, KV, hd = k_rows.shape
    pos = np.full((P, n), delta, np.int32)
    out = apply_rope(jnp.asarray(k_rows), jnp.asarray(pos), theta)
    return np.asarray(out)


def build_fused_caches(
    cfg: Any,
    schedule: FusedSchedule,
    sources: Dict[str, Any],
    kv_len: int,
    dtype=None,
) -> Any:
    """Per-layer KV buffers for the fused launch, ``[n_periods, 1, kv_len,
    KV, hd]``, with every reuse span's stored rows preloaded at its query
    offset — the non-prefix analogue of ``paged.build_packed_caches``.
    ``sources[entry_id]`` is that entry's fetched LMState artifact; K rows
    placed at a different position than they were stored at are re-aligned
    by delta-RoPE.  Recompute rows stay zero: the kernel scatters their
    fresh K/V before attending (at r=1.0 it overwrites everything, which is
    why the fused launch is then bit-identical to a plain full prefill)."""
    import jax.numpy as jnp

    from repro.models import common as common_mod
    from repro.models.attention import KVCache
    from repro.models.blocks import BlockCache
    from repro.kvcache.paged import _attn_kinds

    kinds, n_periods = _attn_kinds(cfg)
    dtype = dtype or common_mod.resolve_dtype(cfg.dtype)
    np_dtype = np.dtype(jnp.zeros((), dtype).dtype.name)
    shape = (n_periods, 1, kv_len, cfg.n_kv_heads, cfg.resolved_head_dim)

    out = []
    for ki in range(len(kinds)):
        k_buf = np.zeros(shape, np_dtype)
        v_buf = np.zeros(shape, np_dtype)
        for s in schedule.spans:
            if s.kind != "reuse":
                continue
            art = sources[s.entry_id]
            src = slice(s.src_start, s.src_start + s.n_tokens)
            k_rows = np.asarray(art.caches[ki].attn.k[:, 0, src], np_dtype)
            v_rows = np.asarray(art.caches[ki].attn.v[:, 0, src], np_dtype)
            delta = s.start - s.src_start
            if delta != 0 and cfg.rope_theta is not None:
                k_rows = _delta_rope(k_rows, delta, cfg.rope_theta).astype(np_dtype)
            dst = slice(s.start, s.end)
            k_buf[:, 0, dst] = k_rows
            v_buf[:, 0, dst] = v_rows
        out.append(
            BlockCache(KVCache(jnp.asarray(k_buf), jnp.asarray(v_buf)), None)
        )
    return tuple(out)
