"""Tiered KV storage hierarchy: capacity-bounded tiers, contended links, and
economics-driven migration.

The paper's central claim is that reuse economics hinge on *where* a KV cache
sits — compute vs. storage vs. network pricing across device/host/disk/object
tiers.  This module turns the flat two-backend store into an ordered
hierarchy:

    host_dram  ->  local_nvme  ->  io2 / gp3  ->  s3 / peer_dram
    (fastest, most expensive $/GB-hour)    (slowest, cheapest)

Pieces:

  * ``TierSpec``                  — declarative tier: capacity, link
    concurrency limit, backend kind.
  * ``DiskSpillBackend``          — local-NVMe tier whose payloads actually
    leave process memory (pickled to files); delays via the TransferModel.
  * ``RpcBackend``                — modeled remote peer (the "Can I Buy Your
    KV Cache?" setting): peer-DRAM pricing plus per-call RPC round trips.
  * ``ConcurrencyLimitedBackend`` — wraps any backend with a k-server link:
    bursty loads accrue queueing delay on their ``TransferHandle``s
    (``queue_s``) instead of fetching for free in parallel.
  * ``TieredStore``               — the store itself: content-addressed trie,
    per-tier byte/GB-hour accounting, cost-aware eviction, **pinning** (an
    in-flight prefetch cannot be evicted or demoted), spill-on-pressure, and
    a clock-driven migration pass.
  * ``BreakEvenMigrator``         — promotion/demotion policy from the
    paper's break-even math: an entry belongs in the tier minimizing
    ``hold $/h + reuse_freq x (GPU-idle $ per fetch + per-GB fees)``.
  * ``TierMigration``             — typed record of one migration, consumed
    by the serving engine's ``TierMigrated`` event.

``kvcache.store.ContextStore`` is a thin backward-compatible wrapper over
``TieredStore``; with a single-tier hierarchy, no concurrency limits, and no
migration policy the two are behaviorally identical (golden-parity tested).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import math
import os
import pathlib
import pickle
import shutil
import tempfile
import weakref
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.pricing import GB, Pricing
from repro.kvcache import compression
from repro.kvcache.backend import (
    HostMemoryBackend,
    ObjectStoreBackend,
    StorageBackend,
    _MemoryBackend,
)
from repro.kvcache.faults import (
    CorruptPayload,
    FaultInjector,
    KeyNotFound,
    StorageError,
    payload_checksum,
)
from repro.kvcache.chunks import ChunkTrie, PrefixMatch
from repro.kvcache.fusion import ChunkIndex, CompositeMatch
from repro.kvcache.transfer import SimClock, TransferHandle, TransferModel

# Storage rate assumed by eviction/migration scoring when no Pricing is
# plumbed in (io2's ~$0.125/GB-month); callers with real catalogs pass
# ``pricing=``.
_FALLBACK_GB_HOUR_RATE = 1.7e-4


# --------------------------------------------------------------------------- #
# Tier declaration
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One level of the hierarchy, fastest-first in the store's tier list."""

    name: str
    capacity_gb: float
    # Max simultaneous transfers on this tier's link; None = uncontended.
    concurrency: Optional[int] = None
    # Backend kind override: "host" | "disk" | "rpc" | "object".
    # Default is inferred from the tier name.
    backend: Optional[str] = None


def _default_kind(name: str) -> str:
    if name == "host_dram":
        return "host"
    if name == "local_nvme":
        return "disk"
    if name.startswith(("peer", "rpc")):
        return "rpc"
    return "object"


# --------------------------------------------------------------------------- #
# New backends
# --------------------------------------------------------------------------- #
class DiskSpillBackend(_MemoryBackend):
    """Local-NVMe spill tier: payloads genuinely leave process memory
    (pickled to files under ``root``); transfer delays are modeled from the
    ``local_nvme`` pricing tier like any other backend."""

    hedgeable = False  # local device: no straggler tail to hedge

    def __init__(self, name: str = "local_nvme", *, root=None, **kw):
        super().__init__(name, **kw)
        if root is not None:
            self.root = pathlib.Path(root)
            self.root.mkdir(parents=True, exist_ok=True)
        else:
            # we own the default spill dir: reclaim it when the backend dies
            self.root = pathlib.Path(tempfile.mkdtemp(prefix=f"kvspill-{name}-"))
            weakref.finalize(self, shutil.rmtree, str(self.root), True)
        self._nbytes: Dict[str, float] = {}

    def _path(self, key: str) -> pathlib.Path:
        return self.root / (hashlib.sha1(key.encode()).hexdigest() + ".pkl")

    # -- storage primitives --------------------------------------------- #
    def _write(self, key: str, payload: Any, nbytes: float) -> None:
        # atomic spill (same temp-file + rename discipline as
        # training/checkpoint.py): a crash mid-write can leave a stray temp
        # file but never a torn payload under the final name.  The record
        # embeds the content checksum put() stamped so a later process (or a
        # corrupted-at-rest file) is caught on load, not served.
        path = self._path(key)
        record = {"payload": payload, "checksum": self._checksums.get(key)}
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(record, f)
            os.replace(tmp, path)
        except BaseException:
            pathlib.Path(tmp).unlink(missing_ok=True)
            raise
        self._nbytes[key] = nbytes

    def _read(self, key: str) -> Tuple[Any, float]:
        if key not in self._nbytes:
            raise KeyNotFound(
                f"{type(self).__name__} tier {self.name!r} has no payload "
                f"under key {key!r}",
                tier=self.name, key=key, reason="not_found",
            )
        try:
            with open(self._path(key), "rb") as f:
                record = pickle.load(f)
        except FileNotFoundError:
            raise KeyNotFound(
                f"{type(self).__name__} tier {self.name!r} lost the spill "
                f"file for key {key!r}",
                tier=self.name, key=key, reason="not_found",
            ) from None
        except (pickle.UnpicklingError, EOFError, OSError) as e:
            raise CorruptPayload(
                f"tier {self.name!r} spill file for {key!r} is unreadable "
                f"({e}): torn or corrupted at rest",
                tier=self.name, key=key, reason="corrupt_at_rest",
                at_rest=True,
            ) from None
        payload, want = record["payload"], record.get("checksum")
        if want is not None and payload_checksum(payload) != want:
            raise CorruptPayload(
                f"tier {self.name!r} spill file for {key!r} fails its "
                f"embedded checksum: corrupted at rest",
                tier=self.name, key=key, reason="corrupt_at_rest",
                at_rest=True,
            )
        return payload, self._nbytes[key]

    def _drop(self, key: str) -> bool:
        if self._nbytes.pop(key, None) is None:
            return False
        self._path(key).unlink(missing_ok=True)
        return True

    def _has(self, key: str) -> bool:
        return key in self._nbytes

    def clear(self) -> None:
        for key in list(self._nbytes):
            self._drop(key)


class RpcBackend(_MemoryBackend):
    """Modeled remote-peer tier (a sibling serving instance selling its KV
    cache): bytes priced/timed as ``peer_dram`` through the shared
    TransferModel, plus a fixed RPC round trip per call.  Remote reads have a
    straggler tail, so hedging applies."""

    hedgeable = True

    def __init__(self, name: str = "peer_dram", *, rtt_s: float = 2e-4, **kw):
        super().__init__(name, **kw)
        self.rtt_s = rtt_s
        self.link_overhead_s = rtt_s


class ConcurrencyLimitedBackend:
    """k-server link in front of any backend: at most ``limit`` transfers are
    in flight at once; excess transfers wait for the earliest free slot, and
    the wait is carried on the handle (``queue_s``, included in ``delay_s``).

    Reservations are keyed to the shared SimClock, so a burst of fetches
    issued at the same instant queue behind each other — the "fetching for
    free in parallel" failure mode of the uncontended model."""

    def __init__(self, inner: StorageBackend, limit: int, *, clock: Optional[SimClock] = None):
        assert limit >= 1, limit
        self.inner = inner
        self.limit = int(limit)
        self.clock = clock or inner.clock
        self._busy_until: List[float] = []  # min-heap of in-flight completions

    # -- queueing ------------------------------------------------------- #
    def _prune(self, now: float) -> None:
        while self._busy_until and self._busy_until[0] <= now:
            heapq.heappop(self._busy_until)

    def _wait(self, now: float, heap: Optional[List[float]] = None) -> float:
        """Wait until a server frees (0 if one is free now).  ``heap`` — an
        alternative busy-until heap to evaluate against (a simulated copy for
        planning); defaults to, and prunes, the live link state."""
        if heap is None:
            self._prune(now)
            heap = self._busy_until
        if len(heap) < self.limit:
            return 0.0
        k = len(heap) - self.limit + 1
        return max(0.0, heapq.nsmallest(k, heap)[-1] - now)

    def _reserve(self, service_s: float) -> float:
        now = self.clock.now
        wait = self._wait(now)
        heapq.heappush(self._busy_until, now + wait + service_s)
        return wait

    def estimated_wait(self, nbytes: float, pending: Sequence[float] = ()) -> float:
        """Predicted queueing delay for a fetch issued now (no reservation) —
        the planning/economics surface.  ``pending`` lists byte sizes of
        fetches that will hit this link at the same instant AHEAD of this one
        (earlier members of an admission batch): their reservations are
        simulated on a copy of the link state so batch-mates see each other's
        queueing at plan time, not just transfers already in flight."""
        now = self.clock.now
        if not pending:
            return self._wait(now)
        self._prune(now)
        heap = list(self._busy_until)  # already heap-ordered; real state untouched
        for nb in pending:
            w = self._wait(now, heap)
            heapq.heappush(heap, now + w + self.inner.estimate_load_delay(nb))
        return self._wait(now, heap)

    def in_flight(self) -> int:
        self._prune(self.clock.now)
        return len(self._busy_until)

    # -- StorageBackend protocol (delegate + queue) ---------------------- #
    @property
    def name(self) -> str:
        return self.inner.name

    def put(self, key, payload, nbytes, *, charge: bool = True, **kw):
        h = self.inner.put(key, payload, nbytes, charge=charge, **kw)
        wait = self._reserve(h.delay_s)
        if wait == 0.0:
            return h
        return dataclasses.replace(h, delay_s=h.delay_s + wait, queue_s=wait)

    def get(self, key, *, nbytes=None, charge: bool = True):
        payload, h = self.inner.get(key, nbytes=nbytes, charge=charge)
        wait = self._reserve(h.delay_s)
        if wait == 0.0:
            return payload, h
        return payload, dataclasses.replace(h, delay_s=h.delay_s + wait, queue_s=wait)

    def delete(self, key) -> bool:
        return self.inner.delete(key)

    def contains(self, key) -> bool:
        return self.inner.contains(key)

    def peek(self, key):
        return self.inner.peek(key)

    def estimate_load_delay(self, nbytes: float) -> float:
        return self.inner.estimate_load_delay(nbytes)

    def __getattr__(self, attr):
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(attr)
        return getattr(inner, attr)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"ConcurrencyLimited({self.inner!r}, limit={self.limit})"


class SharedBackendCore:
    """Content-addressed payload pool behind a tier SHARED by several stores
    (the cluster's cold tier: every replica's s3 backend is a view onto one
    of these).  Ownership is refcounted per content id: each namespaced key
    (one replica's entry) holds one reference, and the payload bytes die only
    when the last reference drops — so one replica evicting (or crashing out
    of the cluster) can never orphan an entry another replica still holds.

    Identical content written by two replicas is stored ONCE: the second
    write is a dedup hit (no bytes move, no fee).  Capacity/GB-hour
    accounting stays per-store (each owner is billed for its logical bytes);
    the cluster-level dedup saving is surfaced via ``stats()`` rather than
    silently altering any store's bill."""

    def __init__(self):
        # content id -> (payload, nbytes); one copy per distinct content
        self._contents: Dict[str, Tuple[Any, float]] = {}
        self._refs: Dict[str, int] = {}
        # namespaced key (one store's entry) -> content id it references
        self._keys: Dict[str, str] = {}
        self.dedup_hits = 0

    def write(self, key: str, cid: str, payload: Any, nbytes: float) -> bool:
        """Bind ``key`` to content ``cid``.  Returns True when the bytes were
        already resident (dedup: the caller's upload is a no-op)."""
        old = self._keys.get(key)
        if old is not None:
            self._release(old)
        dedup = cid in self._contents
        if dedup:
            self.dedup_hits += 1
        else:
            self._contents[cid] = (payload, nbytes)
        self._keys[key] = cid
        self._refs[cid] = self._refs.get(cid, 0) + 1
        return dedup

    def read(self, key: str) -> Tuple[Any, float]:
        return self._contents[self._keys[key]]

    def has(self, key: str) -> bool:
        return key in self._keys

    def drop(self, key: str) -> bool:
        cid = self._keys.pop(key, None)
        if cid is None:
            return False
        self._release(cid)
        return True

    def _release(self, cid: str) -> None:
        n = self._refs.get(cid, 0) - 1
        if n <= 0:
            self._refs.pop(cid, None)
            self._contents.pop(cid, None)
        else:
            self._refs[cid] = n

    def drop_namespace(self, prefix: str) -> int:
        """Release every key under ``prefix`` (a replica leaving the
        cluster); shared payloads survive while other replicas hold them."""
        victims = [k for k in self._keys if k.startswith(prefix)]
        for k in victims:
            self.drop(k)
        return len(victims)

    def stats(self) -> Dict[str, float]:
        resident = sum(nb for _, nb in self._contents.values())
        logical = sum(self._contents[c][1] for c in self._keys.values())
        return {
            "n_contents": len(self._contents),
            "n_keys": len(self._keys),
            "resident_bytes": resident,
            "logical_bytes": logical,
            "dedup_saved_bytes": logical - resident,
            "dedup_hits": self.dedup_hits,
        }


class SharedTierBackend(ObjectStoreBackend):
    """One store's view onto a :class:`SharedBackendCore`: keys are
    namespaced per owner (``r0:ctx3``), transfer delays/fees bill through the
    OWNER's TransferModel/clock, and writes whose content already sits in the
    core complete instantly with a ``dedup`` handle (the bytes never move).
    ``TieredStore`` passes each entry's token-content id via ``put``'s
    ``content=`` kwarg when the backend advertises ``content_addressed``."""

    content_addressed = True

    def __init__(self, name: str = "s3", *, core: SharedBackendCore,
                 namespace: str = "", **kw):
        super().__init__(name, **kw)
        self.core = core
        self.namespace = namespace

    def _key(self, key: str) -> str:
        return f"{self.namespace}:{key}" if self.namespace else key

    def put(self, key, payload, nbytes, *, charge: bool = True,
            content: Optional[str] = None):
        if nbytes < 0:
            raise ValueError(
                f"nbytes must be >= 0, got {nbytes!r} "
                f"(tier {self.name!r}, key {key!r})"
            )
        self._check_brownout(key)
        # same stamp-before-write contract as _MemoryBackend.put (this
        # override bypasses it); identical content hashes identically, so
        # dedup'd writes agree on the stamp
        self._checksums[key] = payload_checksum(payload)
        cid = content if content is not None else self._key(key)
        if self.core.write(self._key(key), cid, payload, nbytes):
            # identical bytes already resident service-wide: free write
            return TransferHandle(
                key=key, tier=self.name, kind="store", nbytes=0.0,
                delay_s=0.0, issued_at_s=self.clock.now, dedup=True,
            )
        delay = 0.0
        if self.transfer is not None and charge:
            delay = self.transfer.store_delay(nbytes, self.name) + self.link_overhead_s
        return TransferHandle(
            key=key, tier=self.name, kind="store", nbytes=nbytes,
            delay_s=delay, issued_at_s=self.clock.now,
        )

    # -- storage primitives route through the shared core ---------------- #
    def _write(self, key: str, payload: Any, nbytes: float) -> None:
        self.core.write(self._key(key), self._key(key), payload, nbytes)

    def _read(self, key: str) -> Tuple[Any, float]:
        try:
            return self.core.read(self._key(key))
        except KeyError:
            raise KeyNotFound(
                f"{type(self).__name__} tier {self.name!r} has no payload "
                f"under key {key!r}",
                tier=self.name, key=key, reason="not_found",
            ) from None

    def _drop(self, key: str) -> bool:
        return self.core.drop(self._key(key))

    def _has(self, key: str) -> bool:
        return self.core.has(self._key(key))

    def release_namespace(self) -> int:
        """Drop every key this view owns (the owning replica leaves)."""
        return self.core.drop_namespace(
            f"{self.namespace}:" if self.namespace else ""
        )


_BACKEND_KINDS = {
    "host": HostMemoryBackend,
    "disk": DiskSpillBackend,
    "rpc": RpcBackend,
    "object": ObjectStoreBackend,
}


def build_backends(
    specs: Sequence[TierSpec],
    *,
    transfer: Optional[TransferModel] = None,
    clock: Optional[SimClock] = None,
    hedge=None,
    faults: Optional[FaultInjector] = None,
) -> Dict[str, StorageBackend]:
    """One backend per TierSpec: kind by name (host_dram -> host memory,
    local_nvme -> disk spill, peer*/rpc* -> RPC peer, else object store),
    hedging only where a straggler tail exists, concurrency-limit wrapped
    when the spec bounds the link."""
    out: Dict[str, StorageBackend] = {}
    for spec in specs:
        cls = _BACKEND_KINDS[spec.backend or _default_kind(spec.name)]
        b = cls(
            spec.name, transfer=transfer, clock=clock,
            hedge=hedge if cls.hedgeable else None, faults=faults,
        )
        if spec.concurrency is not None:
            b = ConcurrencyLimitedBackend(b, spec.concurrency, clock=b.clock)
        out[spec.name] = b
    return out


# --------------------------------------------------------------------------- #
# Store records
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class StoredEntry:
    entry_id: str
    chain: List[str]
    n_tokens: int
    nbytes: int
    compressed: bool
    tier: str
    created_s: float
    last_used_s: float
    uses: int = 0
    # $ saved per reuse (prefill skipped) — set by the caller for cost-aware
    # eviction scoring.
    saved_per_use: float = 0.0
    # pin count: >0 means an in-flight prefetch or planned fetch depends on
    # this entry — it must not be evicted, demoted, or promoted.
    pins: int = 0
    # monotone store-assigned sequence number (deterministic tie-break for
    # the migration pass's move ordering).
    seq: int = 0
    # position-independent content hashes of the entry's complete chunks —
    # its footprint in the fusion ChunkIndex, removed on eviction.
    content_chunks: List[str] = dataclasses.field(default_factory=list)
    # whole-context content hash (exact token sequence): the cross-store
    # dedup identity on a content-addressed shared tier, and the traffic key
    # for cluster rebalancing.  None when the store has no shared backend.
    content_key: Optional[str] = None


@dataclasses.dataclass
class TierState:
    name: str
    capacity_bytes: float
    used_bytes: float = 0.0
    gb_hours: float = 0.0
    _last_accrual_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class TierMigration:
    """One completed tier movement, emitted by the migration/spill machinery
    (the engine wraps these into ``TierMigrated`` events)."""

    t_s: float
    entry_id: str
    from_tier: str
    to_tier: str
    nbytes: float
    reason: str  # "promote" | "demote" | "spill"


# --------------------------------------------------------------------------- #
# Migration policy: the paper's break-even math per tier
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class BreakEvenMigrator:
    """Place each entry in the tier that minimizes its total $/hour:

        rate(tier) = hold + reuse_freq * fetch
        hold       = $/GB-hour(tier) * entry_GB
        fetch      = c_GPU * load_delay(tier, nbytes)  +  per-GB fees

    i.e. the storage-tier delta must be justified by reuse frequency times
    fetch savings — the paper's break-even inequality generalized from
    "store vs recompute" to "which tier".  Hot entries (high freq) promote
    toward DRAM; cold ones demote toward object storage, strictly lowering
    the storage $/hour they accrue."""

    # GPU-second price used to convert fetch delay into $; resolved from the
    # store's Pricing when None.
    compute_cost_per_s: Optional[float] = None
    # Hysteresis: move only if it saves at least this many $/hour.
    min_savings_per_hour: float = 0.0
    # Entries younger than this never migrate (their reuse frequency is not
    # yet informative).
    min_residency_s: float = 0.0

    def rate_parts(self, store: "TieredStore", e: StoredEntry, tier: str) -> Tuple[float, float]:
        """(hold $/h, fetch $/use) for ``e`` in ``tier`` — the two lines of
        the affine rate(freq) = hold + freq * fetch."""
        hold = store._gb_hour_rate(tier) * e.nbytes / GB
        c_gpu = self.compute_cost_per_s
        if c_gpu is None:
            c_gpu = (
                store.pricing.compute.cost_per_hour / 3600.0
                if store.pricing is not None
                else 0.0
            )
        fetch = c_gpu * store.backends[tier].estimate_load_delay(e.nbytes)
        if store.pricing is not None and tier in store.pricing.tiers:
            fetch += store.pricing.tiers[tier].per_gb_transfer_fee * e.nbytes / GB
        return hold, fetch

    def tier_rate(self, store: "TieredStore", e: StoredEntry, tier: str, freq_per_h: float) -> float:
        hold, fetch = self.rate_parts(store, e, tier)
        return hold + freq_per_h * fetch

    def crossing_freq(self, store: "TieredStore", e: StoredEntry) -> float:
        """Largest reuse frequency (per hour) at which some slower-fetch tier
        starts beating the current one by ``min_savings_per_hour``.  Between
        touches freq decays monotonically, so an entry that just evaluated to
        "stay put" next flips exactly when its freq falls below this — the
        break-even crossing in closed form.  Each candidate tier's rate is
        affine in freq (``hold + freq * fetch``); a slower-fetch (cheaper-
        hold) tier overtakes below

            f_t = (hold_cur - hold_t - min_savings) / (fetch_t - fetch_cur)

        and the first crossing reached from above is max over tiers.  Tiers
        with fetch <= fetch_cur only lose ground as freq decays: no crossing.
        Returns 0.0 when no decay can ever flip the decision."""
        hold_cur, fetch_cur = self.rate_parts(store, e, e.tier)
        f_star = 0.0
        for t in store.tier_order:
            if t == e.tier:
                continue
            hold_t, fetch_t = self.rate_parts(store, e, t)
            if fetch_t <= fetch_cur:
                continue
            f = (hold_cur - hold_t - self.min_savings_per_hour) / (fetch_t - fetch_cur)
            f_star = max(f_star, f)
        return f_star

    def target(self, store: "TieredStore", e: StoredEntry) -> Optional[str]:
        """Best tier for ``e`` (None = stay put)."""
        now = store.clock.now
        if now - e.created_s < self.min_residency_s:
            return None
        age_h = max((now - e.created_s) / 3600.0, 1e-9)
        freq = e.uses / age_h
        current = self.tier_rate(store, e, e.tier, freq)
        best_tier, best = e.tier, current
        for t in store.tier_order:
            if t == e.tier:
                continue
            r = self.tier_rate(store, e, t, freq)
            if r < best:
                best_tier, best = t, r
        if best_tier != e.tier and current - best > self.min_savings_per_hour:
            return best_tier
        return None


# --------------------------------------------------------------------------- #
# The tiered store
# --------------------------------------------------------------------------- #
class TieredStore:
    """Multi-tier, content-addressed store for per-context model state.

    Owns *what* is stored — tier metadata, the chain-hash trie
    (``chunks.ChunkTrie``), capacity/GB-hour accounting, pinning, and the
    cost-aware eviction/migration economics — while the bytes live in
    pluggable ``StorageBackend``s, one per tier, ordered fastest-first."""

    def __init__(
        self,
        *,
        tiers: Optional[Sequence[TierSpec]] = None,
        tier_capacities_gb: Optional[Dict[str, float]] = None,
        transfer: Optional[TransferModel] = None,
        clock: Optional[SimClock] = None,
        chunk_tokens: int = 256,
        compress_tier: Optional[str] = None,  # entries entering this tier are int8
        eviction: str = "cost",  # "cost" | "lru"
        backends: Optional[Dict[str, StorageBackend]] = None,
        pricing: Optional[Pricing] = None,
        migration: Optional[BreakEvenMigrator] = None,
        spill_on_pressure: bool = False,
        hedge=None,
        faults: Optional[FaultInjector] = None,
    ):
        if tiers is None:
            assert tier_capacities_gb is not None, (
                "TieredStore needs tiers=[TierSpec...] or tier_capacities_gb={...}"
            )
            tiers = [TierSpec(n, gb) for n, gb in tier_capacities_gb.items()]
        self.specs: Dict[str, TierSpec] = {s.name: s for s in tiers}
        self.tiers: Dict[str, TierState] = {
            s.name: TierState(s.name, s.capacity_gb * GB) for s in tiers
        }
        self.tier_order = [s.name for s in tiers]  # fastest first
        self.transfer = transfer
        self.clock = clock or SimClock()
        self.backends: Dict[str, StorageBackend] = backends or build_backends(
            tiers, transfer=transfer, clock=self.clock, hedge=hedge,
            faults=faults,
        )
        missing = set(self.tier_order) - set(self.backends)
        assert not missing, f"tiers without a backend: {sorted(missing)}"
        # any content-addressed backend (a shared tier) makes the store
        # compute whole-context content keys at put time for cross-store dedup
        self._content_addressed = any(
            getattr(b, "content_addressed", False) for b in self.backends.values()
        )
        self.pricing = pricing
        self.trie = ChunkTrie(chunk_tokens)
        # position-independent per-chunk content index maintained alongside
        # the chain-hash trie — the fusion subsystem's non-prefix match
        # surface (kvcache/fusion.py; consulted via lookup_composite).
        self.chunk_index = ChunkIndex(chunk_tokens)
        self.entries: Dict[str, StoredEntry] = {}
        self.compress_tier = compress_tier
        self.eviction = eviction
        self.migration = migration
        self.spill_on_pressure = spill_on_pressure
        self.migration_log: List[TierMigration] = []
        self._ids = itertools.count()
        self.evictions = 0
        self.rejected_puts = 0
        # failure-handling counters: puts rolled back because the backend
        # raised a typed StorageError, entries discarded after the backend
        # lost/corrupted their bytes
        self.failed_puts = 0
        self.discards = 0
        self.last_put_handle = None
        # bumped on every trie mutation (put/evict): consumers holding a
        # lookup result (e.g. the engine's prefetch pass) revalidate with it
        # instead of re-walking the trie at admission.
        self.trie_version = 0
        # Delta-gossip surface (serving/cluster.py): an append-only log of
        # digest hashes in insertion order.  Puts append; removals
        # (evict/discard) bump ``digest_epoch`` and snapshot the log back to
        # the live set — bloom bits cannot be cleared, so a removal forces
        # the consumer's next gossip tick to rebuild from scratch, while
        # put-only windows ship just the add-set since the last cursor.
        self.digest_epoch = 0
        self._digest_log: List[str] = []
        # Migration priority queue: (due_s, seq, entry_id) min-heap keyed by
        # each entry's predicted band-crossing time — reuse frequency
        # uses/age decays monotonically between touches, so the instant its
        # log2 band drops an edge is closed-form.  run_migrations pops only
        # the DUE entries (plus the event-dirtied ones: fetched, moved,
        # unpinned, repriced) instead of walking O(entries) per tick.
        # Lazy deletion: an entry's ARMED wake-up is the due time in
        # _mig_next; heap items that disagree (superseded by a re-arm) or
        # whose entry died are skipped at pop, so each entry holds at most
        # one live wake-up no matter how often it re-evaluates.
        self._mig_heap: List[Tuple[float, int, str]] = []
        self._mig_next: Dict[str, float] = {}
        self._mig_dirty: set = set()
        self._mig_seq = itertools.count()
        self._mig_env: Optional[tuple] = None
        self.migration_evals = 0
        self.migration_skips = 0

    # ------------------------------------------------------------------ #
    # Accounting
    # ------------------------------------------------------------------ #
    def _accrue(self) -> None:
        now = self.clock.now
        for t in self.tiers.values():
            dt_h = max(0.0, now - t._last_accrual_s) / 3600.0
            t.gb_hours += (t.used_bytes / GB) * dt_h
            t._last_accrual_s = now

    def storage_cost_by_tier(self, pricing: Pricing) -> Dict[str, float]:
        """Per-tier accrued GB-hour dollars.  ``storage_cost`` is exactly
        the sum of these, which is what lets the cost ledger settle storage
        per tier while still satisfying its conservation law."""
        self._accrue()
        return {
            t.name: pricing.tier(t.name).cost_per_gb_hour * t.gb_hours
            for t in self.tiers.values()
            if t.name in pricing.tiers
        }

    def storage_cost(self, pricing: Pricing) -> float:
        return sum(self.storage_cost_by_tier(pricing).values())

    def storage_rate_per_hour(self) -> float:
        """Instantaneous $/hour the currently resident bytes accrue."""
        return sum(
            self._gb_hour_rate(t.name) * t.used_bytes / GB
            for t in self.tiers.values()
        )

    # ------------------------------------------------------------------ #
    # Pinning
    # ------------------------------------------------------------------ #
    def pin(self, entry_id: str) -> None:
        """Protect an entry from eviction/demotion until ``unpin`` (in-flight
        prefetches and planned fetches)."""
        try:
            self.entries[entry_id].pins += 1
        except KeyError:
            raise KeyError(f"cannot pin unknown entry {entry_id!r}") from None

    def unpin(self, entry_id: str) -> bool:
        e = self.entries.get(entry_id)
        if e is None:
            return False
        e.pins = max(0, e.pins - 1)
        if e.pins == 0 and self.migration is not None:
            # the pin suppressed migration: force a fresh look next pass
            self._mig_dirty.add(entry_id)
        return True

    def pinned(self, entry_id: str) -> bool:
        e = self.entries.get(entry_id)
        return e is not None and e.pins > 0

    # ------------------------------------------------------------------ #
    # Write path
    # ------------------------------------------------------------------ #
    def put(
        self,
        tokens: Sequence[int],
        artifact: Any,
        *,
        tier: str,
        saved_per_use: float = 0.0,
        sync: bool = False,
    ) -> Tuple[Optional[str], float]:
        """Store a context artifact.  Returns (entry_id | None, write_delay_s).
        Async writes (default) overlap serving: delay is charged to the link
        stats but not to the caller.  Under capacity pressure, space is made
        by spilling the least valuable entries one tier down
        (``spill_on_pressure``) or evicting them."""
        self._accrue()
        ts = self.tiers[tier]
        compressed = tier == self.compress_tier
        if compressed:
            artifact = compression.compress_tree(artifact)
        nbytes = compression.tree_nbytes(artifact)

        if nbytes > ts.capacity_bytes or not self._ensure_room(tier, nbytes):
            self.rejected_puts += 1
            return None, 0.0

        n = next(self._ids)
        entry_id = f"ctx{n}"
        chain = self.trie.insert(tokens, entry_id)
        if not chain:  # context shorter than one chunk: not storable
            self.rejected_puts += 1
            return None, 0.0
        content = self.chunk_index.insert(tokens, entry_id)
        e = StoredEntry(
            entry_id=entry_id,
            chain=chain,
            n_tokens=len(chain) * self.trie.chunk_tokens,
            nbytes=nbytes,
            compressed=compressed,
            tier=tier,
            created_s=self.clock.now,
            last_used_s=self.clock.now,
            saved_per_use=saved_per_use,
            seq=n,
            content_chunks=content,
            content_key=(
                self.content_key(tokens) if self._content_addressed else None
            ),
        )
        self.entries[entry_id] = e
        ts.used_bytes += nbytes
        self.trie_version += 1
        if self.migration is not None:
            self._mig_dirty.add(entry_id)
        try:
            handle = self._backend_put(e, artifact, tier, nbytes)
        except StorageError:
            # the tier refused the bytes (brownout/injected write failure):
            # roll every piece of bookkeeping back so the store never
            # advertises an entry whose payload was never accepted
            self.trie.remove(chain, entry_id)
            self.chunk_index.remove(content, entry_id)
            ts.used_bytes -= nbytes
            del self.entries[entry_id]
            self._mig_dirty.discard(entry_id)
            self.trie_version += 1
            self.failed_puts += 1
            self.last_put_handle = None
            return None, 0.0
        # surfaced for telemetry: a dedup'd shared-tier put moved zero bytes,
        # and the ledger records that saving as an explicit zero-$ entry
        self.last_put_handle = handle
        self._digest_log.extend(e.chain)
        self._digest_log.extend(e.content_chunks)
        if e.content_key is not None:
            self._digest_log.append(e.content_key)
        return entry_id, (handle.delay_s if sync else 0.0)

    @staticmethod
    def content_key(tokens: Sequence[int]) -> str:
        """Whole-context content id: the exact token sequence hashed — safe
        as a cross-store dedup identity (chain hashes truncate to chunk
        multiples, so two different tails could collide there)."""
        return hashlib.sha256("|".join(map(str, tokens)).encode()).hexdigest()

    def _backend_put(self, e: StoredEntry, payload: Any, tier: str,
                     nbytes: float, *, charge: bool = True):
        """Write an entry's bytes to ``tier``, passing the content identity
        to content-addressed (shared) backends so identical contexts stored
        by sibling stores dedup service-wide.  The compression flag joins the
        id: an int8 artifact is NOT the same bytes as its fp16 twin."""
        b = self.backends[tier]
        if e.content_key is not None and getattr(b, "content_addressed", False):
            return b.put(
                e.entry_id, payload, nbytes, charge=charge,
                content=f"{e.content_key}:c{int(e.compressed)}",
            )
        return b.put(e.entry_id, payload, nbytes, charge=charge)

    # ------------------------------------------------------------------ #
    # Read path
    # ------------------------------------------------------------------ #
    def lookup(self, tokens: Sequence[int]) -> Tuple[PrefixMatch, Optional[StoredEntry]]:
        m = self.trie.longest_prefix(tokens)
        return m, (self.entries.get(m.entry_id) if m.entry_id else None)

    def lookup_composite(self, tokens: Sequence[int]) -> CompositeMatch:
        """Position-independent chunk-content matches for ``tokens`` — the
        fusion planner's non-prefix reuse surface (reused spans name their
        source entries; rows are fetched per entry at execute time)."""
        return self.chunk_index.match(tokens)

    def fetch(
        self, entry_id: str, *, fraction: float = 1.0, nbytes: Optional[float] = None
    ) -> Tuple[Any, float]:
        """Load an artifact (optionally a prefix fraction of its bytes for
        partial attention-KV reuse).  ``nbytes`` overrides the billed byte
        count (economics-at-scale: charge the full arch's KV bytes and occupy
        the link accordingly).  Returns (decompressed artifact, delay_s) —
        the delay includes any queueing on a concurrency-limited link."""
        self._accrue()
        e = self.entries[entry_id]
        e.uses += 1
        e.last_used_s = self.clock.now
        if self.migration is not None:
            # reuse frequency just jumped: the entry's band may have crossed
            # upward, which no time-based schedule can predict
            self._mig_dirty.add(entry_id)
        if nbytes is None:
            nbytes = e.nbytes * max(0.0, min(1.0, fraction))
        try:
            payload, handle = self.backends[e.tier].get(entry_id, nbytes=nbytes)
        except KeyNotFound:
            # the backend lost the bytes: the metadata is a lie — drop it so
            # the next lookup plans an honest recompute instead of retrying
            self.discard(entry_id)
            raise
        except CorruptPayload as exc:
            if exc.at_rest:
                # the stored copy itself is bad; no retry can help
                self.discard(entry_id)
            raise
        art = compression.decompress_tree(payload) if e.compressed else payload
        return art, handle.delay_s

    def estimate_load_delay(self, tier: str, nbytes: float) -> float:
        """Backend-modeled (hedged) read delay for ``nbytes`` from ``tier``,
        charging nothing — the prefetch/economics planning surface."""
        return self.backends[tier].estimate_load_delay(nbytes)

    def estimated_queue_wait(
        self, tier: str, nbytes: float, pending: Sequence[float] = ()
    ) -> float:
        """Predicted queueing delay on ``tier``'s link right now (0 for
        uncontended links).  ``pending`` — byte sizes of same-instant fetches
        ahead of this one (see ``ConcurrencyLimitedBackend.estimated_wait``)."""
        fn = getattr(self.backends[tier], "estimated_wait", None)
        if fn is None:
            return 0.0
        return fn(nbytes, pending) if pending else fn(nbytes)

    # ------------------------------------------------------------------ #
    # Tier movement / eviction / migration
    # ------------------------------------------------------------------ #
    def _tier_index(self, tier: str) -> int:
        return self.tier_order.index(tier)

    def _next_tier_down(self, tier: str) -> Optional[str]:
        i = self._tier_index(tier)
        return self.tier_order[i + 1] if i + 1 < len(self.tier_order) else None

    def _transformed(self, e: StoredEntry, to_tier: str) -> Tuple[Any, float, bool]:
        """(payload, nbytes, compressed) as they would be after moving ``e``
        to ``to_tier``: compressed entering the int8 tier, decompressed
        leaving it — the size the destination must actually absorb."""
        payload = self.backends[e.tier].peek(e.entry_id)
        if to_tier == self.compress_tier and not e.compressed:
            p = compression.compress_tree(payload)
            return p, compression.tree_nbytes(p), True
        if e.compressed and to_tier != self.compress_tier:
            p = compression.decompress_tree(payload)
            return p, compression.tree_nbytes(p), False
        return payload, e.nbytes, e.compressed

    def _move(self, entry_id: str, to_tier: str, *, reason: str) -> Optional[TierMigration]:
        """Move an entry between tiers (uncharged link bytes: migration, not a
        serving write).  Compresses entering the int8 tier, decompresses
        leaving it.  Refuses pinned entries and full destinations."""
        e = self.entries.get(entry_id)
        if e is None or e.tier == to_tier or e.pins > 0:
            return None
        new_payload, new_nbytes, new_compressed = self._transformed(e, to_tier)
        dst = self.tiers[to_tier]
        if dst.used_bytes + new_nbytes > dst.capacity_bytes:
            return None
        self._accrue()
        from_tier = e.tier
        # copy-then-delete: if the destination tier refuses the bytes the
        # entry stays intact at its source instead of vanishing mid-move
        old_nbytes, old_compressed = e.nbytes, e.compressed
        e.tier, e.nbytes, e.compressed = to_tier, new_nbytes, new_compressed
        try:
            self._backend_put(e, new_payload, to_tier, new_nbytes, charge=False)
        except StorageError:
            e.tier, e.nbytes, e.compressed = from_tier, old_nbytes, old_compressed
            return None
        self.backends[from_tier].delete(entry_id)
        self.tiers[from_tier].used_bytes -= old_nbytes
        dst.used_bytes += new_nbytes
        self._mig_dirty.add(entry_id)  # tier changed: re-evaluate fresh
        mig = TierMigration(
            t_s=self.clock.now, entry_id=entry_id, from_tier=from_tier,
            to_tier=to_tier, nbytes=new_nbytes, reason=reason,
        )
        self.migration_log.append(mig)
        return mig

    def demote(self, entry_id: str, to_tier: str) -> bool:
        return self._move(entry_id, to_tier, reason="demote") is not None

    def promote(self, entry_id: str, to_tier: str) -> bool:
        return self._move(entry_id, to_tier, reason="promote") is not None

    def _mig_schedule(self, e: StoredEntry) -> None:
        """Re-arm an entry's next migration wake-up after it evaluated to
        "stay put".  Between touches reuse frequency uses/age decays
        monotonically, so the break-even decision next flips at the EXACT
        closed-form crossing: the instant freq falls to the largest frequency
        at which a slower-fetch tier starts winning
        (``BreakEvenMigrator.crossing_freq``) —

            uses / age_h == f*   =>   t = created + 3600 * uses / f*

        — and that (or the min-residency gate expiring, if sooner) is the
        next time the decision can change without an event.  (Earlier
        revisions woke at the entry's log2 *band* edge instead, which within
        a band could lag the true crossing by up to 2x freq drift — the
        drift-fix regression in tests/test_hierarchy.py pins the exact
        time.)  Event-driven flips (fetch, tier move, unpin, repricing) mark
        the entry dirty instead.  Entries never fetched have frequency zero
        already: if staying won at freq 0, no decay can flip it — no
        wake-up.  Likewise when no crossing exists below the current freq
        (f* <= 0)."""
        due = math.inf
        now = self.clock.now
        if e.uses > 0:
            f_star = self.migration.crossing_freq(self, e)
            if f_star > 0.0:
                due = max(now, e.created_s + 3600.0 * e.uses / f_star)
                due = due * (1 + 1e-12) + 1e-9  # strictly past the crossing
        mig = self.migration
        if mig.min_residency_s > 0 and now - e.created_s < mig.min_residency_s:
            due = min(due, e.created_s + mig.min_residency_s)
        if math.isfinite(due):
            self._mig_next[e.entry_id] = due
            heapq.heappush(self._mig_heap, (due, next(self._mig_seq), e.entry_id))

    def run_migrations(self, full_scan: bool = False) -> List[TierMigration]:
        """Clock-driven migration pass, driven by the band-crossing priority
        queue: pop every entry whose predicted band-crossing time is due,
        union the event-dirtied ones (fetched / moved / unpinned / repriced
        since the last pass), and apply the bound policy to just those — a
        steady store pays O(due) instead of even an O(entries) walk per tick
        (``migration_evals`` / ``migration_skips`` expose the split;
        ``full_scan=True`` forces the exhaustive evaluation).  Evaluating to
        "stay put" re-arms the entry's next crossing (``_mig_schedule``);
        a blocked move (pinned race, full destination) retries next pass.
        Demotions apply first (freeing hot-tier capacity for promotions),
        deepest first, ties in store insertion order — deterministically
        identical to the exhaustive scan (regression-tested)."""
        if self.migration is None:
            return []
        self._accrue()
        now = self.clock.now
        env = (
            tuple(self.tier_order),
            tuple(self._gb_hour_rate(t) for t in self.tier_order),
        )
        if env != self._mig_env:  # tier pricing/topology changed: all stale
            self._mig_env = env
            self._mig_dirty.update(self.entries)
        if full_scan:
            self._mig_heap.clear()
            self._mig_next.clear()
            self._mig_dirty.clear()
            due = set(self.entries)
        else:
            due = set(self._mig_dirty)
            self._mig_dirty.clear()
            while self._mig_heap and self._mig_heap[0][0] <= now:
                due_t, _, eid = heapq.heappop(self._mig_heap)
                if self._mig_next.get(eid) == due_t:
                    due.add(eid)
                # else: superseded by a later re-arm, or the entry died
        moves: List[Tuple[StoredEntry, str]] = []
        repush: List[str] = []
        evaluated = 0
        for eid in sorted(due, key=lambda i: self.entries[i].seq if i in self.entries else -1):
            self._mig_next.pop(eid, None)  # consumed / about to re-arm
            e = self.entries.get(eid)
            if e is None:
                continue  # evicted since it was scheduled (lazy deletion)
            if e.pins > 0:
                repush.append(eid)  # retry once the pin drops
                continue
            tgt = self.migration.target(self, e)
            evaluated += 1
            if tgt is None:
                self._mig_schedule(e)
            else:
                moves.append((e, tgt))
        self.migration_evals += evaluated
        if not full_scan:
            self.migration_skips += max(
                0, len(self.entries) - evaluated - len(repush)
            )
        for eid in repush:
            self._mig_next[eid] = now
            heapq.heappush(self._mig_heap, (now, next(self._mig_seq), eid))
        done: List[TierMigration] = []
        # deepest demotions first, promotions last, ties by insertion order
        moves.sort(
            key=lambda m: (
                self._tier_index(m[0].tier) - self._tier_index(m[1]), m[0].seq
            )
        )
        for e, tgt in moves:
            reason = (
                "demote" if self._tier_index(tgt) > self._tier_index(e.tier)
                else "promote"
            )
            mig = self._move(e.entry_id, tgt, reason=reason)
            if mig is not None:
                done.append(mig)
            elif e.entry_id in self.entries:
                # blocked (pinned race / full destination): retry next pass
                self._mig_next[e.entry_id] = now
                heapq.heappush(
                    self._mig_heap, (now, next(self._mig_seq), e.entry_id)
                )
        return done

    def drain_migrations(self) -> List[TierMigration]:
        """Pop-and-return every migration (policy passes AND pressure spills)
        since the last drain — the engine's event source."""
        out, self.migration_log = self.migration_log, []
        return out

    def _gb_hour_rate(self, tier: str) -> float:
        if self.pricing is not None and tier in self.pricing.tiers:
            return self.pricing.tier(tier).cost_per_gb_hour
        return _FALLBACK_GB_HOUR_RATE

    def _score(self, e: StoredEntry, pricing_rate: float) -> float:
        """Cost-aware eviction score (higher = keep): $ saved per hour by this
        entry minus its $ storage rate; LRU mode uses recency only."""
        if self.eviction == "lru":
            return e.last_used_s
        age_h = max((self.clock.now - e.created_s) / 3600.0, 1e-6)
        save_rate = e.saved_per_use * e.uses / age_h
        hold_rate = pricing_rate * e.nbytes / GB
        return save_rate - hold_rate

    def _victim(self, tier: str) -> Optional[StoredEntry]:
        cands = [
            e for e in self.entries.values() if e.tier == tier and e.pins == 0
        ]
        if not cands:
            return None
        rate = self._gb_hour_rate(tier)
        return min(cands, key=lambda e: self._score(e, pricing_rate=rate))

    def _ensure_room(self, tier: str, nbytes: float) -> bool:
        ts = self.tiers[tier]
        if nbytes > ts.capacity_bytes:
            return False  # can never fit: don't evict anything chasing it
        while ts.used_bytes + nbytes > ts.capacity_bytes:
            if not self._spill_or_evict_one(tier):
                return False
        return True

    def _spill_or_evict_one(self, tier: str) -> bool:
        """Free space in ``tier``: preferably by demoting its least valuable
        unpinned entry one level down (``spill_on_pressure``), else by
        evicting it."""
        if self.spill_on_pressure:
            nxt = self._next_tier_down(tier)
            victim = self._victim(tier)
            if nxt is not None and victim is not None:
                # size the destination for the POST-move bytes: leaving the
                # int8 tier decompresses the entry to several times its
                # current footprint
                _, need, _ = self._transformed(victim, nxt)
                if self._ensure_room(nxt, need):
                    if self._move(victim.entry_id, nxt, reason="spill") is not None:
                        return True
        return self._evict_one(tier)

    def _evict_one(self, tier: str) -> bool:
        victim = self._victim(tier)
        if victim is None:
            return False
        self.trie.remove(victim.chain, victim.entry_id)
        self.chunk_index.remove(victim.content_chunks, victim.entry_id)
        self.tiers[tier].used_bytes -= victim.nbytes
        self.backends[tier].delete(victim.entry_id)
        del self.entries[victim.entry_id]
        self._mig_dirty.discard(victim.entry_id)  # heap ids die lazily at pop
        self._mig_next.pop(victim.entry_id, None)
        self.trie_version += 1
        self.evictions += 1
        self.digest_epoch += 1
        self._digest_log = self.digest_hashes()
        return True

    def discard(self, entry_id: str) -> bool:
        """Unconditionally drop an entry whose stored bytes turned out to be
        lost or corrupt.  Unlike eviction this is failure handling, not
        economics: it ignores pins and value scores — metadata pointing at
        bytes that cannot be served is worse than a miss."""
        e = self.entries.get(entry_id)
        if e is None:
            return False
        self.trie.remove(e.chain, e.entry_id)
        self.chunk_index.remove(e.content_chunks, e.entry_id)
        self.tiers[e.tier].used_bytes -= e.nbytes
        self.backends[e.tier].delete(e.entry_id)
        del self.entries[e.entry_id]
        self._mig_dirty.discard(entry_id)
        self._mig_next.pop(entry_id, None)
        self.trie_version += 1
        self.discards += 1
        self.digest_epoch += 1
        self._digest_log = self.digest_hashes()
        return True

    def digest_hashes(self) -> List[str]:
        """Every hash an affinity router could match against this store: the
        chain hashes (prefix reuse), chunk-content hashes (fused reuse), and
        whole-context content keys of all live entries — the bloom-digest
        gossip surface (``serving/router.py``)."""
        out: List[str] = []
        for e in self.entries.values():
            out.extend(e.chain)
            out.extend(e.content_chunks)
            if e.content_key is not None:
                out.append(e.content_key)
        return out

    def digest_view(self) -> Tuple[int, List[str]]:
        """(epoch, hash log) for delta gossip.  Within one epoch the log only
        grows, so a consumer holding (epoch, cursor) applies ``log[cursor:]``
        as an add-set; an epoch change means a removal happened and the
        consumer must rebuild its digest from the full log (which removals
        re-snapshot to exactly the live ``digest_hashes()`` set)."""
        return self.digest_epoch, self._digest_log

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        self._accrue()
        shared = {
            n: b.core.stats()
            for n, b in self.backends.items()
            if isinstance(getattr(b, "core", None), SharedBackendCore)
        }
        return {
            **({"shared": shared} if shared else {}),
            "entries": len(self.entries),
            "evictions": self.evictions,
            "rejected_puts": self.rejected_puts,
            "failed_puts": self.failed_puts,
            "discards": self.discards,
            "migrations": len(self.migration_log),
            "migration_evals": self.migration_evals,
            "migration_skips": self.migration_skips,
            "migration_queue": len(self._mig_next),  # armed wake-ups
            "content_chunks": len(self.chunk_index),
            "tiers": {
                n: {"used_gb": t.used_bytes / GB, "gb_hours": t.gb_hours}
                for n, t in self.tiers.items()
            },
        }
