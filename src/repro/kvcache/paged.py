"""Context-state extraction/insertion between batched device state and the
storage tier.

The device-side cache is slotted-dense (DESIGN.md §3): one batch slot per
active sequence.  The storage-side artifact for a context of L tokens is the
per-slot slice of the context state:

  * attention layers — K/V rows [0, L)                      (O(L) bytes)
  * Mamba/SSD layers — (conv tail, SSD state)               (O(1) bytes)
  * enc-dec          — encoder-output cross-attention KV    (O(L_enc) bytes)

Artifacts are host numpy pytrees (storage is host/remote by definition);
``insert_slot`` is the load path back into a batched device state.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import KVCache
from repro.models.blocks import BlockCache
from repro.models.encdec import EncDecState
from repro.models.lm import LMState
from repro.models.ssm import MambaState


def _np(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


# --------------------------------------------------------------------------- #
# Extract: batched device state -> per-context host artifact
# --------------------------------------------------------------------------- #
def extract_slot(cfg: ArchConfig, state: Any, slot: int, length: int) -> Any:
    """Pull slot ``slot``'s first ``length`` tokens of context state."""
    if isinstance(state, EncDecState):
        return _np(
            EncDecState(
                # context is the audio: the decoder restarts at pos 0 on reuse
                pos=jnp.zeros((1,), jnp.int32),
                # decoder self-KV is per-request (prompt side), not context
                self_kv=KVCache(
                    state.self_kv.k[:, slot : slot + 1, :0],
                    state.self_kv.v[:, slot : slot + 1, :0],
                ),
                cross_kv=KVCache(
                    state.cross_kv.k[:, slot : slot + 1],
                    state.cross_kv.v[:, slot : slot + 1],
                ),
            )
        )
    assert isinstance(state, LMState)

    def per_cache(c: BlockCache) -> BlockCache:
        if c.attn is not None:
            return BlockCache(
                KVCache(
                    c.attn.k[:, slot : slot + 1, :length],
                    c.attn.v[:, slot : slot + 1, :length],
                ),
                None,
            )
        return BlockCache(
            None,
            MambaState(
                conv=c.mamba.conv[:, slot : slot + 1],
                ssd=c.mamba.ssd[:, slot : slot + 1],
            ),
        )

    return _np(
        LMState(
            pos=jnp.full((1,), length, jnp.int32),
            caches=tuple(per_cache(c) for c in state.caches),
        )
    )


# --------------------------------------------------------------------------- #
# Insert: host artifact -> slot of a batched device state
# --------------------------------------------------------------------------- #
def insert_slot(
    cfg: ArchConfig, state: Any, slot: int, artifact: Any, n_tokens: int = None
) -> Any:
    """Write a stored context into batch slot ``slot``; returns the new state
    with ``pos[slot]`` set to the artifact's token count (or ``n_tokens`` for
    a partial-prefix insert of attention KV)."""
    art_pos = int(np.asarray(artifact.pos)[0])
    L = art_pos if n_tokens is None else min(n_tokens, art_pos)

    if isinstance(state, EncDecState):
        assert isinstance(artifact, EncDecState)
        ck = state.cross_kv
        new_cross = KVCache(
            ck.k.at[:, slot].set(jnp.asarray(artifact.cross_kv.k[:, 0], ck.k.dtype)),
            ck.v.at[:, slot].set(jnp.asarray(artifact.cross_kv.v[:, 0], ck.v.dtype)),
        )
        # self-KV prefix (0 rows for a stored context artifact; the prompt's
        # rows when installing a freshly prefilled batch-1 state).
        sk = state.self_kv
        L_self = artifact.self_kv.k.shape[2]
        if L_self > 0:
            sk = KVCache(
                jax.lax.dynamic_update_slice(
                    sk.k,
                    jnp.asarray(artifact.self_kv.k[:, :, :L_self], sk.k.dtype),
                    (0, slot, 0, 0, 0),
                ),
                jax.lax.dynamic_update_slice(
                    sk.v,
                    jnp.asarray(artifact.self_kv.v[:, :, :L_self], sk.v.dtype),
                    (0, slot, 0, 0, 0),
                ),
            )
        return EncDecState(
            pos=state.pos.at[slot].set(artifact.pos[0]),
            self_kv=sk,
            cross_kv=new_cross,
        )

    assert isinstance(state, LMState) and isinstance(artifact, LMState)

    def per_cache(c: BlockCache, a: BlockCache) -> BlockCache:
        if c.attn is not None:
            ak = jnp.asarray(a.attn.k[:, 0, :L], c.attn.k.dtype)
            av = jnp.asarray(a.attn.v[:, 0, :L], c.attn.v.dtype)
            return BlockCache(
                KVCache(
                    jax.lax.dynamic_update_slice(
                        c.attn.k, ak[:, None], (0, slot, 0, 0, 0)
                    ),
                    jax.lax.dynamic_update_slice(
                        c.attn.v, av[:, None], (0, slot, 0, 0, 0)
                    ),
                ),
                None,
            )
        # SSM state is all-or-nothing (O(1) snapshot at full context length).
        return BlockCache(
            None,
            MambaState(
                conv=c.mamba.conv.at[:, slot].set(
                    jnp.asarray(a.mamba.conv[:, 0], c.mamba.conv.dtype)
                ),
                ssd=c.mamba.ssd.at[:, slot].set(
                    jnp.asarray(a.mamba.ssd[:, 0], c.mamba.ssd.dtype)
                ),
            ),
        )

    return LMState(
        pos=state.pos.at[slot].set(L),
        caches=tuple(per_cache(c, a) for c, a in zip(state.caches, artifact.caches)),
    )


def partial_reuse_allowed(cfg: ArchConfig) -> bool:
    """Partial-prefix reuse needs per-position state (attention KV).  SSM /
    hybrid / enc-dec store O(1)-or-encoder state snapshots at full context
    length only => all-or-nothing (DESIGN.md §6)."""
    return cfg.family in ("dense", "moe", "vlm") and cfg.n_ssm_layers == 0
