"""Context-state extraction/insertion between batched device state and the
storage tier.

The device-side cache is slotted-dense (DESIGN.md §3): one batch slot per
active sequence.  The storage-side artifact for a context of L tokens is the
per-slot slice of the context state:

  * attention layers — K/V rows [0, L)                      (O(L) bytes)
  * Mamba/SSD layers — (conv tail, SSD state)               (O(1) bytes)
  * enc-dec          — encoder-output cross-attention KV    (O(L_enc) bytes)

Artifacts are host numpy pytrees (storage is host/remote by definition);
``insert_slot`` is the load path back into a batched device state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import KVCache
from repro.models.blocks import BlockCache
from repro.models.encdec import EncDecState
from repro.models.lm import LMState
from repro.models.ssm import MambaState


def _np(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


# --------------------------------------------------------------------------- #
# Extract: batched device state -> per-context host artifact
# --------------------------------------------------------------------------- #
def extract_slot(cfg: ArchConfig, state: Any, slot: int, length: int) -> Any:
    """Pull slot ``slot``'s first ``length`` tokens of context state."""
    if isinstance(state, EncDecState):
        return _np(
            EncDecState(
                # context is the audio: the decoder restarts at pos 0 on reuse
                pos=jnp.zeros((1,), jnp.int32),
                # decoder self-KV is per-request (prompt side), not context
                self_kv=KVCache(
                    state.self_kv.k[:, slot : slot + 1, :0],
                    state.self_kv.v[:, slot : slot + 1, :0],
                ),
                cross_kv=KVCache(
                    state.cross_kv.k[:, slot : slot + 1],
                    state.cross_kv.v[:, slot : slot + 1],
                ),
            )
        )
    assert isinstance(state, LMState)

    def per_cache(c: BlockCache) -> BlockCache:
        if c.attn is not None:
            return BlockCache(
                KVCache(
                    c.attn.k[:, slot : slot + 1, :length],
                    c.attn.v[:, slot : slot + 1, :length],
                ),
                None,
            )
        return BlockCache(
            None,
            MambaState(
                conv=c.mamba.conv[:, slot : slot + 1],
                ssd=c.mamba.ssd[:, slot : slot + 1],
            ),
        )

    return _np(
        LMState(
            pos=jnp.full((1,), length, jnp.int32),
            caches=tuple(per_cache(c) for c in state.caches),
        )
    )


# --------------------------------------------------------------------------- #
# Insert: host artifact -> slot of a batched device state
# --------------------------------------------------------------------------- #
def insert_slot(
    cfg: ArchConfig, state: Any, slot: int, artifact: Any, n_tokens: int = None
) -> Any:
    """Write a stored context into batch slot ``slot``; returns the new state
    with ``pos[slot]`` set to the artifact's token count (or ``n_tokens`` for
    a partial-prefix insert of attention KV)."""
    art_pos = int(np.asarray(artifact.pos)[0])
    L = art_pos if n_tokens is None else min(n_tokens, art_pos)

    if isinstance(state, EncDecState):
        assert isinstance(artifact, EncDecState)
        ck = state.cross_kv
        new_cross = KVCache(
            ck.k.at[:, slot].set(jnp.asarray(artifact.cross_kv.k[:, 0], ck.k.dtype)),
            ck.v.at[:, slot].set(jnp.asarray(artifact.cross_kv.v[:, 0], ck.v.dtype)),
        )
        # self-KV prefix (0 rows for a stored context artifact; the prompt's
        # rows when installing a freshly prefilled batch-1 state).
        sk = state.self_kv
        L_self = artifact.self_kv.k.shape[2]
        if L_self > 0:
            sk = KVCache(
                jax.lax.dynamic_update_slice(
                    sk.k,
                    jnp.asarray(artifact.self_kv.k[:, :, :L_self], sk.k.dtype),
                    (0, slot, 0, 0, 0),
                ),
                jax.lax.dynamic_update_slice(
                    sk.v,
                    jnp.asarray(artifact.self_kv.v[:, :, :L_self], sk.v.dtype),
                    (0, slot, 0, 0, 0),
                ),
            )
        return EncDecState(
            pos=state.pos.at[slot].set(artifact.pos[0]),
            self_kv=sk,
            cross_kv=new_cross,
        )

    assert isinstance(state, LMState) and isinstance(artifact, LMState)

    def per_cache(c: BlockCache, a: BlockCache) -> BlockCache:
        if c.attn is not None:
            ak = jnp.asarray(a.attn.k[:, 0, :L], c.attn.k.dtype)
            av = jnp.asarray(a.attn.v[:, 0, :L], c.attn.v.dtype)
            return BlockCache(
                KVCache(
                    jax.lax.dynamic_update_slice(
                        c.attn.k, ak[:, None], (0, slot, 0, 0, 0)
                    ),
                    jax.lax.dynamic_update_slice(
                        c.attn.v, av[:, None], (0, slot, 0, 0, 0)
                    ),
                ),
                None,
            )
        # SSM state is all-or-nothing (O(1) snapshot at full context length).
        return BlockCache(
            None,
            MambaState(
                conv=c.mamba.conv.at[:, slot].set(
                    jnp.asarray(a.mamba.conv[:, 0], c.mamba.conv.dtype)
                ),
                ssd=c.mamba.ssd.at[:, slot].set(
                    jnp.asarray(a.mamba.ssd[:, 0], c.mamba.ssd.dtype)
                ),
            ),
        )

    return LMState(
        pos=state.pos.at[slot].set(L),
        caches=tuple(per_cache(c, a) for c, a in zip(state.caches, artifact.caches)),
    )


def partial_reuse_allowed(cfg: ArchConfig) -> bool:
    """Partial-prefix reuse needs per-position state (attention KV).  SSM /
    hybrid / enc-dec store O(1)-or-encoder state snapshots at full context
    length only => all-or-nothing (DESIGN.md §6)."""
    return cfg.family in ("dense", "moe", "vlm") and cfg.n_ssm_layers == 0


# --------------------------------------------------------------------------- #
# Packed ragged prefill: layout + multi-slot insertion
# --------------------------------------------------------------------------- #
def packable_arch(cfg: ArchConfig, max_len: int) -> bool:
    """Whether batched admission may pack this arch's suffix-prefills into one
    ragged sequence.  Requires per-position attention state (no SSM/enc-dec
    sequence mixing) and a non-ring KV cache: when ``sliding_window <
    max_len`` the slot cache is a ring buffer whose prefill path attends
    [old ring ++ new KV] — a layout a packed buffer cannot reproduce
    bit-exactly — so SWA archs ride the per-request path."""
    return (
        cfg.family in ("dense", "moe", "vlm")
        and cfg.n_ssm_layers == 0
        and not (cfg.sliding_window and cfg.sliding_window < max_len)
    )


@dataclasses.dataclass(frozen=True)
class PackSegment:
    """One request's span of the packed sequence (all indices host-static)."""

    slot: int  # batch slot the outputs scatter back into
    kv_start: int  # first packed kv row of this segment (align-multiple)
    q_start: int  # first packed q index of this segment's new tokens
    matched: int  # reused prefix rows preloaded at [kv_start, kv_start+matched)
    n_new: int  # new (tail + prompt) tokens prefilled by the kernel
    n_total: int  # matched + n_new == rows valid after prefill

    @property
    def q_last(self) -> int:
        return self.q_start + self.n_new - 1


@dataclasses.dataclass(frozen=True)
class PackLayout:
    """Packed-sequence geometry for one admission batch.

    kv spans are aligned to ``align`` (the flash kernel's kv block): every
    segment starts at an align-multiple, so cross-segment kv blocks are
    fully masked exact no-ops and the packed attention is bit-identical to
    per-request attention (tests/test_packed.py).  The q side is
    padding-free: new-token runs concatenate densely and only the total pads
    up to the jit bucket."""

    segments: Tuple[PackSegment, ...]
    q_len: int  # bucketed total q length
    kv_len: int  # bucketed total kv length
    q_tokens: int  # sum of n_new (un-padded)

    @property
    def occupancy(self) -> float:
        """Useful fraction of the padded q sequence the kernel runs over."""
        return self.q_tokens / max(self.q_len, 1)


def pack_bucket(n: int, minimum: int = 16) -> int:
    """Round up to a power-of-two jit bucket so steady-state serving reuses
    compiled shapes instead of recompiling per ragged length."""
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


def pack_layout(
    slots: List[int],
    matched: List[int],
    n_new: List[int],
    *,
    align: int = 128,
    bucket_min: int = 16,
) -> PackLayout:
    segs: List[PackSegment] = []
    kv_off = 0
    q_off = 0
    for slot, m, n in zip(slots, matched, n_new):
        total = m + n
        segs.append(
            PackSegment(
                slot=slot, kv_start=kv_off, q_start=q_off,
                matched=m, n_new=n, n_total=total,
            )
        )
        kv_off += -(-total // align) * align
        q_off += n
    return PackLayout(
        segments=tuple(segs),
        q_len=pack_bucket(q_off, bucket_min),
        kv_len=pack_bucket(kv_off, max(align, bucket_min)),
        q_tokens=q_off,
    )


def _attn_kinds(cfg: ArchConfig):
    from repro.models import blocks as blocks_mod

    kinds = blocks_mod.block_kinds(cfg)
    assert all(k.mixer == "a" for k in kinds), (cfg.name, kinds)
    return kinds, cfg.n_layers // len(kinds)


def build_packed_caches(
    cfg: ArchConfig, layout: PackLayout, artifacts: List[Any], dtype=None
) -> Any:
    """Packed per-layer KV buffers with every segment's reused prefix rows
    preloaded at its kv span — the multi-slot insertion of the load path.
    ``artifacts[i]`` is segment i's stored LMState (or None for recompute);
    assembly happens host-side in one numpy pass, then lands on device as a
    single transfer."""
    from repro.models import common as common_mod
    from repro.models.blocks import BlockCache

    kinds, n_periods = _attn_kinds(cfg)
    dtype = dtype or common_mod.resolve_dtype(cfg.dtype)
    np_dtype = np.dtype(jnp.zeros((), dtype).dtype.name)
    shape = (n_periods, 1, layout.kv_len, cfg.n_kv_heads, cfg.resolved_head_dim)

    out = []
    for ki in range(len(kinds)):
        k_buf = np.zeros(shape, np_dtype)
        v_buf = np.zeros(shape, np_dtype)
        for seg, art in zip(layout.segments, artifacts):
            if art is None or seg.matched <= 0:
                continue
            rows = slice(seg.kv_start, seg.kv_start + seg.matched)
            k_buf[:, :, rows] = np.asarray(
                art.caches[ki].attn.k[:, :, : seg.matched], np_dtype
            )
            v_buf[:, :, rows] = np.asarray(
                art.caches[ki].attn.v[:, :, : seg.matched], np_dtype
            )
        out.append(
            BlockCache(KVCache(jnp.asarray(k_buf), jnp.asarray(v_buf)), None)
        )
    return tuple(out)


def pack_arrays(layout: PackLayout, new_tokens: List[List[int]]) -> dict:
    """Host-side int32 index arrays driving the packed kernel: tokens,
    segment-local q/kv positions, segment ids, kv landing rows, and each
    segment's last-q index (padded with 0 — callers ignore extra rows)."""
    Sq, Skv = layout.q_len, layout.kv_len
    tokens = np.zeros((1, Sq), np.int32)
    q_pos = np.full((1, Sq), -(2**30), np.int32)
    q_seg = np.full((1, Sq), -1, np.int32)
    q_rows = np.full((1, Sq), Skv, np.int32)  # padding lands on the scratch row
    kv_pos = np.full((1, Skv), -1, np.int32)
    kv_seg = np.full((1, Skv), -2, np.int32)
    for i, (seg, toks) in enumerate(zip(layout.segments, new_tokens)):
        assert len(toks) == seg.n_new, (len(toks), seg)
        q = slice(seg.q_start, seg.q_start + seg.n_new)
        tokens[0, q] = toks
        q_pos[0, q] = np.arange(seg.matched, seg.n_total, dtype=np.int32)
        q_seg[0, q] = i
        q_rows[0, q] = np.arange(
            seg.kv_start + seg.matched, seg.kv_start + seg.n_total, dtype=np.int32
        )
        rows = slice(seg.kv_start, seg.kv_start + seg.n_total)
        kv_pos[0, rows] = np.arange(seg.n_total, dtype=np.int32)
        kv_seg[0, rows] = i
    return {
        "tokens": tokens, "q_pos": q_pos, "q_seg": q_seg, "q_rows": q_rows,
        "kv_pos": kv_pos, "kv_seg": kv_seg,
    }


# --------------------------------------------------------------------------- #
# Shared KV block pool: paged batched decode state
# --------------------------------------------------------------------------- #
KV_BLOCK = 128  # pool block size in tokens (== the flash kernels' kv block)


class BlockPool:
    """Host-side bookkeeping for the shared device KV block pool.

    Block ids index a single device array of ``n_blocks * block`` KV rows
    shared by every batch slot.  Block 0 is the reserved *dump* block: a slot
    whose block table is zeroed (freed/inactive) computes its decode write
    row inside block 0, so a stale slot can never corrupt a block that has
    been recycled to another sequence.

    Blocks are reference-counted so batch-mates that loaded the same stored
    context can point their table prefixes at ONE copy of the shared-prefix
    blocks (write-back dedup carried into the pool).  ``release`` returns a
    block to the free list exactly once — when its last reference drops —
    and ``PagedSlots.prepare_append`` is the copy-on-write primitive:
    appending into a shared boundary block first splits it onto a fresh
    private block.  ``tests/test_paged_decode.py`` drives these invariants
    with hypothesis.
    """

    def __init__(self, n_blocks: int, block: int = KV_BLOCK):
        assert n_blocks >= 2, "need the dump block plus at least one real block"
        self.block = block
        self.n_blocks = n_blocks
        self.ref = np.zeros(n_blocks, np.int64)
        self.ref[0] = 1  # dump block: permanently held by the pool itself
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        """Distinct non-dump blocks currently referenced."""
        return self.n_blocks - 1 - len(self._free)

    def alloc(self, n: int) -> List[int]:
        assert n <= len(self._free), (n, len(self._free))
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self.ref[b] == 0, b
            self.ref[b] = 1
        return out

    def share(self, bid: int) -> int:
        assert 0 < bid < self.n_blocks and self.ref[bid] > 0, bid
        self.ref[bid] += 1
        return bid

    def release(self, bid: int) -> None:
        assert 0 < bid < self.n_blocks and self.ref[bid] > 0, bid
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            self._free.append(bid)

    def free_list(self) -> List[int]:
        return list(self._free)


@dataclasses.dataclass(frozen=True)
class CowSplit:
    """A copy-on-write split: pool rows of ``src`` must be device-copied to
    ``dst`` before the next write touches the block."""

    src: int
    dst: int


class PagedSlots:
    """Block tables + live lengths for a batch of slots over one BlockPool.

    The engine's host-side view of the paged decode state: per-slot tables
    (0-padded, fixed width ``max_len // block`` so every decode launch has
    one static shape), live token counts, and the alloc/share/append/free
    lifecycle.  Device arrays are the engine's; this class only decides
    which pool blocks hold what.
    """

    def __init__(self, n_slots: int, max_len: int, block: int = KV_BLOCK):
        assert max_len % block == 0, (max_len, block)
        self.block = block
        self.nb_max = max_len // block
        # worst case every slot fills max_len with private blocks (+ dump)
        self.pool = BlockPool(1 + n_slots * self.nb_max, block)
        self.tables = np.zeros((n_slots, self.nb_max), np.int32)
        self.lens = np.zeros(n_slots, np.int64)
        self.n_blocks = np.zeros(n_slots, np.int64)  # table entries in use
        self.live = np.zeros(n_slots, bool)
        self.shared_block_hits = 0  # blocks deduped across batch-mates
        self.pool_blocks_peak = 0  # high-water distinct blocks in use

    def admit(
        self,
        slot: int,
        n_total: int,
        *,
        shared_from: Optional[int] = None,
        shared_blocks: int = 0,
    ) -> List[int]:
        """Allocate the slot's table for ``n_total`` live rows; the first
        ``shared_blocks`` entries alias slot ``shared_from``'s (same stored
        context, write-back dedup).  Returns the NEWLY allocated block ids —
        the ones whose rows the caller must fill; shared blocks already hold
        the right rows."""
        assert not self.live[slot], slot
        nb = -(-n_total // self.block)
        assert 0 < nb <= self.nb_max, (n_total, self.nb_max)
        assert shared_blocks <= nb
        if shared_blocks:
            assert shared_from is not None and self.live[shared_from]
            assert shared_blocks <= self.n_blocks[shared_from]
            for j in range(shared_blocks):
                self.tables[slot, j] = self.pool.share(
                    int(self.tables[shared_from, j])
                )
            self.shared_block_hits += shared_blocks
        own = self.pool.alloc(nb - shared_blocks)
        self.tables[slot, shared_blocks:nb] = own
        self.tables[slot, nb:] = 0
        self.lens[slot] = n_total
        self.n_blocks[slot] = nb
        self.live[slot] = True
        self.pool_blocks_peak = max(self.pool_blocks_peak, self.pool.n_used)
        return own

    def prepare_append(self, slot: int) -> Optional[CowSplit]:
        """Make the row for the NEXT token (position ``lens[slot]``) writable:
        grow the table by a fresh block at a block boundary, copy-on-write
        split a shared boundary block.  Returns the split to device-copy, or
        None.  The caller bumps ``note_token`` after the write lands."""
        assert self.live[slot], slot
        pos = int(self.lens[slot])
        ib = pos // self.block
        assert ib < self.nb_max, "append past max_len"
        if ib == self.n_blocks[slot]:
            (bid,) = self.pool.alloc(1)
            self.tables[slot, ib] = bid
            self.n_blocks[slot] += 1
            self.pool_blocks_peak = max(self.pool_blocks_peak, self.pool.n_used)
            return None
        bid = int(self.tables[slot, ib])
        if self.pool.ref[bid] > 1:
            (fresh,) = self.pool.alloc(1)
            self.pool.release(bid)
            self.tables[slot, ib] = fresh
            return CowSplit(src=bid, dst=fresh)
        return None

    def note_token(self, slot: int) -> None:
        self.lens[slot] += 1

    def free(self, slot: int) -> None:
        """Return the slot's blocks to the pool (each freed exactly once, on
        its last reference) and zero its table AND length, so any stale
        decode write computes a row inside the dump block (table entry 0)
        without relying on out-of-range index clamping."""
        assert self.live[slot], slot
        for j in range(int(self.n_blocks[slot])):
            self.pool.release(int(self.tables[slot, j]))
        self.tables[slot, :] = 0
        self.lens[slot] = 0
        self.n_blocks[slot] = 0
        self.live[slot] = False

    def stats(self) -> dict:
        """One consolidated pool-audit snapshot (telemetry gauges read this;
        ``engine.decode_stats()`` embeds it under the paged path)."""
        return {
            "block": self.block,
            "pool_blocks": self.pool.n_blocks,
            "pool_blocks_used": self.pool.n_used,
            "pool_blocks_peak": self.pool_blocks_peak,
            "shared_block_hits": self.shared_block_hits,
            "live_slots": int(self.live.sum()),
            "live_tokens": int(self.lens[self.live].sum()),
        }

    # -- auditing (the hypothesis invariants) --------------------------- #
    def audit(self) -> None:
        """Pool-accounting invariants: ref counts == live table references,
        free list disjoint + duplicate-free, and used pool bytes == bytes of
        the live block-table entries (each distinct block counted once)."""
        refs: dict = {}
        for slot in range(self.tables.shape[0]):
            if not self.live[slot]:
                assert self.n_blocks[slot] == 0
                assert not self.tables[slot].any(), slot
                continue
            for j in range(int(self.n_blocks[slot])):
                bid = int(self.tables[slot, j])
                assert bid > 0, (slot, j)
                refs[bid] = refs.get(bid, 0) + 1
        for bid in range(1, self.pool.n_blocks):
            assert self.pool.ref[bid] == refs.get(bid, 0), bid
        free = self.pool.free_list()
        assert len(free) == len(set(free))
        assert not (set(free) & set(refs)), "freed block still referenced"
        assert self.pool.n_used == len(refs)


def block_rows(block_ids, block: int) -> np.ndarray:
    """Flat pool-row indices covered by ``block_ids`` (host-side helper for
    the engine's single-scatter landings and CoW copies)."""
    ids = np.asarray(list(block_ids), np.int64)
    return (
        ids[:, None] * block + np.arange(block, dtype=np.int64)[None, :]
    ).reshape(-1)


def init_pool_caches(
    cfg: ArchConfig, n_blocks: int, block: int = KV_BLOCK, dtype=None
) -> Any:
    """Device-side shared KV block pool: one flat-row KV buffer per layer
    kind, ``[n_periods, n_blocks * block, KV, hd]`` — the paged analogue of
    ``lm.init_state``'s slotted-dense caches."""
    from repro.models import common as common_mod
    from repro.models.blocks import BlockCache

    kinds, n_periods = _attn_kinds(cfg)
    dtype = dtype or common_mod.resolve_dtype(cfg.dtype)
    shape = (n_periods, n_blocks * block, cfg.n_kv_heads, cfg.resolved_head_dim)
    return tuple(
        BlockCache(KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)), None)
        for _ in kinds
    )


def packed_to_artifact(cfg: ArchConfig, caches: Any, seg: PackSegment, n: int) -> Any:
    """Slice one segment's first ``n`` rows out of the packed buffers as a
    standard batch-1 LMState artifact — the bridge back to ``insert_slot``
    (slot installation) and ``ContextStore.put`` (write-back)."""
    from repro.models.blocks import BlockCache

    rows = slice(seg.kv_start, seg.kv_start + n)
    return LMState(
        pos=jnp.full((1,), n, jnp.int32),
        caches=tuple(
            BlockCache(KVCache(c.attn.k[:, :, rows], c.attn.v[:, :, rows]), None)
            for c in caches
        ),
    )
