"""Tiered context-state store — backward-compatible facade.

The store implementation lives in ``repro.kvcache.hierarchy``: a
``TieredStore`` composing capacity-bounded ``StorageBackend``s into an
ordered hierarchy (host_dram -> local_nvme -> io2/gp3 -> s3/peer_dram) with
pinning, link concurrency limits, spill-on-pressure, and economics-driven
promotion/demotion.  ``ContextStore`` is the legacy name, kept as a thin
wrapper: with a single-tier hierarchy, no concurrency limits, and no
migration policy it is behaviorally identical to the pre-hierarchy store
(golden-parity pinned by tests/test_serving.py)."""
from __future__ import annotations

from repro.kvcache.hierarchy import (  # noqa: F401
    BreakEvenMigrator,
    StoredEntry,
    TieredStore,
    TierMigration,
    TierSpec,
    TierState,
    _FALLBACK_GB_HOUR_RATE,
)


class ContextStore(TieredStore):
    """Multi-tier, content-addressed store for per-context model state
    (legacy name; see ``hierarchy.TieredStore``)."""
