"""Tiered context-state store: HBM-adjacent host DRAM -> cloud storage.

The storage half of the paper's system, split along the plan/execute API:
this module owns *what* is stored — tier metadata, the content-addressed
chain-hash trie (``chunks.ChunkTrie``), capacity accounting, and the
cost-aware eviction economics — while the bytes themselves live in pluggable
``StorageBackend``s (``kvcache.backend``), one per tier.  Entries live in
exactly one tier and are promoted/demoted/evicted by either LRU or a
cost-aware score derived from the analytical model (evict the entry whose
storage $ rate is least justified by its prefill-$ savings rate — the
paper's economics turned into an eviction policy, a beyond-paper extension).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.pricing import GB, Pricing
from repro.kvcache import compression
from repro.kvcache.backend import StorageBackend, default_backends
from repro.kvcache.chunks import ChunkTrie, PrefixMatch
from repro.kvcache.transfer import SimClock, TransferModel

# Storage rate assumed by eviction scoring when no Pricing is plumbed in
# (io2's ~$0.125/GB-month); callers with real catalogs pass ``pricing=``.
_FALLBACK_GB_HOUR_RATE = 1.7e-4


@dataclasses.dataclass
class StoredEntry:
    entry_id: str
    chain: List[str]
    n_tokens: int
    nbytes: int
    compressed: bool
    tier: str
    created_s: float
    last_used_s: float
    uses: int = 0
    # $ saved per reuse (prefill skipped) — set by the caller for cost-aware
    # eviction scoring.
    saved_per_use: float = 0.0


@dataclasses.dataclass
class TierState:
    name: str
    capacity_bytes: float
    used_bytes: float = 0.0
    gb_hours: float = 0.0
    _last_accrual_s: float = 0.0


class ContextStore:
    """Multi-tier, content-addressed store for per-context model state."""

    def __init__(
        self,
        *,
        tier_capacities_gb: Dict[str, float],
        transfer: Optional[TransferModel] = None,
        clock: Optional[SimClock] = None,
        chunk_tokens: int = 256,
        compress_tier: Optional[str] = None,  # entries entering this tier are int8
        eviction: str = "cost",  # "cost" | "lru"
        backends: Optional[Dict[str, StorageBackend]] = None,
        pricing: Optional[Pricing] = None,
    ):
        self.tiers: Dict[str, TierState] = {
            n: TierState(n, gb * GB) for n, gb in tier_capacities_gb.items()
        }
        self.tier_order = list(tier_capacities_gb)  # fastest first
        self.transfer = transfer
        self.clock = clock or SimClock()
        self.backends: Dict[str, StorageBackend] = backends or default_backends(
            self.tier_order, transfer=transfer, clock=self.clock
        )
        missing = set(self.tier_order) - set(self.backends)
        assert not missing, f"tiers without a backend: {sorted(missing)}"
        self.pricing = pricing
        self.trie = ChunkTrie(chunk_tokens)
        self.entries: Dict[str, StoredEntry] = {}
        self.compress_tier = compress_tier
        self.eviction = eviction
        self._ids = itertools.count()
        self.evictions = 0
        self.rejected_puts = 0

    # ------------------------------------------------------------------ #
    # Accounting
    # ------------------------------------------------------------------ #
    def _accrue(self) -> None:
        now = self.clock.now
        for t in self.tiers.values():
            dt_h = max(0.0, now - t._last_accrual_s) / 3600.0
            t.gb_hours += (t.used_bytes / GB) * dt_h
            t._last_accrual_s = now

    def storage_cost(self, pricing: Pricing) -> float:
        self._accrue()
        return sum(
            pricing.tier(t.name).cost_per_gb_hour * t.gb_hours
            for t in self.tiers.values()
            if t.name in pricing.tiers
        )

    # ------------------------------------------------------------------ #
    # Write path
    # ------------------------------------------------------------------ #
    def put(
        self,
        tokens: Sequence[int],
        artifact: Any,
        *,
        tier: str,
        saved_per_use: float = 0.0,
        sync: bool = False,
    ) -> Tuple[Optional[str], float]:
        """Store a context artifact.  Returns (entry_id | None, write_delay_s).
        Async writes (default) overlap serving: delay is charged to the link
        stats but not to the caller."""
        self._accrue()
        ts = self.tiers[tier]
        compressed = tier == self.compress_tier
        if compressed:
            artifact = compression.compress_tree(artifact)
        nbytes = compression.tree_nbytes(artifact)

        if nbytes > ts.capacity_bytes:
            self.rejected_puts += 1
            return None, 0.0
        while ts.used_bytes + nbytes > ts.capacity_bytes:
            if not self._evict_one(tier):
                self.rejected_puts += 1
                return None, 0.0

        entry_id = f"ctx{next(self._ids)}"
        chain = self.trie.insert(tokens, entry_id)
        if not chain:  # context shorter than one chunk: not storable
            self.rejected_puts += 1
            return None, 0.0
        e = StoredEntry(
            entry_id=entry_id,
            chain=chain,
            n_tokens=len(chain) * self.trie.chunk_tokens,
            nbytes=nbytes,
            compressed=compressed,
            tier=tier,
            created_s=self.clock.now,
            last_used_s=self.clock.now,
            saved_per_use=saved_per_use,
        )
        self.entries[entry_id] = e
        ts.used_bytes += nbytes
        handle = self.backends[tier].put(entry_id, artifact, nbytes)
        return entry_id, (handle.delay_s if sync else 0.0)

    # ------------------------------------------------------------------ #
    # Read path
    # ------------------------------------------------------------------ #
    def lookup(self, tokens: Sequence[int]) -> Tuple[PrefixMatch, Optional[StoredEntry]]:
        m = self.trie.longest_prefix(tokens)
        return m, (self.entries.get(m.entry_id) if m.entry_id else None)

    def fetch(
        self, entry_id: str, *, fraction: float = 1.0
    ) -> Tuple[Any, float]:
        """Load an artifact (optionally a prefix fraction of its bytes for
        partial attention-KV reuse).  Returns (decompressed artifact, delay_s)."""
        self._accrue()
        e = self.entries[entry_id]
        e.uses += 1
        e.last_used_s = self.clock.now
        nbytes = e.nbytes * max(0.0, min(1.0, fraction))
        payload, handle = self.backends[e.tier].get(entry_id, nbytes=nbytes)
        art = compression.decompress_tree(payload) if e.compressed else payload
        return art, handle.delay_s

    def estimate_load_delay(self, tier: str, nbytes: float) -> float:
        """Backend-modeled (hedged) read delay for ``nbytes`` from ``tier``,
        charging nothing — the prefetch/economics planning surface."""
        return self.backends[tier].estimate_load_delay(nbytes)

    # ------------------------------------------------------------------ #
    # Tier movement / eviction
    # ------------------------------------------------------------------ #
    def demote(self, entry_id: str, to_tier: str) -> bool:
        e = self.entries.get(entry_id)
        if e is None or e.tier == to_tier:
            return False
        dst = self.tiers[to_tier]
        if dst.used_bytes + e.nbytes > dst.capacity_bytes:
            return False
        self._accrue()
        payload = self.backends[e.tier].peek(entry_id)
        self.backends[e.tier].delete(entry_id)
        self.tiers[e.tier].used_bytes -= e.nbytes
        if to_tier == self.compress_tier and not e.compressed:
            payload = compression.compress_tree(payload)
            e.compressed = True
            e.nbytes = compression.tree_nbytes(payload)
        e.tier = to_tier
        dst.used_bytes += e.nbytes
        # tier migration, not a serving write: bytes move uncharged
        self.backends[to_tier].put(entry_id, payload, e.nbytes, charge=False)
        return True

    def _gb_hour_rate(self, tier: str) -> float:
        if self.pricing is not None and tier in self.pricing.tiers:
            return self.pricing.tier(tier).cost_per_gb_hour
        return _FALLBACK_GB_HOUR_RATE

    def _score(self, e: StoredEntry, pricing_rate: float) -> float:
        """Cost-aware eviction score (higher = keep): $ saved per hour by this
        entry minus its $ storage rate; LRU mode uses recency only."""
        if self.eviction == "lru":
            return e.last_used_s
        age_h = max((self.clock.now - e.created_s) / 3600.0, 1e-6)
        save_rate = e.saved_per_use * e.uses / age_h
        hold_rate = pricing_rate * e.nbytes / GB
        return save_rate - hold_rate

    def _evict_one(self, tier: str) -> bool:
        cands = [e for e in self.entries.values() if e.tier == tier]
        if not cands:
            return False
        rate = self._gb_hour_rate(tier)
        victim = min(cands, key=lambda e: self._score(e, pricing_rate=rate))
        self.trie.remove(victim.chain, victim.entry_id)
        self.tiers[tier].used_bytes -= victim.nbytes
        self.backends[tier].delete(victim.entry_id)
        del self.entries[victim.entry_id]
        self.evictions += 1
        return True

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        self._accrue()
        return {
            "entries": len(self.entries),
            "evictions": self.evictions,
            "rejected_puts": self.rejected_puts,
            "tiers": {
                n: {"used_gb": t.used_bytes / GB, "gb_hours": t.gb_hours}
                for n, t in self.tiers.items()
            },
        }
