"""Simulated-time transfer accounting over real data movement.

This container has no TPU/storage fabric, so the framework moves *real
tensors* (host numpy <-> device) while charging *modeled time* from the
analytical PerfModel — the same split the dry-run uses for compute.  All
delay/cost numbers the serving engine reports flow through this module, so
the modeling surface is one screen of code.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional

from repro.core.perf_model import PerfModel
from repro.core.pricing import GB, Pricing


@dataclasses.dataclass(frozen=True)
class TransferHandle:
    """One modeled byte movement against a storage tier.

    ``issued_at_s``/``completes_at_s`` are SimClock times; the transfer is
    logically in flight during that window (the data itself moves eagerly —
    this container has no storage fabric, so only time is simulated).
    """

    key: str
    tier: str
    kind: str  # "load" | "store"
    nbytes: float
    delay_s: float
    issued_at_s: float
    # time spent waiting for a free slot on a concurrency-limited link
    # (``hierarchy.ConcurrencyLimitedBackend``); included in ``delay_s``.
    queue_s: float = 0.0
    # True when a content-addressed shared tier already held identical bytes
    # (``hierarchy.SharedTierBackend``): no upload happened, so nbytes/delay
    # are zero and no fee accrues.
    dedup: bool = False

    @property
    def completes_at_s(self) -> float:
        return self.issued_at_s + self.delay_s


class SimClock:
    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self.now += dt
        return self.now

    def at_least(self, t: float) -> float:
        self.now = max(self.now, t)
        return self.now


@dataclasses.dataclass
class TransferStats:
    loaded_bytes: float = 0.0
    stored_bytes: float = 0.0
    load_events: int = 0
    store_events: int = 0
    load_time_s: float = 0.0
    store_time_s: float = 0.0


class TransferModel:
    """Load/store delay + $ accounting for each storage tier.

    With a cost ledger bound (``bind_ledger``; telemetry only — None by
    default and zero-overhead then), every CHARGED movement also writes one
    attributed fee entry: per-event fees sum to ``transfer_fees()`` within
    float re-association, which is the transfer leg of the ledger's
    conservation law (``obs/ledger.py``).  Attribution context (activity /
    req_id) is a dynamic scope the engine brackets operations with::

        with transfer.attributed(activity="fetch", req_id=7):
            store.fetch(...)   # any charge inside lands on request 7
    """

    def __init__(self, perf: PerfModel, pricing: Pricing):
        self.perf = perf
        self.pricing = pricing
        self.stats: Dict[str, TransferStats] = {}
        self.ledger = None  # obs.CostLedger when telemetry is on
        self._replica = 0
        self._ctx: Dict[str, object] = {}

    def bind_ledger(self, ledger, *, replica: int = 0) -> None:
        self.ledger = ledger
        self._replica = replica

    @contextlib.contextmanager
    def attributed(self, *, activity: str, req_id: Optional[int] = None):
        old = self._ctx
        self._ctx = {"activity": activity, "req_id": req_id}
        try:
            yield
        finally:
            self._ctx = old

    def _charge(self, tier_name: str, kind: str, nbytes: float) -> None:
        fee = self.pricing.tier(tier_name).per_gb_transfer_fee * nbytes / GB
        self.ledger.record_transfer(
            tier_name, kind, nbytes, fee,
            activity=str(self._ctx.get("activity", "other")),
            replica=self._replica,
            req_id=self._ctx.get("req_id"),
        )

    def _tier_stats(self, tier: str) -> TransferStats:
        return self.stats.setdefault(tier, TransferStats())

    def load_delay(self, nbytes: float, tier_name: str) -> float:
        t = self.perf.kv_load_time(nbytes, self.pricing.tier(tier_name))
        s = self._tier_stats(tier_name)
        s.loaded_bytes += nbytes
        s.load_events += 1
        s.load_time_s += t
        if self.ledger is not None:
            self._charge(tier_name, "load", nbytes)
        return t

    def store_delay(self, nbytes: float, tier_name: str) -> float:
        t = self.perf.kv_store_time(nbytes, self.pricing.tier(tier_name))
        s = self._tier_stats(tier_name)
        s.stored_bytes += nbytes
        s.store_events += 1
        s.store_time_s += t
        if self.ledger is not None:
            self._charge(tier_name, "store", nbytes)
        return t

    def estimate_load_delay(self, nbytes: float, tier_name: str) -> float:
        """Pure delay estimate — no bytes charged to the link stats (used by
        prefetch planning and economics-at-scale overrides)."""
        return self.perf.kv_load_time(nbytes, self.pricing.tier(tier_name))

    def transfer_fees(self) -> float:
        total = 0.0
        for name, s in self.stats.items():
            tier = self.pricing.tier(name)
            total += tier.per_gb_transfer_fee * (s.loaded_bytes + s.stored_bytes) / GB
        return total
