"""Simulated-time transfer accounting over real data movement.

This container has no TPU/storage fabric, so the framework moves *real
tensors* (host numpy <-> device) while charging *modeled time* from the
analytical PerfModel — the same split the dry-run uses for compute.  All
delay/cost numbers the serving engine reports flow through this module, so
the modeling surface is one screen of code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.perf_model import PerfModel
from repro.core.pricing import GB, Pricing


@dataclasses.dataclass(frozen=True)
class TransferHandle:
    """One modeled byte movement against a storage tier.

    ``issued_at_s``/``completes_at_s`` are SimClock times; the transfer is
    logically in flight during that window (the data itself moves eagerly —
    this container has no storage fabric, so only time is simulated).
    """

    key: str
    tier: str
    kind: str  # "load" | "store"
    nbytes: float
    delay_s: float
    issued_at_s: float
    # time spent waiting for a free slot on a concurrency-limited link
    # (``hierarchy.ConcurrencyLimitedBackend``); included in ``delay_s``.
    queue_s: float = 0.0
    # True when a content-addressed shared tier already held identical bytes
    # (``hierarchy.SharedTierBackend``): no upload happened, so nbytes/delay
    # are zero and no fee accrues.
    dedup: bool = False

    @property
    def completes_at_s(self) -> float:
        return self.issued_at_s + self.delay_s


class SimClock:
    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self.now += dt
        return self.now

    def at_least(self, t: float) -> float:
        self.now = max(self.now, t)
        return self.now


@dataclasses.dataclass
class TransferStats:
    loaded_bytes: float = 0.0
    stored_bytes: float = 0.0
    load_events: int = 0
    store_events: int = 0
    load_time_s: float = 0.0
    store_time_s: float = 0.0


class TransferModel:
    """Load/store delay + $ accounting for each storage tier."""

    def __init__(self, perf: PerfModel, pricing: Pricing):
        self.perf = perf
        self.pricing = pricing
        self.stats: Dict[str, TransferStats] = {}

    def _tier_stats(self, tier: str) -> TransferStats:
        return self.stats.setdefault(tier, TransferStats())

    def load_delay(self, nbytes: float, tier_name: str) -> float:
        t = self.perf.kv_load_time(nbytes, self.pricing.tier(tier_name))
        s = self._tier_stats(tier_name)
        s.loaded_bytes += nbytes
        s.load_events += 1
        s.load_time_s += t
        return t

    def store_delay(self, nbytes: float, tier_name: str) -> float:
        t = self.perf.kv_store_time(nbytes, self.pricing.tier(tier_name))
        s = self._tier_stats(tier_name)
        s.stored_bytes += nbytes
        s.store_events += 1
        s.store_time_s += t
        return t

    def estimate_load_delay(self, nbytes: float, tier_name: str) -> float:
        """Pure delay estimate — no bytes charged to the link stats (used by
        prefetch planning and economics-at-scale overrides)."""
        return self.perf.kv_load_time(nbytes, self.pricing.tier(tier_name))

    def transfer_fees(self) -> float:
        total = 0.0
        for name, s in self.stats.items():
            tier = self.pricing.tier(name)
            total += tier.per_gb_transfer_fee * (s.loaded_bytes + s.stored_bytes) / GB
        return total
