import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell this lowers + compiles the
real step function (train_step / prefill / decode) against ShapeDtypeStruct
inputs with the production shardings, then records:

  * memory_analysis()  — bytes per device (proves fit / flags overflow),
  * cost_analysis()    — HLO FLOPs + bytes for the roofline terms,
  * collective bytes   — parsed from the post-SPMD optimized HLO text
                         (all-gather / all-reduce / reduce-scatter /
                          all-to-all / collective-permute),

into ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for
``benchmarks/roofline.py`` and EXPERIMENTS.md.

NOTE the XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); this module is the only place that forces 512
host devices.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.configs.base import SHAPES, cell_is_runnable
from repro.configs.shapes import input_specs
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in post-SPMD optimized HLO."""
    totals = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_blob, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_blob):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _param_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, kwargs_of_specs, in_shardings_tree)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = input_specs(cfg, shape)
    api = registry.get_model(cfg)

    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_spec = jax.eval_shape(lambda k: api.init(k, cfg), key_spec)
    p_specs = sharding.param_specs(cfg, params_spec, mesh)
    d_specs = sharding.data_specs(cfg, cell.batch, shape.global_batch, mesh)

    if cell.kind == "train":
        opt = AdamW(lr=1e-4)
        opt_state_spec = jax.eval_shape(opt.init, params_spec)
        o_specs = __import__("repro.training.optimizer", fromlist=["opt_specs"]).opt_specs(
            p_specs, params_spec, mesh
        )
        step = make_train_step(cfg, opt)
        fn = lambda params, opt_state, batch: step(params, opt_state, batch)
        args = (params_spec, opt_state_spec, cell.batch)
        in_shard = (p_specs, o_specs, d_specs)
        return fn, args, in_shard

    if cell.kind == "prefill":
        def fn(params, batch):
            tokens = batch.get("tokens")
            embeds = batch.get("embeds")
            state = batch["state"]
            if cfg.family == "encdec":
                return api.prefill(params, cfg, tokens, state, embeds=embeds)
            if embeds is not None:
                return api.prefill(params, cfg, tokens, state, embeds=embeds)
            return api.prefill(params, cfg, tokens, state)

        return fn, (params_spec, cell.batch), (p_specs, d_specs)

    def fn(params, batch):
        return api.decode(params, cfg, batch["tokens"], batch["state"])

    return fn, (params_spec, cell.batch), (p_specs, d_specs)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": SHAPES[shape_name].kind, "runnable": ok,
    }
    if not ok:
        record["skip_reason"] = why
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, in_shard = build_cell(arch, shape_name, mesh)
        with mesh:
            named = sharding.to_named(in_shard, mesh)
            lowered = jax.jit(fn, in_shardings=named).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        record.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collectives=coll,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
            },
            hlo_collective_lines=len(
                [l for l in hlo.splitlines() if _COLLECTIVE_RE.search(l)]
            ),
        )
        print(
            f"[dryrun] OK  {arch:24s} {shape_name:12s} {mesh_name:10s} "
            f"flops={record['flops']:.3e} coll={coll['total']/1e9:.2f}GB "
            f"compile={t_compile:.1f}s"
        )
    except Exception as e:  # noqa: BLE001 — record and keep sweeping
        record.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] FAIL {arch} {shape_name} {mesh_name}: {e}")
    out_path.write_text(json.dumps(record, indent=2))
    return record


def calibrate_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path) -> dict:
    """Depth calibration: XLA cost_analysis counts a while-loop body ONCE, so
    the full-model numbers undercount the scanned layers.  Compile UNROLLED
    1-period and 2-period variants; the difference is the exact per-period
    cost and roofline.py extrapolates linearly to the real depth:

        f(d) = const + d*per_period   =>   f(D) = f1 + (D - 1)*(f2 - f1)
    """
    import dataclasses

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = os.environ.get("REPRO_DRYRUN_TAG", "")
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}__calib.json"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {}
    period = cfg.n_layers // cfg.n_attn_layers if False else None
    from repro.models.blocks import block_kinds

    p_len = len(block_kinds(cfg)) if cfg.family != "encdec" else 1
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "periods_full": cfg.n_layers // p_len}
    mesh = make_production_mesh(multi_pod=multi_pod)
    for tag, mult in (("d1", 1), ("d2", 2)):
        sub = dict(n_layers=p_len * mult, scan_unroll=True)
        if cfg.family == "encdec":
            sub["n_encoder_layers"] = mult
        cfg_small = dataclasses.replace(cfg, **sub)
        # register so get_config-independent paths (registry caches) stay clean
        import repro.configs as C

        C.CONFIGS[cfg_small.name] = cfg_small
        try:
            fn, args_, in_shard = _build_for(cfg_small, shape_name, mesh)
            with mesh:
                named = sharding.to_named(in_shard, mesh)
                compiled = jax.jit(fn, in_shardings=named).lower(*args_).compile()
                cost = compiled.cost_analysis()
                coll = collective_bytes(compiled.as_text())
            record[tag] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "collective_total": coll["total"],
            }
        except Exception as e:  # noqa: BLE001
            record[tag] = {"error": f"{type(e).__name__}: {e}"}
        finally:
            C.CONFIGS.pop(cfg_small.name, None)
    out_path.write_text(json.dumps(record, indent=2))
    ok1 = "error" not in record.get("d1", {"error": 1})
    ok2 = "error" not in record.get("d2", {"error": 1})
    print(f"[calib] {arch} {shape_name} {mesh_name}: d1={'ok' if ok1 else 'FAIL'} d2={'ok' if ok2 else 'FAIL'}")
    return record


def _build_for(cfg, shape_name, mesh):
    """build_cell but for an explicit (possibly depth-reduced) config."""
    shape = SHAPES[shape_name]
    cell = input_specs(cfg, shape)
    api = registry.get_model(cfg)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_spec = jax.eval_shape(lambda k: api.init(k, cfg), key_spec)
    p_specs = sharding.param_specs(cfg, params_spec, mesh)
    d_specs = sharding.data_specs(cfg, cell.batch, shape.global_batch, mesh)
    if cell.kind == "train":
        from repro.training.optimizer import opt_specs as _opt_specs

        opt = AdamW(lr=1e-4)
        opt_state_spec = jax.eval_shape(opt.init, params_spec)
        o_specs = _opt_specs(p_specs, params_spec, mesh)
        step = make_train_step(cfg, opt)
        return (
            lambda params, opt_state, batch: step(params, opt_state, batch),
            (params_spec, opt_state_spec, cell.batch),
            (p_specs, o_specs, d_specs),
        )
    if cell.kind == "prefill":
        def fn(params, batch):
            return api.prefill(
                params, cfg, batch.get("tokens"), batch["state"],
                embeds=batch.get("embeds"),
            ) if (cfg.family == "encdec" or "embeds" in batch) else api.prefill(
                params, cfg, batch["tokens"], batch["state"]
            )

        return fn, (params_spec, cell.batch), (p_specs, d_specs)

    def fn(params, batch):
        return api.decode(params, cfg, batch["tokens"], batch["state"])

    return fn, (params_spec, cell.batch), (p_specs, d_specs)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="depth-calibration compiles (see calibrate_cell)")
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                tag = args.tag or os.environ.get("REPRO_DRYRUN_TAG", "")
                suffix = (f"__{tag}" if tag else "") + (
                    "__calib" if args.calibrate else ""
                )
                f = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
                if args.skip_existing and f.exists():
                    prev = json.loads(f.read_text())
                    if args.calibrate or prev.get("ok") or not prev.get("runnable", True):
                        continue
                if args.calibrate:
                    calibrate_cell(arch, shape_name, multi, out_dir)
                    continue
                rec = run_cell(arch, shape_name, multi, out_dir, tag=args.tag)
                if not rec.get("runnable", True):
                    n_skip += 1
                elif rec.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"[dryrun] done: ok={n_ok} fail={n_fail} documented-skips={n_skip}")


if __name__ == "__main__":
    main()
