"""Production mesh construction (factory function — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types``/``AxisType``
    first appeared after 0.4.x — pass explicit Auto types when the running
    jax has them (the default there anyway), and omit the kwarg otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16); the ``pod``
    axis is pure data-parallel (crosses DCI once per step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over real local devices (tests / examples)."""
    return make_mesh_compat((data, model), ("data", "model"))
