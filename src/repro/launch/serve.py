"""Production serving launcher.

On a TPU host this binds the engine to the pod mesh and real request
ingress; in this container it runs the same engine against a synthetic
context-sharing workload (reduced compute, full-size economics via
``--cost-arch``) — the launcher surface is identical either way.

    PYTHONPATH=src python -m repro.launch.serve --arch llama-7b \
        --requests 32 --contexts 8 --policy cost --compress
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, list_configs, reduced_config
from repro.core.perf_model import PerfModel, V100_X4_HF, tpu_v5e
from repro.core.pricing import AWS_PAPER, tpu_v5e_pod
from repro.data.synthetic import WorkloadSpec, serving_workload
from repro.models import registry
from repro.serving import (
    AlwaysReusePlanner,
    CostAwarePlanner,
    EngineConfig,
    ServingEngine,
)
from repro.serving.scheduler import HedgePolicy


def main() -> None:
    ap = argparse.ArgumentParser(description="serving launcher")
    ap.add_argument("--arch", default="llama-7b", choices=list_configs())
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--contexts", type=int, default=8)
    ap.add_argument("--context-len", type=int, default=96)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--output-len", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="cost", choices=["cost", "always", "never"])
    ap.add_argument("--compress", action="store_true", help="int8 storage tier")
    ap.add_argument("--overlap", action="store_true", help="prefetch overlap")
    ap.add_argument("--hedge", action="store_true", help="hedged storage reads")
    ap.add_argument("--platform", default="paper", choices=["paper", "tpu"])
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="run reduced compute with full-size economics (CPU)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    full_cfg = get_config(args.arch)
    cfg = reduced_config(full_cfg) if args.reduced else full_cfg
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)

    if args.platform == "tpu":
        pricing, perf = tpu_v5e_pod(256), PerfModel(tpu_v5e(256))
    else:
        pricing, perf = AWS_PAPER, PerfModel(V100_X4_HF)

    ec = EngineConfig(
        max_slots=args.slots,
        max_len=args.context_len + args.prompt_len + args.output_len + 32,
        chunk_tokens=16,
        reuse_enabled=args.policy != "never",
        compress_tier="io2" if args.compress else None,
        overlap_load=args.overlap,
        hedge=HedgePolicy() if args.hedge else None,
        cost_arch=args.arch if args.reduced else None,
    )
    planner = AlwaysReusePlanner() if args.policy == "always" else CostAwarePlanner()
    engine = ServingEngine(
        cfg, params, engine_cfg=ec, planner=planner, pricing=pricing, perf=perf
    )

    spec = WorkloadSpec(
        n_contexts=args.contexts,
        reuses_per_context=max(1, args.requests // args.contexts),
        context_len=args.context_len,
        prompt_len=args.prompt_len,
        output_len=args.output_len,
        arrival_rate_per_s=2.0,
    )
    for req in serving_workload(cfg, spec):
        engine.submit(req)
    summary = engine.run()

    if args.json:
        print(json.dumps({**summary.as_dict(), "store": engine.store.stats()}, indent=2))
    else:
        print(f"served {summary.n_requests} requests "
              f"({summary.reuse_hits} reuse hits) on {cfg.name}")
        print(f"  cost ${summary.total_cost:.4f} "
              f"(compute {summary.compute_cost:.4f} / storage {summary.storage_cost:.6f} "
              f"/ transfer {summary.transfer_cost:.6f})")
        print(f"  TTFT mean {summary.mean_ttft_s:.3f}s p99 {summary.p99_ttft_s:.3f}s; "
              f"e2e p99 {summary.p99_e2e_s:.3f}s")


if __name__ == "__main__":
    main()
