"""Production training launcher: mesh-aware pjit train loop with the full
resilience substrate (auto-resume, async checkpoints, straggler tracking).

On a real pod, run under the production mesh (data/model axes over real
devices); on this host it uses the local device mesh.  The step function,
sharding rules and checkpoint format are identical in both cases — that is
the point of the launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs, reduced_config
from repro.data.synthetic import token_batches
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.training.fault import LoopConfig, ResilientLoop
from repro.training.optimizer import AdamW, cosine_schedule, opt_specs
from repro.training.train_step import make_grad_accum_step, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description="training launcher")
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_configs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1, help="grad accumulation")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    api = registry.get_model(cfg)

    mesh = make_host_mesh(data=1, model=1)
    opt = AdamW(lr=args.lr, weight_decay=0.01,
                schedule=cosine_schedule(warmup=10, total=args.steps))
    step = (
        make_train_step(cfg, opt)
        if args.accum == 1
        else make_grad_accum_step(cfg, opt, args.accum)
    )

    with mesh:
        params = api.init(jax.random.PRNGKey(0), cfg)
        p_specs = sharding.param_specs(cfg, params, mesh)
        o_specs = opt_specs(p_specs, params, mesh)
        step_fn = jax.jit(
            step,
            in_shardings=(
                sharding.to_named(p_specs, mesh),
                sharding.to_named(o_specs, mesh),
                None,
            ),
        )

        it = token_batches(cfg, batch=args.batch, seq_len=args.seq, seed=0)
        cache = {}

        def batch_fn(i):
            if i not in cache:
                cache[i] = {k: jnp.asarray(v) for k, v in next(it).items()}
            return cache[i]

        loop = ResilientLoop(
            step_fn, batch_fn,
            LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir),
        )
        out = loop.run(params, opt.init(params))
    print(f"{cfg.name}: step {out['completed']} "
          f"loss {float(out['metrics']['loss']):.4f} stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
