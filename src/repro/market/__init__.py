"""KV marketplace: a multi-tenant peer economy for stored caches.

"Can I Buy Your KV Cache?" (PAPERS.md) asks the natural sequel to the
source paper's break-even math: if stored KV beats recompute on $, it is a
*tradeable asset*.  This package layers a peer economy on the existing
storage/serving stack:

  * ``TenantStore`` / ``Catalog``  — a tenant's sellable, ACL-filtered view
    over its ``TieredStore``, each entry priced from the seller's Pricing
    plus an amortized write premium (catalog.py);
  * ``MarketPlanner``              — wraps the CostAware/Blend planner chain
    and shops quotes across peers at plan time, buy-vs-recompute by marginal
    cost with RPC latency and seller link contention folded into TTFT
    (planner.py);
  * ``SettlementLedger``           — extends ``obs.ledger.CostLedger`` with
    a "market" category: every purchase debits the buyer and credits the
    seller minus the market fee, conservation asserted at 1e-9
    (settlement.py);
  * ``ReputationBook``             — trust: purchased payloads are checksum-
    verified always and spot-checked against a bit-exact recompute sample;
    sellers caught serving corrupt payloads are priced up and blacklisted
    (reputation.py, market.py);
  * ``Marketplace`` / ``MarketSession`` — the exchange itself: quoting,
    delivery, verification, settlement, and the adversary hook that reuses
    the ``kvcache.faults`` corruption machinery as a dishonest seller
    (market.py).

KVShare-style multi-tenant dedup rides ``SharedBackendCore``: identical
content uploaded by two tenants stores once; the second upload settles as a
zero-byte dedup credit (``MarketSession.note_dedup``).

The marketplace is opt-in: engines built without a session behave exactly
as before (the golden seed trace is untouched), and a purchased payload is
bit-identical KV, so generated tokens match recompute exactly.
"""
from repro.market.catalog import Catalog, CatalogEntry, TenantStore
from repro.market.market import Marketplace, MarketResult, MarketSession, Quote
from repro.market.planner import MarketPlanner
from repro.market.reputation import ReputationBook
from repro.market.settlement import SettlementLedger

__all__ = [
    "Catalog",
    "CatalogEntry",
    "TenantStore",
    "Marketplace",
    "MarketResult",
    "MarketSession",
    "Quote",
    "MarketPlanner",
    "ReputationBook",
    "SettlementLedger",
]
