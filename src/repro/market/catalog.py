"""Per-tenant sellable views over a ``TieredStore``.

A ``TenantStore`` wraps one tenant's store with the two things a market
needs that the store itself does not have: an **ask price** per entry and an
**access-control list**.  The priced ``Catalog`` it publishes is the
marketplace's quoting surface; the prefix trie already inside the store is
the match index (chain hashes ARE the catalog keys), so quoting a context is
one trie walk per seller — no separate index to keep fresh.

Pricing follows the production prompt-cache rule (SNIPPETS.md): the seller
paid a write premium (~1.25x a read) to create the entry, and amortizes it
over the sales it expects, plus its tier's per-GB egress fee with a margin.
``saved_per_use`` — the GPU dollars one reuse of this entry saves, stamped
at write-back time — is exactly the right base: the ask lands at
``write_premium / expected_sales`` of the buyer's recompute cost, so a full
match is always a good deal for the buyer while still repaying the seller's
storage investment.

ACL: entries default **public** (the marketplace premise); ``set_private``
removes one from the catalog entirely — a private entry can never be
matched, quoted, or fetched by another tenant (the invariant the hypothesis
suite drives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Set, Tuple

from repro.core.pricing import GB, Pricing
from repro.kvcache import compression
from repro.kvcache.faults import payload_checksum
from repro.kvcache.store import StoredEntry


@dataclasses.dataclass(frozen=True)
class CatalogEntry:
    """One sellable entry: identity, size, and the seller's full-entry ask
    (pro-rated by matched fraction at quote time).  ``checksum`` is the
    payload checksum of the *decompressed* artifact — the form a buyer
    receives — stamped from the seller's own bytes at publication, so any
    in-flight tampering by a dishonest seller is detectable."""

    seller: str
    entry_id: str
    n_tokens: int
    nbytes: float
    tier: str
    ask_dollars: float
    checksum: str
    public: bool = True


@dataclasses.dataclass(frozen=True)
class Catalog:
    """A tenant's published price list (public, live entries only)."""

    seller: str
    entries: Tuple[CatalogEntry, ...]

    def __len__(self) -> int:
        return len(self.entries)

    def total_bytes(self) -> float:
        return sum(e.nbytes for e in self.entries)


class TenantStore:
    """One tenant's market-facing wrapper: ACL + pricing over its store.

    ``transfer`` (the tenant engine's ``TransferModel``, when bound through
    a ``MarketSession``) lets the marketplace attribute seller-side fetch
    fees to a ``market_sale`` activity, keeping the seller's own cost
    conservation exact.
    """

    def __init__(
        self,
        tenant: str,
        store,
        *,
        pricing: Optional[Pricing] = None,
        transfer=None,
        write_premium: float = 0.25,
        expected_sales: float = 4.0,
        margin: float = 0.10,
    ) -> None:
        self.tenant = tenant
        self.store = store
        self.pricing = pricing
        self.transfer = transfer
        # the premium share of the write the seller recovers per expected
        # sale (production caches price a cache write ~1.25x a read; the
        # 0.25x premium is what the ask must amortize)
        self.write_premium = write_premium
        self.expected_sales = max(expected_sales, 1.0)
        self.margin = margin
        self._private: Set[str] = set()
        # checksum of the decompressed artifact, cached per stored identity
        self._checksums: Dict[Tuple[str, bool], str] = {}
        self.revenue = 0.0  # settled credits (mirror of the ledger account)
        self.sales = 0

    # -- ACL ------------------------------------------------------------- #
    def set_private(self, entry_id: str) -> None:
        self._private.add(entry_id)

    def set_public(self, entry_id: str) -> None:
        self._private.discard(entry_id)

    def is_public(self, entry_id: str) -> bool:
        return entry_id not in self._private

    # -- pricing --------------------------------------------------------- #
    def ask_dollars(self, e: StoredEntry) -> float:
        """Full-entry ask: amortized write premium + egress fee with margin."""
        fee = 0.0
        if self.pricing is not None and e.tier in self.pricing.tiers:
            fee = self.pricing.tier(e.tier).per_gb_transfer_fee * e.nbytes / GB
        premium = self.write_premium * e.saved_per_use / self.expected_sales
        return (1.0 + self.margin) * fee + premium

    def checksum(self, entry_id: str) -> Optional[str]:
        """Publication-time checksum of the entry's deliverable (decompressed)
        payload, read without charging (``peek``)."""
        e = self.store.entries.get(entry_id)
        if e is None:
            return None
        key = (entry_id, e.compressed)
        got = self._checksums.get(key)
        if got is None:
            payload = self.store.backends[e.tier].peek(entry_id)
            if payload is None:
                return None
            if e.compressed:
                payload = compression.decompress_tree(payload)
            got = payload_checksum(payload)
            self._checksums[key] = got
        return got

    # -- market surface -------------------------------------------------- #
    def catalog(self) -> Catalog:
        entries = []
        for e in self.store.entries.values():
            if not self.is_public(e.entry_id):
                continue
            cs = self.checksum(e.entry_id)
            if cs is None:
                continue
            entries.append(
                CatalogEntry(
                    seller=self.tenant,
                    entry_id=e.entry_id,
                    n_tokens=e.n_tokens,
                    nbytes=e.nbytes,
                    tier=e.tier,
                    ask_dollars=self.ask_dollars(e),
                    checksum=cs,
                )
            )
        return Catalog(seller=self.tenant, entries=tuple(entries))

    def match(self, tokens: Sequence[int]) -> Tuple[Any, Optional[StoredEntry]]:
        """ACL-filtered prefix match: a private entry is a miss to outsiders."""
        m, e = self.store.lookup(tokens)
        if e is not None and not self.is_public(e.entry_id):
            return m, None
        return m, e
