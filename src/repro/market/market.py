"""The exchange: quoting, delivery, verification, settlement.

``Marketplace`` holds the tenant registry, the ``SettlementLedger``, the
``ReputationBook``, and the adversary hooks.  One purchase runs:

    quote   — walk every non-blacklisted peer's trie for the buyer's
              context (ACL-filtered), price the best match (seller ask
              pro-rated by matched fraction, times the seller's risk
              multiplier, plus the flat transaction fee), and fold seller
              link contention + RPC latency into the load estimate;
    deliver — fetch from the SELLER's store (fees attributed to its
              transfer model as a ``market_sale``), then give any armed
              adversary its chance to tamper with the bytes in flight —
              the dishonest-seller model: the seller's stored copy stays
              intact, the DELIVERY lies;
    verify  — checksum against the publication-time stamp ALWAYS, plus a
              probabilistic deep spot-check: the buyer's engine recomputes
              a prefix sample and compares the purchased KV bit-exactly
              (``ServingEngine.market_spot_check``).  A failed verification
              means the payload is NEVER served: the seller is priced down
              or blacklisted and the request degrades to exact recompute;
    settle  — debit buyer, credit seller minus fee, conservation at 1e-9.

Determinism: the deep-verify draw hashes (seed, buyer, seller, entry,
purchase ordinal) — same run, same checks — and the first purchase from any
seller is always deep-checked, so a corrupt seller cannot survive even a
checksum collision fantasy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kvcache.faults import FaultInjector, StorageError, payload_checksum
from repro.market.catalog import TenantStore
from repro.market.reputation import ReputationBook
from repro.market.settlement import SettlementLedger
from repro.serving.events import KVPurchased, SellerBlacklisted, SellerVerified


@dataclasses.dataclass(frozen=True)
class Quote:
    """One seller's priced offer for a buyer's context prefix."""

    buyer: str
    seller: str
    entry_id: str
    tier: str  # seller-side tier the bytes would come from
    matched_tokens: int  # buyer-context tokens the entry's prefix covers
    n_tokens: int  # tokens the full entry covers
    nbytes: float  # bytes billed (pro-rated by matched fraction)
    price: float  # buyer spend: ask x fraction x risk multiplier + flat fee
    est_load_s: float  # seller link delay + queue wait + RPC round trip
    checksum: str  # publication-time stamp of the deliverable payload


@dataclasses.dataclass
class MarketResult:
    """Outcome of executing a quote."""

    ok: bool
    artifact: Any = None
    delay_s: float = 0.0  # delivery delay charged to the buyer's request
    nbytes: float = 0.0
    matched_tokens: int = 0
    price: float = 0.0
    verify_s: float = 0.0  # spot-check GPU seconds (buyer-side)
    verify_cost: float = 0.0  # spot-check GPU dollars (buyer-side)
    wasted_s: float = 0.0  # burned delay when the purchase failed
    reason: str = ""
    events: List[Any] = dataclasses.field(default_factory=list)


def _tamper(payload: Any) -> Any:
    """Flip one byte of the first array leaf — a dishonest delivery.  The
    seller's stored artifact is untouched (copies, never mutation), and the
    damage is guaranteed visible to both the checksum and a bit-exact
    compare, whatever the dtype."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(payload)
    out, done = [], False
    for leaf in leaves:
        if not done and hasattr(leaf, "dtype") and getattr(leaf, "size", 0):
            arr = np.asarray(leaf)
            raw = bytearray(arr.tobytes())
            raw[0] ^= 0xFF
            out.append(np.frombuffer(bytes(raw), dtype=arr.dtype).reshape(arr.shape))
            done = True
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


class MarketSession:
    """One tenant's handle on the marketplace.  ``bind_engine`` (called by
    the engine when constructed with ``market=``) publishes the engine's
    store as this tenant's ``TenantStore`` and keeps the engine for the
    deep-verify oracle."""

    def __init__(self, marketplace: "Marketplace", tenant: str) -> None:
        self.marketplace = marketplace
        self.tenant = tenant
        self.engine = None
        self.tenant_store: Optional[TenantStore] = None

    def bind_engine(self, engine) -> None:
        self.engine = engine
        self.tenant_store = TenantStore(
            self.tenant, engine.store, pricing=engine.pricing,
            transfer=engine.transfer,
        )
        self.marketplace.register(self.tenant, self.tenant_store, session=self)

    def quote(self, tokens: Sequence[int]) -> Optional[Quote]:
        return self.marketplace.quote(self.tenant, tokens)

    def execute(
        self, quote: Quote, *, req_id: int, now: float,
        context_tokens: Sequence[int] = (), replica: int = 0,
    ) -> MarketResult:
        return self.marketplace.execute(
            quote, req_id=req_id, now=now, context_tokens=context_tokens,
            replica=replica,
        )

    def note_dedup(self, nbytes: float, *, req_id: Optional[int] = None,
                   replica: int = 0) -> None:
        self.marketplace.settlement.record_dedup_credit(
            self.tenant, nbytes, req_id=req_id, replica=replica,
        )


class Marketplace:
    def __init__(
        self,
        *,
        fee_rate: float = 0.05,
        flat_fee: float = 0.0,
        rtt_s: float = 2e-4,
        verify_rate: float = 0.25,
        verify_sample_tokens: int = 16,
        seed: int = 0,
        blacklist_after: int = 1,
    ) -> None:
        self.rtt_s = rtt_s
        self.verify_rate = verify_rate
        self.verify_sample_tokens = verify_sample_tokens
        self.seed = seed
        self.tenants: Dict[str, TenantStore] = {}
        self.sessions: Dict[str, MarketSession] = {}
        self.settlement = SettlementLedger(fee_rate=fee_rate, flat_fee=flat_fee)
        self.reputation = ReputationBook(blacklist_after=blacklist_after)
        self._adversaries: Dict[str, FaultInjector] = {}
        self._pair_purchases: Dict[Tuple[str, str], int] = {}
        self.quotes_served = 0
        self.purchases = 0
        self.corrupt_blocked = 0  # tampered payloads caught by verification
        self.corrupt_served = 0  # must stay 0: the acceptance invariant
        self.failed_purchases = 0

    # -- membership -------------------------------------------------------- #
    def join(self, tenant: str) -> MarketSession:
        s = self.sessions.get(tenant)
        if s is None:
            s = self.sessions[tenant] = MarketSession(self, tenant)
        return s

    def register(
        self, tenant: str, store: TenantStore,
        *, session: Optional[MarketSession] = None,
    ) -> None:
        self.tenants[tenant] = store
        if session is not None:
            self.sessions[tenant] = session

    def arm_adversary(self, tenant: str, injector: FaultInjector) -> None:
        """Make ``tenant`` a dishonest seller: its deliveries pass through
        the injector's corruption draw (``faults.FaultInjector``) from now
        on.  Its stored bytes stay intact — only what it SHIPS lies."""
        self._adversaries[tenant] = injector

    # -- quoting ----------------------------------------------------------- #
    def quote(self, buyer: str, tokens: Sequence[int]) -> Optional[Quote]:
        """Best offer across peers for the buyer's context: longest match
        first, then cheapest."""
        best: Optional[Quote] = None
        for name, ts in self.tenants.items():
            if name == buyer or self.reputation.is_blacklisted(name):
                continue
            m, e = ts.match(tokens)
            if e is None:
                continue
            matched = min(m.matched_tokens, len(tokens))
            if matched <= 0:
                continue
            frac = min(1.0, matched / max(e.n_tokens, 1))
            nbytes = e.nbytes * frac
            cs = ts.checksum(e.entry_id)
            if cs is None:
                continue
            price = self.settlement.buyer_price(
                ts.ask_dollars(e) * frac * self.reputation.price_multiplier(name)
            )
            est = (
                ts.store.estimate_load_delay(e.tier, nbytes)
                + ts.store.estimated_queue_wait(e.tier, nbytes)
                + self.rtt_s
            )
            q = Quote(
                buyer=buyer, seller=name, entry_id=e.entry_id, tier=e.tier,
                matched_tokens=matched, n_tokens=e.n_tokens, nbytes=nbytes,
                price=price, est_load_s=est, checksum=cs,
            )
            if (
                best is None
                or q.matched_tokens > best.matched_tokens
                or (q.matched_tokens == best.matched_tokens and q.price < best.price)
            ):
                best = q
        if best is not None:
            self.quotes_served += 1
        return best

    # -- execution --------------------------------------------------------- #
    def _deep_verify_due(self, quote: Quote) -> bool:
        n = self._pair_purchases.get((quote.buyer, quote.seller), 0)
        if n == 0:
            return True  # first trade with this seller: always spot-check
        h = hashlib.blake2b(
            f"{self.seed}|{quote.buyer}|{quote.seller}|{quote.entry_id}|{n}".encode(),
            digest_size=8,
        ).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64 < self.verify_rate

    def execute(
        self,
        quote: Quote,
        *,
        req_id: int,
        now: float,
        context_tokens: Sequence[int] = (),
        replica: int = 0,
    ) -> MarketResult:
        seller = self.tenants.get(quote.seller)
        if seller is None or self.reputation.is_blacklisted(quote.seller):
            self.failed_purchases += 1
            return MarketResult(ok=False, reason="seller_gone")
        if quote.entry_id not in seller.store.entries:
            self.failed_purchases += 1
            return MarketResult(ok=False, reason="evicted")

        frac = min(1.0, quote.matched_tokens / max(quote.n_tokens, 1))
        attr = (
            seller.transfer.attributed(activity="market_sale")
            if seller.transfer is not None
            else contextlib.nullcontext()
        )
        try:
            with attr:
                payload, delay_s = seller.store.fetch(quote.entry_id, fraction=frac)
        except StorageError as err:
            self.failed_purchases += 1
            return MarketResult(
                ok=False, reason=f"seller_fetch:{err.reason}",
                wasted_s=getattr(err, "delay_s", 0.0),
            )

        inj = self._adversaries.get(quote.seller)
        if inj is not None and inj.should_corrupt("market", quote.entry_id):
            payload = _tamper(payload)

        # -- verification: checksum always, deep spot-check probabilistically
        ok = payload_checksum(payload) == quote.checksum
        deep = False
        verify_s = verify_cost = 0.0
        buyer_session = self.sessions.get(quote.buyer)
        engine = buyer_session.engine if buyer_session is not None else None
        if ok and engine is not None and self._deep_verify_due(quote):
            deep = True
            sample = min(self.verify_sample_tokens, quote.matched_tokens)
            ok, verify_s, verify_cost = engine.market_spot_check(
                tuple(context_tokens)[:quote.matched_tokens], payload, sample,
            )
        self._pair_purchases[(quote.buyer, quote.seller)] = (
            self._pair_purchases.get((quote.buyer, quote.seller), 0) + 1
        )

        events: List[Any] = [
            SellerVerified(
                t_s=now, req_id=req_id, seller=quote.seller,
                entry_id=quote.entry_id, ok=ok, deep=deep,
            )
        ]
        if not ok:
            # corrupt delivery caught BEFORE serving: no settlement, the
            # seller pays in reputation, the buyer degrades to recompute
            self.corrupt_blocked += 1
            self.failed_purchases += 1
            if self.reputation.record_verification(quote.seller, ok=False):
                events.append(
                    SellerBlacklisted(
                        t_s=now, req_id=req_id, seller=quote.seller,
                        corrupt_count=self.reputation.corrupt[quote.seller],
                    )
                )
            return MarketResult(
                ok=False, reason="verify_failed", wasted_s=delay_s + verify_s,
                verify_s=verify_s, verify_cost=verify_cost, events=events,
            )

        self.reputation.record_verification(quote.seller, ok=True)
        self.reputation.record_sale(quote.seller)
        credit = self.settlement.settle_purchase(
            buyer=quote.buyer, seller=quote.seller, price=quote.price,
            nbytes=quote.nbytes, entry_id=quote.entry_id, tier=quote.tier,
            replica=replica, req_id=req_id,
        )
        seller.revenue += credit
        seller.sales += 1
        self.purchases += 1
        events.insert(
            0,
            KVPurchased(
                t_s=now, req_id=req_id, seller=quote.seller, buyer=quote.buyer,
                entry_id=quote.entry_id, tier=quote.tier, nbytes=quote.nbytes,
                price=quote.price, matched_tokens=quote.matched_tokens,
            ),
        )
        return MarketResult(
            ok=True, artifact=payload, delay_s=delay_s + self.rtt_s,
            nbytes=quote.nbytes, matched_tokens=quote.matched_tokens,
            price=quote.price, verify_s=verify_s, verify_cost=verify_cost,
            events=events,
        )

    # -- reporting --------------------------------------------------------- #
    def stats(self) -> dict:
        return {
            "tenants": sorted(self.tenants),
            "quotes_served": self.quotes_served,
            "purchases": self.purchases,
            "corrupt_blocked": self.corrupt_blocked,
            "corrupt_served": self.corrupt_served,
            "failed_purchases": self.failed_purchases,
            "settlement": self.settlement.as_dict(),
            "reputation": self.reputation.as_dict(),
        }
