"""Buy-vs-recompute planning: the marketplace's entry into the planner chain.

``MarketPlanner`` wraps any existing planner (CostAware by default, or a
BlendPlanner for fusion-enabled engines) and adds ONE more option to the
auction the base already runs: buy the matched prefix KV from a peer.  The
buy option is priced honestly —

    est_ttft = quote.est_load_s (seller link + queue + RPC) + tail prefill
    est_cost = marginal compute for the unmatched tail and decode
               + the quote price (seller ask x risk multiplier + flat fee)

— and competes under the same SLO guard the fused option uses.  A winning
buy becomes a ``load``/``partial`` plan carrying the ``Quote`` in
``ReusePlan.market``; the engine's ``_market_fetch`` executes it (delivery,
verification, settlement) instead of a local store fetch.  The buyer's own
store always wins ties: a quote matching no more than the local prefix is
discarded before pricing.

``always=True`` is the always-buy baseline for benchmarks: buy whenever a
peer has anything and the local store can't serve a full load — the bench
gate requires the cost-aware mode to beat it (and never-buy) on total $.
"""
from __future__ import annotations

from typing import Optional

from repro.core import policy as policy_mod
from repro.core.cost_model import Workload
from repro.serving.planner import (
    CostAwarePlanner,
    ReusePlan,
    StoreLookup,
    _PlannerBase,
)
from repro.serving.request import Request


class MarketPlanner(_PlannerBase):
    def __init__(
        self, base: Optional[_PlannerBase] = None, *, session=None,
        always: bool = False,
    ) -> None:
        super().__init__()
        self.base: _PlannerBase = base or CostAwarePlanner()
        self.session = session
        self.always = always

    def configure(self, **kw) -> None:
        super().configure(**kw)
        self.base.configure(**kw)

    def _buy_plan(
        self, request: Request, lookup: StoreLookup, workload: Workload
    ) -> Optional[ReusePlan]:
        if self.session is None:
            return None
        quote = self.session.quote(tuple(request.context_tokens))
        if quote is None:
            return None
        n_ctx = len(request.context_tokens)
        matched = min(quote.matched_tokens, n_ctx)
        if matched <= lookup.prefix_tokens:
            return None  # own store covers at least as much, fee-free
        if matched < n_ctx and not lookup.partial_ok:
            return None  # architecture can't consume a partial prefix
        frac = matched / max(n_ctx, 1)
        tail = n_ctx - matched
        ttft = quote.est_load_s + self.perf.t_prefill(
            self.cost_cfg, workload.L_prompt + tail
        )
        # marginal compute for the tail + decode (tier=None: the transfer
        # economics live in the quote price, not in a storage-fee term)
        cost = policy_mod._marginal_request_cost(
            self.cost_cfg, workload, self.pricing, self.perf,
            tier=None, reused_fraction=frac,
        ) + quote.price
        return ReusePlan(
            action="load" if matched >= n_ctx else "partial",
            tier=f"market:{quote.seller}",
            matched_tokens=matched,
            reused_fraction=frac,
            fetch_bytes=quote.nbytes,
            store_after=False,
            est_ttft_s=ttft,
            est_cost=cost,
            market=quote,
        )

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        base_plan = self.base.plan(request, lookup, workload)
        buy = self._buy_plan(request, lookup, workload)
        if buy is None:
            return base_plan
        if self.always:
            # always-buy baseline: a full local load still wins (no bytes
            # to buy); anything less and the market gets the trade
            return base_plan if base_plan.action == "load" else buy
        slo = workload.slo_ttft_s
        if slo is not None and buy.est_ttft_s > slo >= base_plan.est_ttft_s:
            return base_plan
        return buy if buy.est_cost < base_plan.est_cost else base_plan
