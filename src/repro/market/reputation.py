"""Seller reputation: price risk in, eject the corrupt.

Every verification outcome (checksum and/or deep spot-check,
``market.Marketplace``) updates the seller's score in [floor, 1.0].  The
score feeds quoting as a **risk multiplier** — ``1/score`` — so a seller
with a blemished record must be proportionally cheaper to win a quote, and
a seller caught serving corrupt payloads ``blacklist_after`` times is
ejected outright: ``Marketplace.quote`` skips blacklisted sellers entirely,
which is the "never matched again" invariant the hypothesis suite drives.
"""
from __future__ import annotations

from typing import Dict, Set


class ReputationBook:
    def __init__(
        self,
        *,
        decay: float = 0.5,
        recover: float = 0.10,
        floor: float = 0.25,
        blacklist_after: int = 1,
    ) -> None:
        self.decay = decay
        self.recover = recover
        self.floor = floor
        self.blacklist_after = max(1, blacklist_after)
        self.scores: Dict[str, float] = {}
        self.corrupt: Dict[str, int] = {}
        self.sales: Dict[str, int] = {}
        self.blacklisted: Set[str] = set()

    def score(self, seller: str) -> float:
        return self.scores.get(seller, 1.0)

    def is_blacklisted(self, seller: str) -> bool:
        return seller in self.blacklisted

    def price_multiplier(self, seller: str) -> float:
        """Risk-adjusted quote multiplier: a seller at half trust must be
        half price to compete."""
        return 1.0 / max(self.score(seller), self.floor)

    def record_sale(self, seller: str) -> None:
        self.sales[seller] = self.sales.get(seller, 0) + 1

    def record_verification(self, seller: str, ok: bool) -> bool:
        """Update the book with one verification outcome.  Returns True iff
        this outcome NEWLY blacklisted the seller (the caller emits the
        ``SellerBlacklisted`` event exactly once)."""
        s = self.score(seller)
        if ok:
            self.scores[seller] = s + self.recover * (1.0 - s)
            return False
        self.corrupt[seller] = self.corrupt.get(seller, 0) + 1
        self.scores[seller] = max(self.floor, s * self.decay)
        if (
            self.corrupt[seller] >= self.blacklist_after
            and seller not in self.blacklisted
        ):
            self.blacklisted.add(seller)
            return True
        return False

    def as_dict(self) -> dict:
        return {
            "scores": dict(self.scores),
            "corrupt": dict(self.corrupt),
            "sales": dict(self.sales),
            "blacklisted": sorted(self.blacklisted),
        }
