"""Double-entry settlement for marketplace purchases.

``SettlementLedger`` extends the repo's exact cost ledger with a "market"
category and per-tenant accounts.  Every purchase writes BOTH sides:

    buyer account  -= price                      (debit, the quote price)
    seller account += price - fee                (credit, net of market fee)
    fees_collected += fee                        (the exchange's cut)

so the conservation law is structural:

    sum(accounts) + fees_collected == 0          (atol 1e-9)
    debits == credits + fees_collected

Ledger rows mirror the accounts: a "purchase" entry for the buyer's spend
and a negative "sale" entry for the seller's revenue, netting the category
to exactly the fees — the system-wide cost of running the market.  Dedup
credits (KVShare-style: a second tenant uploading identical content moved
zero bytes through ``SharedBackendCore``) are zero-dollar rows carrying the
saved byte counts, so "where did the bytes NOT go" stays answerable without
touching conservation.

Purchase dollars deliberately live here, NOT in any engine's own
``CostLedger``: engine conservation (compute/storage/transfer vs its
summary) must stay exact with the market on, so peer-to-peer flows settle
in their own book and the two books are reconciled by the bench gate.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.obs.ledger import CATEGORIES, CostLedger


class SettlementLedger(CostLedger):
    """Cost ledger + per-tenant market accounts with exact conservation."""

    CATEGORIES = CATEGORIES + ("market",)

    def __init__(self, *, fee_rate: float = 0.05, flat_fee: float = 0.0) -> None:
        super().__init__()
        self.fee_rate = fee_rate
        self.flat_fee = flat_fee
        self.accounts: Dict[str, float] = {}
        self.fees_collected = 0.0
        self.debits = 0.0
        self.credits = 0.0
        self.volume_bytes = 0.0
        self.dedup_bytes = 0.0
        self.n_purchases = 0
        self.n_dedup_credits = 0

    # -- quoting helper --------------------------------------------------- #
    def buyer_price(self, ask: float) -> float:
        """Buyer-facing price for a seller ask: the flat transaction fee is
        added on top, which is what makes tiny purchases uneconomical."""
        return ask + self.flat_fee

    def fee_for(self, price: float) -> float:
        """The exchange's cut of a buyer price: the flat fee plus a rate
        share of the remainder (the seller's ask portion)."""
        return self.flat_fee + self.fee_rate * max(0.0, price - self.flat_fee)

    # -- settlement -------------------------------------------------------- #
    def settle_purchase(
        self,
        *,
        buyer: str,
        seller: str,
        price: float,
        nbytes: float,
        entry_id: str,
        tier: Optional[str] = None,
        replica: int = 0,
        req_id: Optional[int] = None,
    ) -> float:
        """Debit the buyer, credit the seller net of fee.  Returns the
        seller's credit."""
        fee = self.fee_for(price)
        credit = price - fee
        self.accounts[buyer] = self.accounts.get(buyer, 0.0) - price
        self.accounts[seller] = self.accounts.get(seller, 0.0) + credit
        self.fees_collected += fee
        self.debits += price
        self.credits += credit
        self.volume_bytes += nbytes
        self.n_purchases += 1
        self.add(
            "market", "purchase", price, replica=replica, req_id=req_id,
            tier=tier, nbytes=nbytes, kind="buy",
        )
        self.add(
            "market", "sale", -credit, replica=replica, req_id=req_id,
            tier=tier, nbytes=nbytes, kind="sell",
        )
        return credit

    def record_dedup_credit(
        self, tenant: str, nbytes: float, *, replica: int = 0,
        req_id: Optional[int] = None,
    ) -> None:
        """KVShare dedup: the tenant's upload stored zero new bytes because
        an identical artifact already lives in the shared core.  Zero
        dollars move; the saved bytes are recorded."""
        self.dedup_bytes += nbytes
        self.n_dedup_credits += 1
        self.add(
            "market", "dedup_credit", 0.0, replica=replica, req_id=req_id,
            nbytes=nbytes,
        )

    # -- conservation ------------------------------------------------------ #
    def conservation_residual(self) -> float:
        return max(
            abs(sum(self.accounts.values()) + self.fees_collected),
            abs(self.debits - self.credits - self.fees_collected),
        )

    def assert_conserved(self, atol: float = 1e-9) -> float:
        r = self.conservation_residual()
        if not r <= atol:
            raise AssertionError(
                f"market settlement conservation violated (atol={atol}): "
                f"residual {r}; accounts={self.accounts}, "
                f"fees={self.fees_collected}"
            )
        return r

    def as_dict(self) -> dict:
        out = super().as_dict()
        out.update(
            accounts=dict(self.accounts),
            fees_collected=self.fees_collected,
            n_purchases=self.n_purchases,
            n_dedup_credits=self.n_dedup_credits,
            volume_bytes=self.volume_bytes,
            dedup_bytes=self.dedup_bytes,
            conservation_residual=self.conservation_residual(),
        )
        return out
