"""Grouped-query self-attention (+ cross-attention) with a slotted KV cache.

Three call modes share one weight set:
  * ``forward``  — full training forward (no cache).
  * ``prefill``  — writes KV for ``S`` new tokens at ``offset`` into the cache
                   and attends causally over ``[0, offset+S)``.  With
                   ``offset > 0`` this is the paper's *suffix prefill*: the
                   reused context KV occupying ``[0, offset)`` is NOT
                   recomputed.
  * ``decode``   — one token per sequence against the cache (ring-buffer
                   indexing for sliding-window attention).

Cache layout (TPU-native slotted dense cache, see DESIGN.md §3):
  k/v: [B, L_cache, KV_heads, head_dim]
where ``L_cache = min(max_len, window)`` for SWA archs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import common
from repro.models.common import KeyGen, Params
from repro.models.layers import apply_rope


class KVCache(NamedTuple):
    """Per-layer slotted KV cache (a pytree leaf-pair)."""

    k: jax.Array  # [B, L_cache, KV, hd]
    v: jax.Array  # [B, L_cache, KV, hd]


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> KVCache:
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dtype = dtype or common.resolve_dtype(cfg.dtype)
    shape = (batch, length, cfg.n_kv_heads, cfg.resolved_head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def init_attention(key: jax.Array, cfg: ArchConfig) -> Params:
    kg = KeyGen(key)
    pdtype = common.resolve_dtype(cfg.param_dtype)
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p: Params = {
        "wq": common.dense_init(kg(), (D, H, hd), pdtype, fan_in=D),
        "wk": common.dense_init(kg(), (D, KV, hd), pdtype, fan_in=D),
        "wv": common.dense_init(kg(), (D, KV, hd), pdtype, fan_in=D),
        "wo": common.dense_init(kg(), (H, hd, D), pdtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), pdtype)
        p["bk"] = jnp.zeros((KV, hd), pdtype)
        p["bv"] = jnp.zeros((KV, hd), pdtype)
    return p


def _qkv(p: Params, cfg: ArchConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _out(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bshe,hed->bsd", x, p["wo"].astype(x.dtype))


# --------------------------------------------------------------------------- #
# Training forward (no cache)
# --------------------------------------------------------------------------- #
def forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, D]
    *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,  # [B, S]
) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = ops.flash_attention(
        q, k, v, q_pos=positions, kv_pos=positions, causal=causal,
        window=cfg.sliding_window,
    )
    return _out(p, o)


# --------------------------------------------------------------------------- #
# Prefill (full or suffix) against a slotted cache
# --------------------------------------------------------------------------- #
def prefill(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, D] — the *new* (non-reused) tokens
    cache: KVCache,
    offset: jax.Array,  # [B] int32 — number of already-cached context tokens
) -> Tuple[jax.Array, KVCache]:
    B, S, _ = x.shape
    L = cache.k.shape[1]
    q, k_new, v_new = _qkv(p, cfg, x)
    positions = offset[:, None] + jnp.arange(S, dtype=jnp.int32)[None]  # [B, S]
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    if cfg.sliding_window and L == cfg.sliding_window:
        # Ring buffer (SWA). Queries early in the chunk need keys that later
        # writes would overwrite, so attend over [old ring ∪ new KV] and only
        # write each slot's LAST occurrence back into the ring.
        W = cfg.sliding_window
        old_pos = _ring_positions(offset, W, B)  # positions held before this call
        k_all = jnp.concatenate([cache.k, k_new], axis=1)
        v_all = jnp.concatenate([cache.v, v_new], axis=1)
        kv_pos_all = jnp.concatenate([old_pos, positions], axis=1)
        o = ops.flash_attention(
            q, k_all, v_all, q_pos=positions, kv_pos=kv_pos_all, causal=True, window=W
        )
        slots = positions % W
        write = positions >= (offset[:, None] + S - W)  # last occurrence per slot
        slots_eff = jnp.where(write, slots, W)  # dropped -> scratch row
        cache = KVCache(
            _scatter_rows_padded(cache.k, slots_eff, k_new),
            _scatter_rows_padded(cache.v, slots_eff, v_new),
        )
        return _out(p, o), cache
    else:
        # Contiguous write at [offset, offset+S).  Uniform offset uses a cheap
        # dynamic slice; ragged offsets fall back to a scatter.
        cache = KVCache(
            _write_rows(cache.k, offset, k_new), _write_rows(cache.v, offset, v_new)
        )
        idx = jnp.arange(L, dtype=jnp.int32)[None]
        kv_pos = jnp.where(idx < (offset[:, None] + S), idx, -1)  # [B, L]

    o = ops.flash_attention(
        q, cache.k, cache.v, q_pos=positions, kv_pos=kv_pos, causal=True,
        window=cfg.sliding_window,
    )
    return _out(p, o), cache


# --------------------------------------------------------------------------- #
# Packed ragged (suffix-)prefill: many requests, one kernel launch
# --------------------------------------------------------------------------- #
def prefill_packed(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [1, Sq, D] — new tokens of ALL segments, concatenated
    cache: KVCache,  # [1, Skv, KV, hd] packed buffer, reused prefixes preloaded
    *,
    q_pos: jax.Array,  # [1, Sq] segment-local positions of the new tokens
    q_seg: jax.Array,  # [1, Sq] segment id per query token (-1 = padding)
    q_rows: jax.Array,  # [1, Sq] packed-buffer row each new token's KV lands in
    kv_pos: jax.Array,  # [1, Skv] segment-local position per kv row (-1 invalid)
    kv_seg: jax.Array,  # [1, Skv] segment id per kv row
) -> Tuple[jax.Array, KVCache]:
    """Suffix-prefill of several requests in one attention call.

    ``cache`` is the *packed* KV buffer: each segment owns a contiguous row
    span holding [its reused context KV ++ its new KV], laid out by the
    caller (``kvcache.paged.PackLayout``).  New-token K/V are scattered to
    ``q_rows`` (padding tokens carry an out-of-range row and land on a
    dropped scratch row), then every query attends its own segment only
    (``q_seg == kv_seg``), causally at segment-local positions — numerically
    the same attention each request would run alone.
    """
    q, k_new, v_new = _qkv(p, cfg, x)
    if cfg.rope_theta is not None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k_new = apply_rope(k_new, q_pos, cfg.rope_theta)
    cache = KVCache(
        _scatter_rows_padded(cache.k, q_rows, k_new),
        _scatter_rows_padded(cache.v, q_rows, v_new),
    )
    o = ops.packed_attention(
        q, cache.k, cache.v, q_pos=q_pos, kv_pos=kv_pos, q_seg=q_seg,
        kv_seg=kv_seg, causal=True, window=cfg.sliding_window,
    )
    return _out(p, o), cache


# --------------------------------------------------------------------------- #
# Fused selective-recompute prefill (CacheBlend-style non-prefix reuse)
# --------------------------------------------------------------------------- #
def prefill_fused(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [1, Sq, D] — ONLY the tokens chosen for recompute
    cache: KVCache,  # [1, Skv, KV, hd] assembled buffer, reused spans preloaded
    *,
    q_pos: jax.Array,  # [1, Sq] absolute positions of the recompute tokens
    q_rows: jax.Array,  # [1, Sq] buffer row each token's fresh KV lands in
    kv_pos: jax.Array,  # [1, Skv] row positions (-1 = invalid/padding)
) -> Tuple[jax.Array, KVCache]:
    """Selective-recompute prefill of one request over an assembled buffer.

    ``cache`` holds the context KV in query order, with reused chunk spans
    preloaded from storage (``kvcache.fusion.build_fused_caches``) and zeros
    at the recompute rows.  The recompute tokens — a gappy subset of
    positions, not a suffix — get fresh K/V scattered into their rows
    (padding tokens carry an out-of-range row and land on a dropped scratch
    row), then attend causally over the FULL buffer at their absolute
    positions (``ops.fused_prefill``).  At r=1.0 every row is overwritten
    and this is exactly ``prefill`` of the whole sequence.
    """
    q, k_new, v_new = _qkv(p, cfg, x)
    if cfg.rope_theta is not None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k_new = apply_rope(k_new, q_pos, cfg.rope_theta)
    cache = KVCache(
        _scatter_rows_padded(cache.k, q_rows, k_new),
        _scatter_rows_padded(cache.v, q_rows, v_new),
    )
    o = ops.fused_prefill(
        q, cache.k, cache.v, q_pos=q_pos, kv_pos=kv_pos,
        window=cfg.sliding_window,
    )
    return _out(p, o), cache


# --------------------------------------------------------------------------- #
# Decode (one token)
# --------------------------------------------------------------------------- #
def decode(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, 1, D]
    cache: KVCache,
    pos: jax.Array,  # [B] int32 — position of this token (== cached length)
) -> Tuple[jax.Array, KVCache]:
    B = x.shape[0]
    L = cache.k.shape[1]
    q, k_new, v_new = _qkv(p, cfg, x)
    positions = pos[:, None]  # [B, 1]
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    if cfg.sliding_window and L == cfg.sliding_window:
        slots = positions % cfg.sliding_window
        cache = KVCache(
            _scatter_rows(cache.k, slots, k_new), _scatter_rows(cache.v, slots, v_new)
        )
        kv_pos = _ring_positions(pos + 1, L, B)
    else:
        cache = KVCache(
            _scatter_rows(cache.k, positions, k_new), _scatter_rows(cache.v, positions, v_new)
        )
        idx = jnp.arange(L, dtype=jnp.int32)[None]
        kv_pos = jnp.where(idx <= pos[:, None], idx, -1)

    o = ops.decode_attention(
        q, cache.k, cache.v, q_pos=positions, kv_pos=kv_pos, window=cfg.sliding_window
    )
    return _out(p, o), cache


# --------------------------------------------------------------------------- #
# Paged decode (one token per sequence against the shared block pool)
# --------------------------------------------------------------------------- #
def decode_paged(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, 1, D]
    pool: KVCache,  # k/v: [N_rows, KV, hd] — the SHARED block pool, flat rows
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    pos: jax.Array,  # [B] int32 — position of this token (== cached length)
    *,
    block: int,
) -> Tuple[jax.Array, KVCache]:
    """``decode`` over the paged layout: the new token's K/V rows scatter
    into the pool at ``table[pos // block] * block + pos % block`` and
    attention gathers each sequence's live blocks through its table
    (``ops.paged_decode``).  A slot whose table is zeroed (freed/inactive)
    writes onto the reserved dump block's rows — never into a block that may
    have been recycled to another sequence.  Numerics are bit-identical to
    ``decode`` against a slotted-dense cache (tests/test_paged_decode.py).
    """
    B = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x)
    positions = pos[:, None]  # [B, 1]
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    blk = jnp.take_along_axis(
        block_table.astype(jnp.int32), (pos // block)[:, None], axis=1
    )[:, 0]
    rows = blk * block + pos % block  # [B] — dump rows when blk == 0
    pool = KVCache(
        pool.k.at[rows].set(k_new[:, 0]), pool.v.at[rows].set(v_new[:, 0])
    )
    o = ops.paged_decode(
        q, pool.k, pool.v, block_table=block_table, q_pos=positions,
        block=block, window=cfg.sliding_window,
    )
    return _out(p, o), pool


# --------------------------------------------------------------------------- #
# Chunked prefill (mixed prefill-chunk + decode rows over the block pool)
# --------------------------------------------------------------------------- #
def prefill_chunked(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, C, D] — up to C new tokens per sequence
    pool: KVCache,  # k/v: [N_rows, KV, hd] — the SHARED block pool, flat rows
    block_table: jax.Array,  # [B, nb] int32 pool-block id per sequence block
    q_pos: jax.Array,  # [B, C] int32 token positions (-2^30 = padding)
    *,
    block: int,
) -> Tuple[jax.Array, KVCache]:
    """``decode_paged`` generalised to a chunk of up to ``C`` tokens per
    sequence — the unified continuous-batching step.  Each valid token's K/V
    rows scatter into the pool at ``table[pos // block] * block + pos %
    block``; padding tokens write onto the reserved dump block's row 0 (their
    rope positions are clamped to 0 first, so only garbage lands there and
    dump rows are never attended — positions exceed every valid query).  One
    launch mixes decode rows (1 valid token), prefill-chunk rows (many) and
    idle rows (none); numerics per row are bit-identical to dense suffix
    prefill / ``decode`` (tests/test_chunked_prefill.py).
    """
    B, C, _ = x.shape
    q, k_new, v_new = _qkv(p, cfg, x)
    valid = q_pos >= 0  # [B, C]
    positions = jnp.where(valid, q_pos, 0).astype(jnp.int32)
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    blk = jnp.take_along_axis(
        block_table.astype(jnp.int32), positions // block, axis=1
    )  # [B, C]
    rows = jnp.where(valid, blk * block + positions % block, 0)  # 0 = dump row
    KVh, hd = pool.k.shape[1], pool.k.shape[2]
    rows_flat = rows.reshape(B * C)
    pool = KVCache(
        pool.k.at[rows_flat].set(k_new.reshape(B * C, KVh, hd)),
        pool.v.at[rows_flat].set(v_new.reshape(B * C, KVh, hd)),
    )
    o = ops.chunked_prefill(
        q, pool.k, pool.v, block_table=block_table, q_pos=q_pos,
        block=block, window=cfg.sliding_window,
    )
    return _out(p, o), pool


# --------------------------------------------------------------------------- #
# Cross-attention (Whisper decoder): KV computed once from encoder output
# --------------------------------------------------------------------------- #
def init_cross_attention(key: jax.Array, cfg: ArchConfig) -> Params:
    return init_attention(key, cfg)


def cross_kv(p: Params, cfg: ArchConfig, enc_out: jax.Array) -> KVCache:
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dke->bske", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", enc_out, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return KVCache(k, v)


def cross_attend(p: Params, cfg: ArchConfig, x: jax.Array, ckv: KVCache) -> jax.Array:
    B, S, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    Skv = ckv.k.shape[1]
    q_pos = jnp.zeros((B, S), jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    o = ops.flash_attention(q, ckv.k, ckv.v, q_pos=q_pos, kv_pos=kv_pos, causal=False)
    return _out(p, o)


# --------------------------------------------------------------------------- #
# Cache write helpers
# --------------------------------------------------------------------------- #
def _write_rows(cache: jax.Array, offset: jax.Array, new: jax.Array) -> jax.Array:
    """Write ``new`` [B,S,...] into ``cache`` [B,L,...] at row ``offset[b]``."""
    B, S = new.shape[0], new.shape[1]

    def per_seq(c, o, n):
        return jax.lax.dynamic_update_slice(c, n, (o,) + (0,) * (c.ndim - 1))

    return jax.vmap(per_seq)(cache, offset.astype(jnp.int32), new)


def _scatter_rows(cache: jax.Array, slots: jax.Array, new: jax.Array) -> jax.Array:
    """Scatter ``new`` [B,S,...] rows into per-sequence slots [B,S]."""

    def per_seq(c, s, n):
        return c.at[s].set(n)

    return jax.vmap(per_seq)(cache, slots.astype(jnp.int32), new)


def _scatter_rows_padded(cache: jax.Array, slots: jax.Array, new: jax.Array) -> jax.Array:
    """Scatter with a scratch row at index L (rows sent there are dropped) —
    used to suppress duplicate ring-buffer writes without data-dependent
    shapes."""
    L = cache.shape[1]
    pad = jnp.zeros_like(cache[:, :1])
    padded = jnp.concatenate([cache, pad], axis=1)
    return _scatter_rows(padded, slots, new)[:, :L]


def _ring_positions(length: jax.Array, window: int, batch: int) -> jax.Array:
    """Absolute position held by each ring slot given ``length`` tokens seen.

    Slot j holds the largest position p < length with p % window == j
    (or -1 if no token ever landed there).
    """
    j = jnp.arange(window, dtype=jnp.int32)[None]  # [1, W]
    ln = length.astype(jnp.int32)[:, None]  # [B, 1]
    p = ln - 1 - ((ln - 1 - j) % window)
    return jnp.where((p >= 0) & (ln > 0), p, -1)
