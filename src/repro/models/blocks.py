"""Decoder blocks: (attention | mamba) mixer + optional (MLP | MoE) FFN.

A block is described by a static :class:`BlockKind`; parameters are nested
dicts so homogeneous stacks scan cleanly and hybrid periods unroll.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers, moe, ssm
from repro.models.common import KeyGen, Params


class BlockKind(NamedTuple):
    mixer: str  # "a" (attention) | "m" (mamba)
    ffn: str  # "mlp" | "moe" | "none"


def block_kinds(cfg: ArchConfig) -> Tuple[BlockKind, ...]:
    """Static per-layer block kinds for one scan period.

    * uniform families (dense/moe/ssm/vlm): period length 1;
    * hybrid (Jamba): the full ``hybrid_period`` with MoE on layers where
      ``idx % moe.every == moe.offset``.
    """
    if cfg.family == "hybrid":
        assert cfg.hybrid_period is not None and cfg.moe is not None
        kinds = []
        for i, mixer in enumerate(cfg.hybrid_period):
            is_moe = i % cfg.moe.every == cfg.moe.offset
            kinds.append(BlockKind(mixer, "moe" if is_moe else "mlp"))
        return tuple(kinds)
    if cfg.family == "ssm":
        return (BlockKind("m", "none" if cfg.d_ff == 0 else "mlp"),)
    if cfg.family == "moe":
        assert cfg.moe is not None and cfg.moe.every == 1, (
            "uniform scan requires MoE on every layer; use family='hybrid' otherwise"
        )
        return (BlockKind("a", "moe"),)
    return (BlockKind("a", "mlp"),)


def init_block(key: jax.Array, cfg: ArchConfig, kind: BlockKind) -> Params:
    kg = KeyGen(key)
    p: Params = {"norm1": layers.init_norm(cfg)}
    if kind.mixer == "a":
        p["attn"] = attention.init_attention(kg(), cfg)
    else:
        p["mamba"] = ssm.init_mamba(kg(), cfg)
    if kind.ffn != "none":
        p["norm2"] = layers.init_norm(cfg)
        p["ffn"] = (
            moe.init_moe(kg(), cfg) if kind.ffn == "moe" else layers.init_mlp(kg(), cfg)
        )
    return p


def _apply_ffn(p: Params, cfg: ArchConfig, kind: BlockKind, x: jax.Array):
    if kind.ffn == "none":
        return x, jnp.float32(0.0)
    h = layers.apply_norm(p["norm2"], cfg, x)
    if kind.ffn == "moe":
        out, aux = moe.apply_moe(p["ffn"], cfg, h)
        return x + out, aux
    return x + layers.apply_mlp(p["ffn"], cfg, h), jnp.float32(0.0)


# --------------------------------------------------------------------------- #
# Training forward (no cache)
# --------------------------------------------------------------------------- #
def forward(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    h = layers.apply_norm(p["norm1"], cfg, x)
    if kind.mixer == "a":
        x = x + attention.forward(p["attn"], cfg, h, positions=positions)
    else:
        out, _ = ssm.forward(p["mamba"], cfg, h)
        x = x + out
    return _apply_ffn(p, cfg, kind, x)


# --------------------------------------------------------------------------- #
# Prefill / decode with per-layer cache slices
# --------------------------------------------------------------------------- #
class BlockCache(NamedTuple):
    """Union cache for one layer; unused member is None (static per kind)."""

    attn: Optional[attention.KVCache]
    mamba: Optional[ssm.MambaState]


def init_block_cache(
    cfg: ArchConfig, kind: BlockKind, batch: int, max_len: int, dtype=None
) -> BlockCache:
    if kind.mixer == "a":
        return BlockCache(attention.init_kv_cache(cfg, batch, max_len, dtype), None)
    return BlockCache(None, ssm.init_mamba_state(cfg, batch, dtype))


def prefill(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,
    cache: BlockCache,
    offset: jax.Array,  # [B]
) -> Tuple[jax.Array, BlockCache, jax.Array]:
    h = layers.apply_norm(p["norm1"], cfg, x)
    if kind.mixer == "a":
        out, kv = attention.prefill(p["attn"], cfg, h, cache.attn, offset)
        cache = BlockCache(kv, None)
    else:
        out, st = ssm.forward(p["mamba"], cfg, h, state=cache.mamba)
        cache = BlockCache(None, st)
    x = x + out
    x, aux = _apply_ffn(p, cfg, kind, x)
    return x, cache, aux


def prefill_packed(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,  # [1, Sq, D]
    cache: BlockCache,  # packed attention KV buffer (mixer must be "a")
    *,
    q_pos: jax.Array,
    q_seg: jax.Array,
    q_rows: jax.Array,
    kv_pos: jax.Array,
    kv_seg: jax.Array,
) -> Tuple[jax.Array, BlockCache, jax.Array]:
    """Packed ragged prefill of one block — attention mixers only (SSM state
    mixes along the sequence, so SSM/hybrid archs cannot be packed)."""
    assert kind.mixer == "a", "packed prefill requires an attention mixer"
    h = layers.apply_norm(p["norm1"], cfg, x)
    out, kv = attention.prefill_packed(
        p["attn"], cfg, h, cache.attn,
        q_pos=q_pos, q_seg=q_seg, q_rows=q_rows, kv_pos=kv_pos, kv_seg=kv_seg,
    )
    x = x + out
    x, aux = _apply_ffn(p, cfg, kind, x)
    return x, BlockCache(kv, None), aux


def prefill_fused(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,  # [1, Sq, D] — recompute tokens only
    cache: BlockCache,  # assembled attention KV buffer (mixer must be "a")
    *,
    q_pos: jax.Array,
    q_rows: jax.Array,
    kv_pos: jax.Array,
) -> Tuple[jax.Array, BlockCache, jax.Array]:
    """Selective-recompute fused prefill of one block — attention mixers
    only (SSM state mixes along the sequence, so chunk-composite reuse
    cannot skip tokens there)."""
    assert kind.mixer == "a", "fused prefill requires an attention mixer"
    h = layers.apply_norm(p["norm1"], cfg, x)
    out, kv = attention.prefill_fused(
        p["attn"], cfg, h, cache.attn, q_pos=q_pos, q_rows=q_rows, kv_pos=kv_pos
    )
    x = x + out
    x, aux = _apply_ffn(p, cfg, kind, x)
    return x, BlockCache(kv, None), aux


def prefill_chunked(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,  # [B, C, D]
    cache: BlockCache,  # shared block-pool KV buffer (mixer must be "a")
    block_table: jax.Array,  # [B, nb]
    q_pos: jax.Array,  # [B, C]
    *,
    block: int,
) -> Tuple[jax.Array, BlockCache, jax.Array]:
    """Chunked prefill of one block over the pool — attention mixers only
    (SSM state mixes along the sequence, so chunk interleaving cannot skip
    ahead there; those archs keep the legacy admit-then-decode path)."""
    assert kind.mixer == "a", "chunked prefill requires an attention mixer"
    h = layers.apply_norm(p["norm1"], cfg, x)
    out, kv = attention.prefill_chunked(
        p["attn"], cfg, h, cache.attn, block_table, q_pos, block=block
    )
    x = x + out
    x, aux = _apply_ffn(p, cfg, kind, x)
    return x, BlockCache(kv, None), aux


def decode_paged(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,  # [B, 1, D]
    cache: BlockCache,  # shared block-pool KV buffer (mixer must be "a")
    block_table: jax.Array,  # [B, nb]
    pos: jax.Array,  # [B]
    *,
    block: int,
) -> Tuple[jax.Array, BlockCache]:
    """Paged decode of one block — attention mixers only (SSM state is O(1)
    per slot and gains nothing from paging; those archs keep dense decode)."""
    assert kind.mixer == "a", "paged decode requires an attention mixer"
    h = layers.apply_norm(p["norm1"], cfg, x)
    out, kv = attention.decode_paged(
        p["attn"], cfg, h, cache.attn, block_table, pos, block=block
    )
    x = x + out
    x, _ = _apply_ffn(p, cfg, kind, x)
    return x, BlockCache(kv, None)


def decode(
    p: Params,
    cfg: ArchConfig,
    kind: BlockKind,
    x: jax.Array,
    cache: BlockCache,
    pos: jax.Array,  # [B]
) -> Tuple[jax.Array, BlockCache]:
    h = layers.apply_norm(p["norm1"], cfg, x)
    if kind.mixer == "a":
        out, kv = attention.decode(p["attn"], cfg, h, cache.attn, pos)
        cache = BlockCache(kv, None)
    else:
        out, st = ssm.decode(p["mamba"], cfg, h, cache.mamba)
        cache = BlockCache(None, st)
    x = x + out
    x, _ = _apply_ffn(p, cfg, kind, x)
    return x, cache
