"""Shared model utilities: parameter init, dtype policy, sharding helpers.

The model zoo is pure-JAX and dependency-free: parameters are nested-dict
pytrees produced by explicit ``init`` functions; forward passes are pure
functions of ``(params, config, inputs)``.  Sharding is expressed as a
parallel pytree of :class:`jax.sharding.PartitionSpec` built by
``repro.distributed.sharding`` — keeping the lowering path transparent for
the roofline analysis.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int8": jnp.int8,
}


def resolve_dtype(name) -> jnp.dtype:
    if isinstance(name, str):
        return _DTYPES[name]
    return name


# --------------------------------------------------------------------------- #
# Parameter initialisation
# --------------------------------------------------------------------------- #
def dense_init(key: jax.Array, shape: Sequence[int], dtype, fan_in: Optional[int] = None):
    """Lecun-normal init (stddev = 1/sqrt(fan_in)); fan_in defaults to the
    first dimension (our dense weights are stored ``[in, out...]``)."""
    fan_in = int(fan_in if fan_in is not None else shape[0])
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape), jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Sequence[int], dtype):
    return (jax.random.normal(key, tuple(shape), jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(tuple(shape), dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(tuple(shape), dtype)


class KeyGen:
    """Splits a PRNG key on demand; keeps init code linear and readable."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# --------------------------------------------------------------------------- #
# Stacking (for scan-over-layers)
# --------------------------------------------------------------------------- #
def stack_layers(layer_params: Sequence[Params]) -> Params:
    """Stack a list of identical-structure param trees along a new axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def init_stacked(key: jax.Array, n: int, init_one) -> Params:
    """Initialise ``n`` layers worth of parameters, stacked on axis 0.

    Uses vmap over per-layer keys so init stays fast and the result is a
    single stacked pytree suitable for ``lax.scan``.
    """
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


# --------------------------------------------------------------------------- #
# Sharding helper
# --------------------------------------------------------------------------- #
def maybe_shard(x: jax.Array, spec) -> jax.Array:
    """``with_sharding_constraint`` that no-ops when no mesh is active (so the
    same model code runs in single-device tests and in the dry-run)."""
    if spec is None:
        return x
    try:
        env = jax.sharding.get_abstract_mesh()
        if env is None or env.empty:  # pragma: no cover - env dependent
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # pragma: no cover - older jax fallbacks
        return x


# --------------------------------------------------------------------------- #
# Numerics
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def count_tree_params(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


def tree_bytes(params: Params) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params)
    )


def cast_tree(params: Params, dtype) -> Params:
    dtype = resolve_dtype(dtype)
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params
    )
