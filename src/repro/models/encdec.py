"""Whisper-style encoder–decoder transformer.

Per the assignment the audio frontend (mel conv stem) is a STUB:
``input_specs()`` provides precomputed frame embeddings ``[B, S_enc, D]``.
LayerNorm + GELU MLP + sinusoidal (encoder) / trained (decoder) absolute
positions, per the Whisper architecture (arXiv:2212.04356).

Reusable context state for the paper's technique (DESIGN.md §6): the encoder
output and the decoder's *cross*-attention KV of the audio context; decoder
self-attention KV is per-request.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers
from repro.models.common import KeyGen, Params, init_stacked, resolve_dtype


class EncDecState(NamedTuple):
    pos: jax.Array  # [B] decoder positions filled
    self_kv: attention.KVCache  # stacked [n_dec, B, L, KV, hd]
    cross_kv: attention.KVCache  # stacked [n_dec, B, S_enc, KV, hd]


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _init_enc_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    kg = KeyGen(key)
    return {
        "norm1": layers.init_norm(cfg),
        "attn": attention.init_attention(kg(), cfg),
        "norm2": layers.init_norm(cfg),
        "mlp": layers.init_mlp(kg(), cfg),
    }


def _init_dec_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    kg = KeyGen(key)
    return {
        "norm1": layers.init_norm(cfg),
        "self_attn": attention.init_attention(kg(), cfg),
        "norm_x": layers.init_norm(cfg),
        "cross_attn": attention.init_cross_attention(kg(), cfg),
        "norm2": layers.init_norm(cfg),
        "mlp": layers.init_mlp(kg(), cfg),
    }


def init(key: jax.Array, cfg: ArchConfig) -> Params:
    kg = KeyGen(key)
    pdtype = resolve_dtype(cfg.param_dtype)
    return {
        "embed": layers.init_embedding(kg(), cfg),
        "dec_pos": (
            jax.random.normal(kg(), (cfg.decoder_seq_len, cfg.d_model), jnp.float32) * 0.02
        ).astype(pdtype),
        "encoder": init_stacked(
            kg(), cfg.n_encoder_layers, lambda k: _init_enc_layer(k, cfg)
        ),
        "enc_norm": layers.init_norm(cfg),
        "decoder": init_stacked(kg(), cfg.n_layers, lambda k: _init_dec_layer(k, cfg)),
        "dec_norm": layers.init_norm(cfg),
    }


# --------------------------------------------------------------------------- #
# Encoder
# --------------------------------------------------------------------------- #
def encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, S_enc, D] stub embeddings -> encoder output [B, S_enc, D]."""
    x = frames.astype(resolve_dtype(cfg.dtype))
    S = x.shape[1]
    x = x + layers.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

    def layer_fn(x, lp):
        h = layers.apply_norm(lp["norm1"], cfg, x)
        x = x + attention.forward(lp["attn"], cfg, h, causal=False)
        h = layers.apply_norm(lp["norm2"], cfg, x)
        return x + layers.apply_mlp(lp["mlp"], cfg, h), None

    x, _ = jax.lax.scan(layer_fn, x, params["encoder"], unroll=cfg.scan_unroll)
    return layers.apply_norm(params["enc_norm"], cfg, x)


def build_cross_kv(params: Params, cfg: ArchConfig, enc_out: jax.Array) -> attention.KVCache:
    """Precompute the decoder cross-attention KV — part of the reusable
    context state (stored once per audio context, reused across requests)."""

    def per_layer(lp):
        return attention.cross_kv(lp["cross_attn"], cfg, enc_out)

    return jax.vmap(per_layer, in_axes=(0,))(params["decoder"])


# --------------------------------------------------------------------------- #
# Decoder
# --------------------------------------------------------------------------- #
def _dec_embed(params: Params, cfg: ArchConfig, tokens: jax.Array, offset) -> jax.Array:
    x = layers.embed_tokens(params["embed"], cfg, tokens)
    S = tokens.shape[1]
    pos = offset[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    pos = jnp.minimum(pos, cfg.decoder_seq_len - 1)
    return x + jnp.take(params["dec_pos"], pos, axis=0).astype(x.dtype)


def forward(
    params: Params, cfg: ArchConfig, frames: jax.Array, dec_tokens: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Training forward: encode frames, causally decode tokens. Returns
    (logits [B, S_dec, V], aux=0)."""
    enc_out = encode(params, cfg, frames)
    B = dec_tokens.shape[0]
    x = _dec_embed(params, cfg, dec_tokens, jnp.zeros((B,), jnp.int32))

    def layer_fn(x, lp):
        h = layers.apply_norm(lp["norm1"], cfg, x)
        x = x + attention.forward(lp["self_attn"], cfg, h, causal=True)
        h = layers.apply_norm(lp["norm_x"], cfg, x)
        ckv = attention.cross_kv(lp["cross_attn"], cfg, enc_out)
        x = x + attention.cross_attend(lp["cross_attn"], cfg, h, ckv)
        h = layers.apply_norm(lp["norm2"], cfg, x)
        return x + layers.apply_mlp(lp["mlp"], cfg, h), None

    x, _ = jax.lax.scan(layer_fn, x, params["decoder"], unroll=cfg.scan_unroll)
    x = layers.apply_norm(params["dec_norm"], cfg, x)
    return layers.lm_logits(params["embed"], cfg, x), jnp.float32(0.0)


def init_state(
    cfg: ArchConfig, batch: int, max_len: int, enc_len: Optional[int] = None, dtype=None
) -> EncDecState:
    enc_len = enc_len or cfg.encoder_seq_len
    dtype = dtype or resolve_dtype(cfg.dtype)
    n = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def z(shape):
        return jnp.zeros(shape, dtype)

    return EncDecState(
        pos=jnp.zeros((batch,), jnp.int32),
        self_kv=attention.KVCache(
            z((n, batch, max_len, kv, hd)), z((n, batch, max_len, kv, hd))
        ),
        cross_kv=attention.KVCache(
            z((n, batch, enc_len, kv, hd)), z((n, batch, enc_len, kv, hd))
        ),
    )


def prefill(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,
    state: EncDecState,
    embeds: Optional[jax.Array] = None,  # audio frames (stub embeddings)
) -> Tuple[jax.Array, EncDecState]:
    """Decoder prefill.  If ``embeds`` is given the audio context is encoded
    and its cross-KV written into the state; otherwise the state's cross-KV is
    *reused* stored context state (the paper's technique)."""
    cross = state.cross_kv
    if embeds is not None:
        enc_out = encode(params, cfg, embeds)
        cross = build_cross_kv(params, cfg, enc_out)
    B, S = tokens.shape
    offset = state.pos
    x = _dec_embed(params, cfg, tokens, offset)

    def layer_fn(x, per):
        lp, kv, ckv = per
        h = layers.apply_norm(lp["norm1"], cfg, x)
        out, kv = attention.prefill(lp["self_attn"], cfg, h, kv, offset)
        x = x + out
        h = layers.apply_norm(lp["norm_x"], cfg, x)
        x = x + attention.cross_attend(lp["cross_attn"], cfg, h, ckv)
        h = layers.apply_norm(lp["norm2"], cfg, x)
        return x + layers.apply_mlp(lp["mlp"], cfg, h), kv

    x, self_kv = jax.lax.scan(
        layer_fn, x, (params["decoder"], state.self_kv, cross), unroll=cfg.scan_unroll
    )
    x = layers.apply_norm(params["dec_norm"], cfg, x[:, -1:])
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]
    return logits, EncDecState(pos=offset + S, self_kv=self_kv, cross_kv=cross)


def decode(
    params: Params, cfg: ArchConfig, tokens: jax.Array, state: EncDecState
) -> Tuple[jax.Array, EncDecState]:
    pos = state.pos
    x = _dec_embed(params, cfg, tokens, pos)

    def layer_fn(x, per):
        lp, kv, ckv = per
        h = layers.apply_norm(lp["norm1"], cfg, x)
        out, kv = attention.decode(lp["self_attn"], cfg, h, kv, pos)
        x = x + out
        h = layers.apply_norm(lp["norm_x"], cfg, x)
        x = x + attention.cross_attend(lp["cross_attn"], cfg, h, ckv)
        h = layers.apply_norm(lp["norm2"], cfg, x)
        return x + layers.apply_mlp(lp["mlp"], cfg, h), kv

    x, self_kv = jax.lax.scan(
        layer_fn, x, (params["decoder"], state.self_kv, state.cross_kv),
        unroll=cfg.scan_unroll,
    )
    x = layers.apply_norm(params["dec_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]
    return logits, EncDecState(pos=pos + 1, self_kv=self_kv, cross_kv=state.cross_kv)
