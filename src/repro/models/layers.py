"""Core neural-net layers: embeddings, positional encodings, norms, MLPs."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import KeyGen, Params


# --------------------------------------------------------------------------- #
# Rotary position embedding (Llama rotate-half convention)
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32) -> jax.Array:
    half = head_dim // 2
    return (theta ** (-np.arange(0, half) / half)).astype(dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (absolute token positions)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int, dtype=jnp.float32) -> jax.Array:
    """Standard transformer sinusoidal table (Whisper encoder)."""
    pos = np.arange(max_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d_model)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(table, dtype=dtype)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def init_norm(cfg: ArchConfig) -> Params:
    if cfg.norm_type == "layernorm":
        return {
            "scale": jnp.ones((cfg.d_model,), common.resolve_dtype(cfg.param_dtype)),
            "bias": jnp.zeros((cfg.d_model,), common.resolve_dtype(cfg.param_dtype)),
        }
    return {"scale": jnp.ones((cfg.d_model,), common.resolve_dtype(cfg.param_dtype))}


def apply_norm(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return common.layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return common.rms_norm(x, p["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Embedding / LM head
# --------------------------------------------------------------------------- #
def init_embedding(key: jax.Array, cfg: ArchConfig) -> Params:
    kg = KeyGen(key)
    pdtype = common.resolve_dtype(cfg.param_dtype)
    params: Params = {"table": common.embed_init(kg(), (cfg.padded_vocab, cfg.d_model), pdtype)}
    if not cfg.tie_embeddings:
        params["head"] = common.dense_init(
            kg(), (cfg.d_model, cfg.padded_vocab), pdtype, fan_in=cfg.d_model
        )
    return params


def embed_tokens(p: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    return x.astype(common.resolve_dtype(cfg.dtype))


def lm_logits(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Final-hidden -> vocab logits (f32 for a stable softmax/loss)."""
    if cfg.tie_embeddings:
        w = p["table"].astype(jnp.float32)
        return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), w)
    w = p["head"].astype(jnp.float32)
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32), w)


# --------------------------------------------------------------------------- #
# MLP (SwiGLU for llama-family; GELU for Whisper)
# --------------------------------------------------------------------------- #
def init_mlp(key: jax.Array, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    pdtype = common.resolve_dtype(cfg.param_dtype)
    kg = KeyGen(key)
    if cfg.mlp_type == "gelu":
        return {
            "w1": common.dense_init(kg(), (cfg.d_model, d_ff), pdtype),
            "b1": jnp.zeros((d_ff,), pdtype),
            "w2": common.dense_init(kg(), (d_ff, cfg.d_model), pdtype, fan_in=d_ff),
            "b2": jnp.zeros((cfg.d_model,), pdtype),
        }
    return {
        "w_gate": common.dense_init(kg(), (cfg.d_model, d_ff), pdtype),
        "w_up": common.dense_init(kg(), (cfg.d_model, d_ff), pdtype),
        "w_down": common.dense_init(kg(), (d_ff, cfg.d_model), pdtype, fan_in=d_ff),
    }


def apply_mlp(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    if cfg.mlp_type == "gelu":
        h = jnp.einsum("...d,df->...f", x, p["w1"].astype(dtype)) + p["b1"].astype(dtype)
        h = jax.nn.gelu(h)
        return jnp.einsum("...f,fd->...d", h, p["w2"].astype(dtype)) + p["b2"].astype(dtype)
    gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dtype))
    up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
    h = common.swiglu(gate, up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dtype))
