"""Decoder-only LM over block stacks (dense / MoE / SSM / hybrid / VLM).

Layer stacking: the model scans over *periods* (``blocks.block_kinds``); a
uniform arch has period length 1 (pure ``lax.scan`` over all layers — keeps
HLO size O(1) in depth so 512-device SPMD compiles stay tractable); Jamba
unrolls its 8-layer period inside a scan over 9 periods.

API (all pure):
  init(key, cfg) -> params
  forward(params, cfg, tokens, embeds=None) -> (logits [B,S,V], aux)
  init_state(cfg, batch, max_len) -> LMState
  prefill(params, cfg, tokens, state, embeds=None) -> (last_logits [B,V], LMState)
  decode(params, cfg, tokens [B,1], state) -> (logits [B,V], LMState)

``prefill`` is *suffix* prefill whenever ``state.pos > 0``: positions
``[0, state.pos)`` of the caches are treated as reused context state (the
paper's technique) and are not recomputed.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, layers
from repro.models.common import KeyGen, Params, init_stacked, resolve_dtype


class LMState(NamedTuple):
    """Decode/prefill context state ("ContextState" in DESIGN.md)."""

    pos: jax.Array  # [B] — tokens already in the caches
    caches: Tuple[blocks.BlockCache, ...]  # one per period position, stacked over periods


def _layout(cfg: ArchConfig):
    kinds = blocks.block_kinds(cfg)
    assert cfg.n_layers % len(kinds) == 0, (cfg.name, cfg.n_layers, len(kinds))
    return kinds, cfg.n_layers // len(kinds)


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def init(key: jax.Array, cfg: ArchConfig) -> Params:
    kinds, n_periods = _layout(cfg)
    kg = KeyGen(key)
    layer_stacks = [
        init_stacked(kg(), n_periods, lambda k, kind=kind: blocks.init_block(k, cfg, kind))
        for kind in kinds
    ]
    return {
        "embed": layers.init_embedding(kg(), cfg),
        "layers": layer_stacks,
        "final_norm": layers.init_norm(cfg),
    }


def init_state(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> LMState:
    kinds, n_periods = _layout(cfg)

    def stacked(kind):
        one = blocks.init_block_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros((n_periods,) + l.shape, l.dtype), one
        )

    return LMState(
        pos=jnp.zeros((batch,), jnp.int32), caches=tuple(stacked(k) for k in kinds)
    )


# --------------------------------------------------------------------------- #
# Embedding (VLM stub frontends prepend precomputed patch embeddings)
# --------------------------------------------------------------------------- #
def _embed_inputs(
    params: Params, cfg: ArchConfig, tokens: Optional[jax.Array], embeds: Optional[jax.Array]
) -> jax.Array:
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(resolve_dtype(cfg.dtype)))
    if tokens is not None:
        parts.append(layers.embed_tokens(params["embed"], cfg, tokens))
    assert parts, "need tokens and/or embeds"
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


# --------------------------------------------------------------------------- #
# Training forward (no cache)
# --------------------------------------------------------------------------- #
def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    kinds, _ = _layout(cfg)
    x = _embed_inputs(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def period_fn(x, layer_params):
        aux = jnp.float32(0.0)
        for i, kind in enumerate(kinds):
            x, a = blocks.forward(layer_params[i], cfg, kind, x, positions=positions)
            aux = aux + a
        return x, aux

    x, auxes = jax.lax.scan(
        _remat(cfg, period_fn), x, tuple(params["layers"]), unroll=cfg.scan_unroll
    )
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)
    return logits, jnp.sum(auxes)


# --------------------------------------------------------------------------- #
# Prefill (full when state.pos == 0; suffix when state.pos > 0)
# --------------------------------------------------------------------------- #
def prefill(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array],
    state: LMState,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, LMState]:
    kinds, _ = _layout(cfg)
    x = _embed_inputs(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    offset = state.pos

    def period_fn(x, per):
        layer_params, caches = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c, _ = blocks.prefill(layer_params[i], cfg, kind, x, caches[i], offset)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        _remat(cfg, period_fn), x, (tuple(params["layers"]), state.caches),
        unroll=cfg.scan_unroll,
    )
    x = layers.apply_norm(params["final_norm"], cfg, x[:, -1:])
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]
    return logits, LMState(pos=offset + S, caches=new_caches)


# --------------------------------------------------------------------------- #
# Packed ragged prefill (many requests, one launch) — attention archs only
# --------------------------------------------------------------------------- #
def prefill_packed(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,  # [1, Sq] new tokens of every segment, concatenated
    caches: Tuple[blocks.BlockCache, ...],  # packed buffers (paged.init_packed_caches)
    *,
    q_pos: jax.Array,  # [1, Sq]
    q_seg: jax.Array,  # [1, Sq]
    q_rows: jax.Array,  # [1, Sq]
    kv_pos: jax.Array,  # [1, Skv]
    kv_seg: jax.Array,  # [1, Skv]
    last_idx: jax.Array,  # [n] q index of each segment's last token
) -> Tuple[jax.Array, Tuple[blocks.BlockCache, ...]]:
    """Suffix-prefill of several requests as ONE packed sequence.

    Everything outside attention is positionwise, so packing is transparent
    to norms/MLP/MoE; attention isolates segments via ``q_seg``/``kv_seg``
    (see ``attention.prefill_packed``).  Returns per-segment last-token
    logits ``[n, V]`` (rows of ``last_idx``) and the updated packed caches,
    from which the caller scatters each segment back into its batch slot
    (``kvcache.paged.packed_to_artifact``).
    """
    kinds, _ = _layout(cfg)
    assert all(k.mixer == "a" for k in kinds), (
        "packed prefill requires attention-only stacks", cfg.name)
    x = _embed_inputs(params, cfg, tokens, None)

    def period_fn(x, per):
        layer_params, caches_ = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c, _ = blocks.prefill_packed(
                layer_params[i], cfg, kind, x, caches_[i],
                q_pos=q_pos, q_seg=q_seg, q_rows=q_rows,
                kv_pos=kv_pos, kv_seg=kv_seg,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        _remat(cfg, period_fn), x, (tuple(params["layers"]), caches),
        unroll=cfg.scan_unroll,
    )
    x = jnp.take_along_axis(x, last_idx.astype(jnp.int32)[None, :, None], axis=1)
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[0]  # [n, V]
    return logits, new_caches


# --------------------------------------------------------------------------- #
# Fused selective-recompute prefill (non-prefix chunk reuse) — attention only
# --------------------------------------------------------------------------- #
def prefill_fused(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,  # [1, Sq] the recompute tokens, in position order
    caches: Tuple[blocks.BlockCache, ...],  # assembled buffers (fusion.build_fused_caches)
    *,
    q_pos: jax.Array,  # [1, Sq] absolute positions (gappy; padding -2^30)
    q_rows: jax.Array,  # [1, Sq] buffer row per token (padding -> scratch)
    kv_pos: jax.Array,  # [1, Skv] row positions (-1 invalid)
    last_idx: jax.Array,  # [1] q index of the final (prompt) token
) -> Tuple[jax.Array, Tuple[blocks.BlockCache, ...]]:
    """Selective-recompute prefill over a chunk-composite KV assembly.

    The CacheBlend-style execute path: reused chunk spans sit preloaded in
    ``caches`` and only the selected r-fraction of tokens (plus every prompt
    token) flows through the layer stack, each attending the full assembled
    buffer at its absolute position.  Everything outside attention is
    positionwise, so the gappy token subset is transparent to norms/MLP/MoE;
    attention semantics live in ``attention.prefill_fused``.  Returns the
    last-token logits ``[1, V]`` and the updated buffers, from which the
    caller slices the full context+prompt state (rows ``[0, total)``) for
    slot installation or pool landing.  At ``recompute_frac=1.0`` the token
    set is the whole sequence and the result is bit-identical to ``prefill``
    (tests/test_fusion.py).
    """
    kinds, _ = _layout(cfg)
    assert all(k.mixer == "a" for k in kinds), (
        "fused prefill requires attention-only stacks", cfg.name)
    x = _embed_inputs(params, cfg, tokens, None)

    def period_fn(x, per):
        layer_params, caches_ = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c, _ = blocks.prefill_fused(
                layer_params[i], cfg, kind, x, caches_[i],
                q_pos=q_pos, q_rows=q_rows, kv_pos=kv_pos,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        _remat(cfg, period_fn), x, (tuple(params["layers"]), caches),
        unroll=cfg.scan_unroll,
    )
    x = jnp.take_along_axis(x, last_idx.astype(jnp.int32)[None, :, None], axis=1)
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[0]  # [1, V]
    return logits, new_caches


# --------------------------------------------------------------------------- #
# Decode (one token per sequence)
# --------------------------------------------------------------------------- #
def decode(
    params: Params, cfg: ArchConfig, tokens: jax.Array, state: LMState
) -> Tuple[jax.Array, LMState]:
    kinds, _ = _layout(cfg)
    x = _embed_inputs(params, cfg, tokens, None)
    pos = state.pos

    def period_fn(x, per):
        layer_params, caches = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c = blocks.decode(layer_params[i], cfg, kind, x, caches[i], pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        period_fn, x, (tuple(params["layers"]), state.caches), unroll=cfg.scan_unroll
    )
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]
    return logits, LMState(pos=pos + 1, caches=new_caches)


# --------------------------------------------------------------------------- #
# Paged decode (one token per sequence over the shared KV block pool)
# --------------------------------------------------------------------------- #
def decode_paged(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,  # [B, 1]
    caches: Tuple[blocks.BlockCache, ...],  # pool buffers (paged.init_pool_caches)
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block ids per sequence block
    pos: jax.Array,  # [B] int32 — cached length per slot (0-padded tables for
    # freed slots route their writes to the reserved dump block)
    block: int = 128,
) -> Tuple[jax.Array, Tuple[blocks.BlockCache, ...]]:
    """``decode`` against the shared block pool instead of per-slot dense
    caches: every layer's attention gathers exactly the live blocks each
    slot's table names (``attention.decode_paged``).  Positions/tables are
    host-managed by the caller (the serving engine), so only the pool
    buffers flow through: returns (logits [B, V], updated caches) —
    bit-identical logits to ``decode`` (tests/test_paged_decode.py)."""
    kinds, _ = _layout(cfg)
    assert all(k.mixer == "a" for k in kinds), (
        "paged decode requires attention-only stacks", cfg.name)
    x = _embed_inputs(params, cfg, tokens, None)

    def period_fn(x, per):
        layer_params, caches_ = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c = blocks.decode_paged(
                layer_params[i], cfg, kind, x, caches_[i], block_table, pos,
                block=block,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        period_fn, x, (tuple(params["layers"]), caches), unroll=cfg.scan_unroll
    )
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]
    return logits, new_caches


# --------------------------------------------------------------------------- #
# Chunked prefill (mixed prefill-chunk + decode rows over the block pool)
# --------------------------------------------------------------------------- #
def prefill_chunked(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,  # [B, C] — up to C new tokens per slot (0 on padding)
    caches: Tuple[blocks.BlockCache, ...],  # pool buffers (paged.init_pool_caches)
    *,
    block_table: jax.Array,  # [B, nb] int32 pool-block ids per sequence block
    q_pos: jax.Array,  # [B, C] int32 token positions (-2^30 = padding)
    last_idx: jax.Array,  # [B] chunk index of each row's last valid token
    block: int = 128,
) -> Tuple[jax.Array, Tuple[blocks.BlockCache, ...]]:
    """The unified continuous-batching step: ONE launch over the shared
    block pool whose rows mix prefill chunks (up to ``C`` new suffix tokens
    each), decode rows (1 token at the live length) and idle rows (all
    padding).  Every valid token's KV lands in the pool blocks its slot's
    table names (``attention.prefill_chunked``), then attends causally at
    its absolute position — per-row numerics are bit-identical to the
    legacy suffix-prefill / paged-decode launches.  Returns per-row logits
    ``[B, V]`` gathered at ``last_idx`` (meaningful only for rows whose
    chunk completes a prefill or carries a decode token) and the updated
    pool buffers.  Static shapes ([B, C] tokens, [B, nb] tables) make the
    launch compile once per (C, nb) bucket — zero steady-state recompiles.
    """
    kinds, _ = _layout(cfg)
    assert all(k.mixer == "a" for k in kinds), (
        "chunked prefill requires attention-only stacks", cfg.name)
    x = _embed_inputs(params, cfg, tokens, None)

    def period_fn(x, per):
        layer_params, caches_ = per
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c, _ = blocks.prefill_chunked(
                layer_params[i], cfg, kind, x, caches_[i], block_table, q_pos,
                block=block,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        _remat(cfg, period_fn), x, (tuple(params["layers"]), caches),
        unroll=cfg.scan_unroll,
    )
    x = jnp.take_along_axis(x, last_idx.astype(jnp.int32)[:, None, None], axis=1)
    x = layers.apply_norm(params["final_norm"], cfg, x)
    logits = layers.lm_logits(params["embed"], cfg, x)[:, 0]  # [B, V]
    return logits, new_caches


# --------------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------------- #
def cross_entropy(
    logits: jax.Array,  # [B, S, V] (activation dtype; upcast internally)
    labels: jax.Array,  # [B, S] int32
    mask: Optional[jax.Array] = None,  # [B, S] float/bool
) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)  # [B, S]
    label_logit = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    nll = lse - label_logit
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
