"""Mixture-of-experts FFN with sort-based capacity dispatch.

Why not GShard one-hot dispatch: its dispatch einsum costs O(T·E·C·D) FLOPs,
which inflates the compiled-HLO FLOP count quadratically in sequence length
and would poison the roofline analysis.  The sort-based formulation costs
O(T·k·D·F) in the expert matmuls — proportional to *active* parameters — plus
O(T·k·log) for the sort and O(T·k·D) for gather/scatter.

Expert-parallel sharding: the per-expert batched matmul ``ecd,edf->ecf``
shards E over the model axis when divisible (OLMoE: 64/16), otherwise the
expert FFN dim F is sharded (Mixtral 8e, Jamba 16e) — see
``distributed/sharding.py``.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import KeyGen, Params


def _ep_spec(cfg: ArchConfig):
    """Expert-parallel sharding constraint for the dispatched token block
    [E, C, D]: E over the model axis when divisible (OLMoE 64, Jamba 16),
    else the per-expert FFN dim is sharded and the block replicates.  Without
    this constraint XLA partial-sums the expert matmuls over the model axis
    (observed: 8 x 32 GB all-reduce per Jamba train step — EXPERIMENTS.md
    §Perf hillclimb C)."""
    try:
        import jax.sharding as jsh

        mesh = jsh.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return None
        m = mesh.shape["model"]
        if m > 1 and cfg.moe.n_experts % m == 0:
            return jsh.PartitionSpec("model", None, None)
    except Exception:  # pragma: no cover
        return None
    return None


def init_moe(key: jax.Array, cfg: ArchConfig) -> Params:
    assert cfg.moe is not None
    kg = KeyGen(key)
    pdtype = common.resolve_dtype(cfg.param_dtype)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts

    def expert_w(k, shape, fan_in):
        return common.dense_init(k, shape, pdtype, fan_in=fan_in)

    return {
        "router": common.dense_init(kg(), (D, E), jnp.float32, fan_in=D),
        "w_gate": expert_w(kg(), (E, D, F), D),
        "w_up": expert_w(kg(), (E, D, F), D),
        "w_down": expert_w(kg(), (E, F, D), F),
    }


def expert_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)  # align for TPU-friendly shapes


def apply_moe(p: Params, cfg: ArchConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    aux_loss is the standard switch-style load-balancing loss
    ``E * sum_e(frac_tokens_e * mean_prob_e)`` (== 1.0 at perfect balance).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    C = expert_capacity(T, cfg)

    xf = x.reshape(T, D)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- load-balancing aux loss ---------------------------------------- #
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=1), axis=0
    ) / k  # fraction of token-slots routed to each expert
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)

    # ---- sort token-expert pairs by expert ------------------------------- #
    e_flat = top_i.reshape(-1)  # [T*k]
    w_flat = top_p.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)
    e_s, w_s, t_s = e_flat[order], w_flat[order], t_flat[order]
    # position of each pair within its expert's group
    first = jnp.searchsorted(e_s, e_s, side="left")
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos_in_e < C
    slot = jnp.where(keep, e_s * C + pos_in_e, E * C)  # dropped -> overflow row

    # ---- dispatch -> per-expert batches (all-to-all under EP) ------------- #
    xs = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xf[t_s])
    xe = xs[: E * C].reshape(E, C, D)
    ep = _ep_spec(cfg)
    xe = common.maybe_shard(xe, ep)

    # ---- expert FFN (SwiGLU), batched over E ------------------------------ #
    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    h = common.swiglu(g, u)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    ye = common.maybe_shard(ye, ep)

    # ---- combine ----------------------------------------------------------- #
    ys = jnp.concatenate([ye.reshape(E * C, D), jnp.zeros((1, D), dt)], axis=0)
    contrib = ys[slot] * (w_s * keep).astype(dt)[:, None]
    out = jnp.zeros((T, D), dt).at[t_s].add(contrib)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
