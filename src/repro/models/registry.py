"""Uniform model API over all families + parameter counting via eval_shape."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


class ModelApi(NamedTuple):
    """Family-dispatched pure functions sharing one signature set.

    * init(key, cfg) -> params
    * forward(params, cfg, **batch) -> (logits, aux)        [training]
    * init_state(cfg, batch, max_len) -> state pytree
    * prefill(params, cfg, tokens, state, embeds=None) -> (last_logits, state)
    * decode(params, cfg, tokens, state) -> (logits, state)
    * prefill_packed(params, cfg, tokens, caches, **layout) -> (logits, caches)
      — packed ragged prefill across requests; None for families that cannot
      pack (enc-dec; SSM/hybrid stacks assert inside lm.prefill_packed).
    * decode_paged(params, cfg, tokens, caches, block_table=, pos=, block=)
      -> (logits, caches) — batched decode over the shared KV block pool
      (kvcache/paged.py); None for families that cannot page (enc-dec;
      SSM/hybrid stacks assert inside lm.decode_paged).
    * prefill_fused(params, cfg, tokens, caches, q_pos=, q_rows=, kv_pos=,
      last_idx=) -> (logits, caches) — selective-recompute prefill over a
      chunk-composite KV assembly (kvcache/fusion.py, CacheBlend-style
      non-prefix reuse); None for families that cannot fuse (enc-dec;
      SSM/hybrid stacks assert inside lm.prefill_fused).
    * prefill_chunked(params, cfg, tokens, caches, block_table=, q_pos=,
      last_idx=, block=) -> (logits, caches) — the unified
      continuous-batching step: ONE launch over the shared block pool whose
      rows mix prefill chunks, decode tokens and idle padding; None for
      families that cannot page (enc-dec; SSM/hybrid stacks assert inside
      lm.prefill_chunked).
    """

    init: Callable[..., Any]
    forward: Callable[..., Any]
    init_state: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    prefill_packed: Optional[Callable[..., Any]] = None
    decode_paged: Optional[Callable[..., Any]] = None
    prefill_fused: Optional[Callable[..., Any]] = None
    prefill_chunked: Optional[Callable[..., Any]] = None


def get_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family == "encdec":
        return ModelApi(
            init=encdec.init,
            forward=encdec.forward,
            init_state=encdec.init_state,
            prefill=encdec.prefill,
            decode=encdec.decode,
        )
    return ModelApi(
        init=lm.init,
        forward=lm.forward,
        init_state=lm.init_state,
        prefill=lm.prefill,
        decode=lm.decode,
        prefill_packed=lm.prefill_packed,
        decode_paged=lm.decode_paged,
        prefill_fused=lm.prefill_fused,
        prefill_chunked=lm.prefill_chunked,
    )


@functools.lru_cache(maxsize=None)
def count_params(cfg: ArchConfig) -> int:
    """Exact parameter count via shape-only tracing (no allocation)."""
    api = get_model(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    tree = jax.eval_shape(lambda k: api.init(k, cfg), key)
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


@functools.lru_cache(maxsize=None)
def count_active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE: only top_k of n_experts count).

    Used for MODEL_FLOPS = 6 * N_active * D in the roofline analysis.
    """
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    # Expert FFN weights: 3 * d_model * d_ff per expert on MoE layers.
    kinds = _moe_layer_count(cfg)
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = kinds * (cfg.moe.n_experts - cfg.moe.top_k) * per_expert
    return total - inactive


def _moe_layer_count(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        assert cfg.hybrid_period is not None
        per = sum(
            1 for i in range(len(cfg.hybrid_period)) if i % cfg.moe.every == cfg.moe.offset
        )
        return per * (cfg.n_layers // len(cfg.hybrid_period))
    return cfg.n_layers
