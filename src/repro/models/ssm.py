"""Mamba2 (SSD — state-space duality) mixer layer.

The sequence mixer for the ``mamba2-1.3b`` arch and the Mamba layers of the
``jamba`` hybrid (Jamba's Mamba-1 layers are implemented in the SSD
formulation — same O(1) recurrent-state semantics, TPU-friendlier chunked
matmul form; documented in DESIGN.md §3).

Stored context state (the paper's technique, extended to SSMs): a
:class:`MambaState` — (conv tail, SSD state) — is O(1) in context length,
making KV-reuse economics strictly more favorable (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import common
from repro.models.common import KeyGen, Params


class MambaState(NamedTuple):
    conv: jax.Array  # [B, d_conv-1, conv_dim]  — tail of pre-conv activations
    ssd: jax.Array  # [B, H, P, S]              — SSD recurrent state


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_ssm_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, H, conv_dim


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=None) -> MambaState:
    s, d_in, H, conv_dim = _dims(cfg)
    dtype = dtype or common.resolve_dtype(cfg.dtype)
    return MambaState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        ssd=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    )


def init_mamba(key: jax.Array, cfg: ArchConfig) -> Params:
    s, d_in, H, conv_dim = _dims(cfg)
    kg = KeyGen(key)
    pdtype = common.resolve_dtype(cfg.param_dtype)
    D = cfg.d_model
    return {
        # The input projection is stored as three tensors (z | xBC | dt)
        # rather than one fused [D, 2*d_in+2GS+H] matrix: fused-column splits
        # land mid-shard under tensor parallelism and cost a 392 GB/step
        # collective-permute on jamba train (EXPERIMENTS.md §Perf).  Split
        # weights shard each output dim cleanly (z and xBC boundaries are
        # head-aligned) at identical FLOPs.
        "in_proj_z": common.dense_init(kg(), (D, d_in), pdtype, fan_in=D),
        "in_proj_x": common.dense_init(kg(), (D, conv_dim), pdtype, fan_in=D),
        "in_proj_dt": common.dense_init(kg(), (D, H), pdtype, fan_in=D),
        "conv_w": common.dense_init(kg(), (s.d_conv, conv_dim), pdtype, fan_in=s.d_conv),
        "conv_b": jnp.zeros((conv_dim,), pdtype),
        # A = -exp(A_log); init A in [1, 16] as in the Mamba2 reference.
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),  # softplus^-1
        "norm_w": jnp.ones((d_in,), pdtype),
        "out_proj": common.dense_init(kg(), (d_in, D), pdtype, fan_in=d_in),
    }


def _in_proj(p: Params, x: jax.Array):
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"].astype(dt_))
    xBC = jnp.einsum("bsd,de->bse", x, p["in_proj_x"].astype(dt_))
    dt = jnp.einsum("bsd,de->bse", x, p["in_proj_dt"].astype(dt_))
    return z, xBC, dt


def _causal_conv(
    p: Params, cfg: ArchConfig, xBC: jax.Array, conv_init: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over the sequence axis with an optional carried
    tail (so suffix-prefill is exact across the reuse boundary).

    xBC: [B, S, conv_dim] -> (conv_out [B, S, conv_dim], new tail)."""
    s = cfg.ssm
    B, S, Cd = xBC.shape
    if conv_init is None:
        conv_init = jnp.zeros((B, s.d_conv - 1, Cd), xBC.dtype)
    padded = jnp.concatenate([conv_init.astype(xBC.dtype), xBC], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    out = jnp.zeros((B, S, Cd), jnp.float32)
    for i in range(s.d_conv):
        out = out + padded[:, i : i + S].astype(jnp.float32) * w[i]
    out = out + p["conv_b"].astype(jnp.float32)
    new_tail = padded[:, S:][:, -(s.d_conv - 1) :]
    return jax.nn.silu(out).astype(xBC.dtype), new_tail


def _ssd_inputs(cfg: ArchConfig, conv_out: jax.Array, dt_raw: jax.Array, p: Params):
    s, d_in, H, _ = _dims(cfg)
    B, S, _ = conv_out.shape
    x_in = conv_out[..., :d_in].reshape(B, S, H, s.head_dim)
    Bmat = conv_out[..., d_in : d_in + s.n_groups * s.d_state].reshape(
        B, S, s.n_groups, s.d_state
    )
    Cmat = conv_out[..., d_in + s.n_groups * s.d_state :].reshape(
        B, S, s.n_groups, s.d_state
    )
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return x_in, dt, A, Bmat, Cmat


def _gated_out(p: Params, cfg: ArchConfig, y: jax.Array, z: jax.Array) -> jax.Array:
    s, d_in, _, _ = _dims(cfg)
    B = y.shape[0]
    y = y.reshape(B, -1, d_in)
    y = common.rms_norm(y * jax.nn.silu(z.astype(y.dtype)), p["norm_w"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(y.dtype))


# --------------------------------------------------------------------------- #
# Full-sequence forward / (suffix-)prefill
# --------------------------------------------------------------------------- #
def forward(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, D]
    state: Optional[MambaState] = None,  # carried state (KV-reuse / prefill)
) -> Tuple[jax.Array, MambaState]:
    s, d_in, H, _ = _dims(cfg)
    z, xBC, dt_raw = _in_proj(p, x)
    conv_out, conv_tail = _causal_conv(p, cfg, xBC, state.conv if state else None)
    x_in, dt, A, Bmat, Cmat = _ssd_inputs(cfg, conv_out, dt_raw, p)
    y, ssd_state = ops.ssd_chunked(
        x_in, dt, A, Bmat, Cmat, chunk=s.chunk,
        initial_state=state.ssd if state else None,
    )
    y = y + p["D_skip"][None, None, :, None] * x_in.astype(jnp.float32)
    out = _gated_out(p, cfg, y.astype(x.dtype), z)
    return out, MambaState(conv=conv_tail, ssd=ssd_state)


# --------------------------------------------------------------------------- #
# O(1) decode step
# --------------------------------------------------------------------------- #
def decode(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, 1, D]
    state: MambaState,
) -> Tuple[jax.Array, MambaState]:
    s, d_in, H, Cd = _dims(cfg)
    B = x.shape[0]
    z, xBC, dt_raw = _in_proj(p, x)

    window = jnp.concatenate([state.conv.astype(xBC.dtype), xBC], axis=1)  # [B, d_conv, Cd]
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w) + p["conv_b"].astype(
        jnp.float32
    )
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(xBC.dtype)  # [B, 1, Cd]
    new_tail = window[:, 1:]

    x_in, dt, A, Bmat, Cmat = _ssd_inputs(cfg, conv_out, dt_raw, p)
    y_t, ssd_state = ops.ssd_decode(
        state.ssd, x_in[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0]
    )
    y_t = y_t.astype(jnp.float32) + p["D_skip"][None, :, None] * x_in[:, 0].astype(jnp.float32)
    out = _gated_out(p, cfg, y_t[:, None].astype(x.dtype), z)
    return out, MambaState(conv=new_tail, ssd=ssd_state)
