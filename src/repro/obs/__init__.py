"""Unified telemetry: spans, a labeled metrics registry, a cost ledger.

The paper's whole argument is an accounting identity — reuse wins only when
compute + storage + network dollars and delays are measured honestly — so
this package makes the serving stack's observability first-class instead of
scattered:

  * ``registry``  — ``MetricsRegistry``: labeled counters/gauges/histograms
    with Prometheus-style text exposition and a JSON snapshot.  Absorbs the
    engine/store/cluster counters (jit buckets, migration evals/skips,
    lookup walks, block-pool audit, packed/fused stats) into one view.
  * ``ledger``    — ``CostLedger``: every dollar of the cost model attributed
    to a request or an infrastructure activity (migration, rebalance,
    dedup'd write-back, gossip), with a conservation law against
    ``ServingSummary`` totals at 1e-9.
  * ``spans``     — per-request span trees (queue → plan → per-tier fetch →
    prefill → decode → write-back) derived purely from the typed event
    stream, with cluster parent info (routing/rebalance) and a Chrome
    trace-event export loadable in Perfetto.
  * ``telemetry`` — the ``Telemetry`` facade engines/clusters accept:
    subscribes to the event stream, feeds all three pillars, and stays
    entirely host-side (telemetry on is token-identical to telemetry off,
    with zero added jit traffic).

Telemetry is OFF by default everywhere; pass ``telemetry=Telemetry()`` to
``ServingEngine``/``ServingCluster`` to turn it on.
"""
from repro.obs.ledger import (
    CostLedger,
    LedgerEntry,
    check_conservation,
    ledger_from_simulation,
)
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import (
    Span,
    build_cluster_spans,
    build_spans,
    chrome_trace,
    write_chrome_trace,
)
from repro.obs.telemetry import Telemetry

__all__ = [
    "CostLedger",
    "LedgerEntry",
    "MetricsRegistry",
    "Span",
    "Telemetry",
    "build_cluster_spans",
    "build_spans",
    "check_conservation",
    "chrome_trace",
    "ledger_from_simulation",
    "write_chrome_trace",
]
