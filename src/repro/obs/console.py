"""Console dashboard: one readable text block over a Telemetry session.

``examples/serve_reuse.py --telemetry`` prints this after the run — the
headline cache-hit-rate gauge first (the production metric that matters),
then latency histograms, then the cost ledger's "where did the money go"
tables, then the conservation residuals against the run's summary.
"""
from __future__ import annotations

from typing import List, Optional

from repro.obs.telemetry import Telemetry


def _hist_line(tel: Telemetry, name: str, label: str, replica) -> Optional[str]:
    m = tel.registry.get(name)
    if m is None:
        return None
    s = m.hist(replica=replica)
    if s is None or s.n == 0:
        return None
    return (
        f"  {label:<12s} n={s.n:<5d} mean={s.total / s.n:8.4f}s "
        f"p50~{s.quantile(0.5):8.4f}s p90~{s.quantile(0.9):8.4f}s"
    )


def render(tel: Telemetry, summary=None, *, top_n: int = 5) -> str:
    lines: List[str] = ["== telemetry dashboard =="]
    hit = tel.registry.get("kv_cache_hit_rate")
    hit_v = hit.value() if hit is not None and hit.series else float("nan")
    tokens = tel.registry.get("tokens_emitted_total")
    n_tokens = sum(tokens.series.values()) if tokens else 0
    reqs = tel.registry.get("serving_requests_total")
    n_reqs = sum(reqs.series.values()) if reqs else 0
    lines.append(
        f"cache hit rate {hit_v:.3f} | {int(n_reqs)} requests | "
        f"{int(n_tokens)} tokens"
    )

    replicas = sorted(
        {rep for rep, _ in tel.events} | {0}
    )
    lines.append("latency:")
    for rep in replicas:
        rep_lines = [
            h for h in (
                _hist_line(tel, "queue_wait_seconds", "queue wait", rep),
                _hist_line(tel, "ttft_seconds", "TTFT", rep),
                _hist_line(tel, "tbt_seconds", "TBT", rep),
                _hist_line(tel, "e2e_seconds", "e2e", rep),
            ) if h is not None
        ]
        if rep_lines:
            lines.append(f" replica {rep}:")
            lines.extend(rep_lines)

    lines.append("cost ledger ($):")
    totals = tel.ledger.totals()
    lines.append(
        f"  compute {totals['compute']:.6f}  storage {totals['storage']:.6f}"
        f"  transfer {totals['transfer']:.6f}  total {tel.ledger.total():.6f}"
    )
    by_act = tel.ledger.by_activity()
    if by_act:
        lines.append("  by activity: " + "  ".join(
            f"{a}={d:.6f}" for a, d in sorted(by_act.items())
        ))
    by_tier = tel.ledger.by_tier()
    if by_tier:
        lines.append("  by tier:     " + "  ".join(
            f"{t}={d:.6f}" for t, d in sorted(by_tier.items())
        ))
    infra = tel.ledger.infrastructure_total()
    lines.append(f"  infrastructure (unattributed to requests): {infra:.6f}")
    top = sorted(
        tel.ledger.by_request().items(), key=lambda kv: -kv[1]
    )[:top_n]
    if top:
        lines.append("  top requests: " + "  ".join(
            f"#{rid}={d:.6f}" for rid, d in top
        ))

    if summary is not None:
        residuals = tel.check(summary)
        worst = max(residuals.values())
        lines.append(
            f"conservation vs summary: OK (max residual {worst:.2e} <= 1e-9)"
        )
    return "\n".join(lines)
