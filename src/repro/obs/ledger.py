"""Cost-attribution ledger: every dollar lands on a request or an activity.

The engine's bill has three categories (``ServingSummary``):

  * compute  — GPU-seconds, accrued per request (prefill share + decode
    share; ``serving/engine.py``);
  * storage  — GB-hour accrual per resident tier
    (``kvcache/hierarchy.TieredStore``);
  * transfer — per-GB fees on every charged byte movement
    (``kvcache/transfer.TransferModel``).

The ledger records the same dollars as typed ``LedgerEntry`` rows tagged
with WHO caused them: a request (``req_id``) or an infrastructure activity
(migration, rebalance, dedup'd write-back, gossip).  Attribution is exact
by construction — compute entries copy each finished record's accrued
cost, transfer entries are written by the ``TransferModel`` fee hook at
charge time (the engine brackets fetches/write-backs with an attribution
context), storage entries settle from the store's own per-tier GB-hour
meters — so the conservation law

    ledger.totals() == summary.{compute,storage,transfer}_cost  (atol 1e-9)

holds for any run, including cluster runs per replica.  ``check_conservation``
asserts it; ``benchmarks/check_snapshot.py`` gates CI on it.

Uncharged movements (migrations move bytes with ``charge=False``, gossip
is host-side, dedup'd write-backs skip the upload) still get zero-dollar
entries carrying their byte counts, so "where did the money go" and
"where did the bytes go" are both answerable without breaking conservation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

CATEGORIES = ("compute", "storage", "transfer")


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    category: str  # "compute" | "storage" | "transfer"
    # what caused the spend: "request" (compute), "fetch"/"write_back"
    # (request-attributed transfers), "fetch_retry" (re-issued attempts
    # under the retry policy — retry dollars separable by activity),
    # "fetch_failed" (zero-$ marker per failed attempt; its wasted dollars
    # were charged when the bytes moved, so conservation already holds),
    # "hold" (storage residency, per tier),
    # "migration" | "rebalance" | "gossip" | "write_back_dedup" (infra),
    # "other" (a charge outside any attribution context — still conserved)
    activity: str
    dollars: float
    replica: int = 0
    req_id: Optional[int] = None  # None = infrastructure
    tier: Optional[str] = None
    nbytes: float = 0.0
    kind: Optional[str] = None  # transfers: "load" | "store"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class CostLedger:
    """Append-mostly entry log + the aggregations consumers ask of it."""

    # subclasses may extend (e.g. the marketplace SettlementLedger adds
    # a "market" category for peer-to-peer purchase flows)
    CATEGORIES = CATEGORIES

    def __init__(self) -> None:
        self.entries: List[LedgerEntry] = []
        # storage "hold" entries are a settlement, not a log: recomputed
        # from the store's meters on demand, replaced per (replica, tier)
        self._holds: Dict[tuple, LedgerEntry] = {}

    # -- writes ---------------------------------------------------------- #
    def add(
        self,
        category: str,
        activity: str,
        dollars: float,
        *,
        replica: int = 0,
        req_id: Optional[int] = None,
        tier: Optional[str] = None,
        nbytes: float = 0.0,
        kind: Optional[str] = None,
    ) -> None:
        assert category in self.CATEGORIES, category
        self.entries.append(
            LedgerEntry(
                category=category, activity=activity, dollars=float(dollars),
                replica=replica, req_id=req_id, tier=tier,
                nbytes=float(nbytes), kind=kind,
            )
        )

    def record_transfer(
        self, tier: str, kind: str, nbytes: float, dollars: float, *,
        activity: str = "other", replica: int = 0,
        req_id: Optional[int] = None,
    ) -> None:
        """The ``TransferModel`` fee hook: one entry per charged movement,
        called at charge time with whatever attribution context the engine
        has bracketed the operation with."""
        self.add(
            "transfer", activity, dollars, replica=replica, req_id=req_id,
            tier=tier, nbytes=nbytes, kind=kind,
        )

    def settle_storage(
        self, costs_by_tier: Dict[str, float], *, replica: int = 0,
        bytes_by_tier: Optional[Dict[str, float]] = None,
    ) -> None:
        """Replace this replica's storage "hold" entries with the store's
        current per-tier accrued dollars.  Idempotent: call at every
        summary; the latest settlement wins."""
        for tier, dollars in costs_by_tier.items():
            nb = (bytes_by_tier or {}).get(tier, 0.0)
            self._holds[(replica, tier)] = LedgerEntry(
                category="storage", activity="hold", dollars=float(dollars),
                replica=replica, tier=tier, nbytes=float(nb),
            )

    # -- reads ----------------------------------------------------------- #
    def all_entries(self) -> List[LedgerEntry]:
        return self.entries + [self._holds[k] for k in sorted(self._holds)]

    def totals(self, *, replica: Optional[int] = None) -> Dict[str, float]:
        """category -> dollars (optionally one replica's share)."""
        out = {c: 0.0 for c in self.CATEGORIES}
        for e in self.all_entries():
            if replica is not None and e.replica != replica:
                continue
            out[e.category] += e.dollars
        return out

    def total(self) -> float:
        return sum(self.totals().values())

    def by_request(self, *, replica: Optional[int] = None) -> Dict[int, float]:
        """req_id -> attributed dollars (compute + its transfers)."""
        out: Dict[int, float] = {}
        for e in self.all_entries():
            if e.req_id is None:
                continue
            if replica is not None and e.replica != replica:
                continue
            out[e.req_id] = out.get(e.req_id, 0.0) + e.dollars
        return out

    def by_activity(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.all_entries():
            out[e.activity] = out.get(e.activity, 0.0) + e.dollars
        return out

    def by_tier(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.all_entries():
            if e.tier is not None:
                out[e.tier] = out.get(e.tier, 0.0) + e.dollars
        return out

    def infrastructure_total(self) -> float:
        """Dollars not attributable to any single request (holds included)."""
        return sum(e.dollars for e in self.all_entries() if e.req_id is None)

    def as_dict(self) -> dict:
        return {
            "totals": self.totals(),
            "by_activity": self.by_activity(),
            "by_tier": self.by_tier(),
            "infrastructure": self.infrastructure_total(),
            "n_entries": len(self.all_entries()),
        }


def check_conservation(
    ledger: CostLedger,
    summary,
    *,
    replica: Optional[int] = None,
    atol: float = 1e-9,
) -> Dict[str, float]:
    """Assert the conservation law against a ``ServingSummary`` (or any
    object with compute/storage/transfer_cost); returns the per-category
    absolute residuals on success."""
    t = ledger.totals(replica=replica)
    residuals = {
        "compute": abs(t["compute"] - summary.compute_cost),
        "storage": abs(t["storage"] - summary.storage_cost),
        "transfer": abs(t["transfer"] - summary.transfer_cost),
    }
    bad = {k: v for k, v in residuals.items() if not v <= atol}
    if bad:
        raise AssertionError(
            f"cost conservation violated (atol={atol}): residuals {bad}; "
            f"ledger={t}, summary=({summary.compute_cost}, "
            f"{summary.storage_cost}, {summary.transfer_cost})"
        )
    return residuals


def ledger_from_simulation(result, pricing, tier) -> CostLedger:
    """Exact ledger for an analytic ``core.simulator.SimResult``: one
    compute entry per request (prefill + decode seconds at the GPU rate),
    one storage hold, one transfer entry — the same three terms
    ``SimResult.cost`` sums, so conservation holds by construction (the
    property test checks the float identity actually survives
    re-association)."""
    from repro.core.pricing import GB

    ledger = CostLedger()
    c_gpu_s = pricing.compute.cost_per_hour / 3600.0
    for i, r in enumerate(result.results):
        ledger.add(
            "compute", "request", c_gpu_s * (r.prefill_s + r.decode_s),
            req_id=i,
        )
    ledger.settle_storage(
        {tier.name: tier.cost_per_gb_hour * result.storage_gb_hours}
    )
    ledger.add(
        "transfer", "other",
        tier.per_gb_transfer_fee * result.transferred_bytes / GB,
        tier=tier.name, nbytes=result.transferred_bytes,
    )
    return ledger
