"""Labeled metrics registry: counters, gauges, histograms, two expositions.

One ``MetricsRegistry`` per telemetry session.  Metrics are created (or
fetched — creation is idempotent) by name + label-name tuple; every
``(label values)`` combination is its own series, Prometheus-style::

    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Finished requests", ("action", "replica"))
    c.inc(action="load", replica=0)
    reg.histogram("ttft_seconds", "TTFT", ("replica",)).observe(0.12, replica=0)
    print(reg.to_prometheus())       # text exposition
    snap = reg.snapshot()            # JSON-ready nested dict

Everything is plain host-side Python — no jax, no numpy arrays retained —
so feeding the registry from a serving hot loop adds zero device traffic
and can never trigger a recompile.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# default histogram buckets: latency-flavored, seconds (upper bounds)
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0,
)

LabelValues = Tuple[Tuple[str, str], ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, object]) -> LabelValues:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {tuple(labelnames)}, got {tuple(labels)}"
        )
    return tuple((n, str(labels[n])) for n in labelnames)


def _fmt_labels(kv: LabelValues) -> str:
    if not kv:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in kv)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if v == math.inf:
        return "+Inf"
    return repr(v) if isinstance(v, float) else str(v)


@dataclasses.dataclass
class _HistSeries:
    buckets: Tuple[float, ...]
    counts: List[int]
    total: float = 0.0
    n: int = 0

    def observe(self, value: float) -> None:
        self.total += value
        self.n += 1
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.counts[i] += 1
        # +Inf bucket is implicit: == n

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile (NaN when empty) — good enough for
        the console dashboard; exact stats live in ServingSummary."""
        if self.n == 0:
            return float("nan")
        rank = q * self.n
        cum = 0
        lo = 0.0
        for ub, c_ in zip(self.buckets, self.counts):
            # counts are cumulative per bucket; convert to per-bin
            binc = c_ - cum
            if cum + binc >= rank and binc > 0:
                frac = (rank - cum) / binc
                return lo + frac * (ub - lo)
            cum += binc
            lo = ub
        return lo  # landed in +Inf bucket: report the last finite bound


class Metric:
    """One named metric family; per-label-value series live in ``series``."""

    def __init__(
        self,
        name: str,
        kind: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ):
        self.name = name
        self.kind = kind  # "counter" | "gauge" | "histogram"
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets or DEFAULT_BUCKETS)
        self.series: Dict[LabelValues, object] = {}

    # -- writes --------------------------------------------------------- #
    def inc(self, value: float = 1.0, **labels) -> None:
        assert self.kind == "counter", self.name
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({value})")
        k = _label_key(self.labelnames, labels)
        self.series[k] = self.series.get(k, 0.0) + value

    def set(self, value: float, **labels) -> None:
        assert self.kind == "gauge", self.name
        self.series[_label_key(self.labelnames, labels)] = value

    def observe(self, value: float, **labels) -> None:
        assert self.kind == "histogram", self.name
        k = _label_key(self.labelnames, labels)
        s = self.series.get(k)
        if s is None:
            s = self.series[k] = _HistSeries(
                self.buckets, [0] * len(self.buckets)
            )
        s.observe(value)

    # -- reads ---------------------------------------------------------- #
    def value(self, **labels) -> float:
        """Current value of one counter/gauge series (0.0 when never set)."""
        assert self.kind in ("counter", "gauge"), self.name
        return float(self.series.get(_label_key(self.labelnames, labels), 0.0))

    def hist(self, **labels) -> Optional[_HistSeries]:
        assert self.kind == "histogram", self.name
        return self.series.get(_label_key(self.labelnames, labels))


class MetricsRegistry:
    """Name -> Metric map with idempotent creation and two expositions."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _get(
        self, name: str, kind: str, help: str,
        labelnames: Sequence[str], buckets=None,
    ) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Metric(name, kind, help, labelnames, buckets)
        else:
            if m.kind != kind or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-registered as {kind}{tuple(labelnames)} "
                    f"(was {m.kind}{m.labelnames})"
                )
        return m

    def counter(self, name: str, help: str = "", labelnames: Sequence[str] = ()) -> Metric:
        return self._get(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames: Sequence[str] = ()) -> Metric:
        return self._get(name, "gauge", help, labelnames)

    def histogram(
        self, name: str, help: str = "", labelnames: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ) -> Metric:
        return self._get(name, "histogram", help, labelnames, buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Iterable[Metric]:
        return self._metrics.values()

    # -- expositions ----------------------------------------------------- #
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one family per # HELP/# TYPE
        block; histograms expand to _bucket/_sum/_count)."""
        lines: List[str] = []
        for m in self._metrics.values():
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for kv in sorted(m.series):
                if m.kind == "histogram":
                    s: _HistSeries = m.series[kv]
                    for ub, c in zip(s.buckets, s.counts):
                        bl = kv + (("le", _fmt_value(float(ub))),)
                        lines.append(
                            f"{m.name}_bucket{_fmt_labels(bl)} {c}"
                        )
                    bl = kv + (("le", "+Inf"),)
                    lines.append(f"{m.name}_bucket{_fmt_labels(bl)} {s.n}")
                    lines.append(
                        f"{m.name}_sum{_fmt_labels(kv)} {_fmt_value(s.total)}"
                    )
                    lines.append(f"{m.name}_count{_fmt_labels(kv)} {s.n}")
                else:
                    v = m.series[kv]
                    lines.append(
                        f"{m.name}{_fmt_labels(kv)} {_fmt_value(float(v))}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready nested dict: name -> {kind, help, series: [...]}.
        Histogram series carry buckets/counts/sum/count."""
        out: Dict[str, dict] = {}
        for m in self._metrics.values():
            series = []
            for kv in sorted(m.series):
                entry: Dict[str, object] = {"labels": dict(kv)}
                if m.kind == "histogram":
                    s: _HistSeries = m.series[kv]
                    entry.update(
                        buckets=list(s.buckets),
                        counts=list(s.counts),
                        sum=s.total,
                        count=s.n,
                    )
                else:
                    entry["value"] = float(m.series[kv])
                series.append(entry)
            out[m.name] = {"kind": m.kind, "help": m.help, "series": series}
        return out
