"""Per-request span trees derived from the typed event stream.

A span is a named wall-clock interval on a replica's SimClock.  The tree
for one request mirrors its lifecycle::

    request #7 (action=load, replica=0)
      ├─ queue        [arrival, start]
      ├─ plan         @start            (action, tier, estimates)
      ├─ fetch:s3     [start, +load_s]  (one per KVLoaded, per source tier)
      ├─ prefill      [start+load, +prefill_s]  (packed | fused | single)
      ├─ write_back   @t                (entry, tier, bytes)
      └─ decode       [ttft_end, finish]  (tokens, busy_s)

Spans are a PURE function of the event stream — no engine internals — so a
saved JSONL trace (``serving/trace.py``) reconstructs byte-identical trees:
``build_spans(read_events(path))`` equals the live-stream result exactly
(tests/test_obs.py pins this for engine and cluster runs).

Cluster streams are replica-tagged ``(replica, event)`` pairs
(``ServingCluster.events``): ``build_cluster_spans`` files each request
under its landing replica, prepends a ``route`` child carrying the router's
digest-predicted overlap and score, and returns cluster infrastructure
spans (rebalance copies, migrations, batch admissions) alongside.

``chrome_trace`` exports any span list as Chrome trace-event JSON —
``write_chrome_trace(path, spans)`` produces a file Perfetto
(https://ui.perfetto.dev) loads directly; see docs/OBSERVABILITY.md.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple, Union

from repro.serving import events as ev


@dataclasses.dataclass
class Span:
    """One named interval; ``children`` nest (zero-duration = instant)."""

    name: str
    start_s: float
    end_s: float
    req_id: int = -1  # -1 = infrastructure / engine-level
    replica: int = 0
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)
    children: List["Span"] = dataclasses.field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    def walk(self) -> Iterable["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


@dataclasses.dataclass
class _ReqEvents:
    admitted: Optional[ev.RequestAdmitted] = None
    plan: Optional[ev.PlanChosen] = None
    loads: List[ev.KVLoaded] = dataclasses.field(default_factory=list)
    fused: Optional[ev.FusedAdmitted] = None
    writeback: Optional[ev.StoreWriteBack] = None
    finished: Optional[ev.RequestFinished] = None
    routed: Optional[ev.RequestRouted] = None
    n_tokens: int = 0


def _collect(
    events: Iterable[ev.Event],
) -> Tuple[Dict[int, _ReqEvents], Dict[int, tuple], List[ev.Event]]:
    """Split a stream into per-request groups, the packed-batch membership
    map, and the engine-level infrastructure events."""
    reqs: Dict[int, _ReqEvents] = {}
    batches: Dict[int, tuple] = {}  # req_id -> its BatchAdmitted's req_ids
    infra: List[ev.Event] = []
    for e in events:
        if isinstance(e, ev.BatchAdmitted):
            for rid in e.req_ids:
                batches[rid] = e.req_ids
            infra.append(e)
            continue
        if isinstance(e, (ev.TierMigrated, ev.ReplicaRebalanced)):
            infra.append(e)
            continue
        if isinstance(e, ev.ClockAdvanced):
            continue
        r = reqs.setdefault(e.req_id, _ReqEvents())
        if isinstance(e, ev.RequestAdmitted):
            r.admitted = e
        elif isinstance(e, ev.PlanChosen):
            r.plan = e
        elif isinstance(e, ev.KVLoaded):
            r.loads.append(e)
        elif isinstance(e, ev.FusedAdmitted):
            r.fused = e
        elif isinstance(e, ev.StoreWriteBack):
            r.writeback = e
        elif isinstance(e, ev.RequestFinished):
            r.finished = e
        elif isinstance(e, ev.RequestRouted):
            r.routed = e
        elif isinstance(e, ev.TokenEmitted):
            r.n_tokens += 1
    return reqs, batches, infra


def _request_tree(
    rid: int, r: _ReqEvents, in_batch: bool, replica: int
) -> Optional[Span]:
    if r.finished is None:
        return None  # request still in flight: no complete tree to build
    rec = r.finished.record
    arrival = rec.arrival_s
    start = rec.start_s
    load_end = start + rec.load_s
    ttft_end = load_end + rec.prefill_s
    root = Span(
        name=f"request #{rid}",
        start_s=arrival, end_s=rec.finish_s, req_id=rid, replica=replica,
        attrs={
            "action": rec.action,
            "matched_tokens": rec.matched_tokens,
            "tokens": len(rec.tokens),
            "compute_cost": rec.compute_cost,
        },
    )
    if r.routed is not None:
        root.children.append(
            Span(
                name="route", start_s=r.routed.t_s, end_s=r.routed.t_s,
                req_id=rid, replica=replica,
                attrs={
                    "replica": r.routed.replica,
                    "predicted_matched_tokens": r.routed.matched_tokens,
                    "score": r.routed.score,
                    "ring_owner": r.routed.ring_owner,
                },
            )
        )
    root.children.append(
        Span("queue", arrival, start, req_id=rid, replica=replica)
    )
    if r.plan is not None:
        p = r.plan.plan
        root.children.append(
            Span(
                "plan", start, start, req_id=rid, replica=replica,
                attrs={
                    "action": p.action,
                    "tier": p.tier,
                    "est_ttft_s": p.est_ttft_s,
                    "est_cost": p.est_cost,
                    "store_after": p.store_after,
                },
            )
        )
    for kv in r.loads:
        root.children.append(
            Span(
                f"fetch:{kv.tier}", kv.t_s, kv.t_s + kv.load_s,
                req_id=rid, replica=replica,
                attrs={
                    "tier": kv.tier,
                    "nbytes": kv.nbytes,
                    "matched_tokens": kv.matched_tokens,
                },
            )
        )
    mode = "fused" if r.fused is not None else ("packed" if in_batch else "single")
    prefill_attrs: Dict[str, object] = {"mode": mode}
    if r.fused is not None:
        prefill_attrs.update(
            reused_tokens=r.fused.reused_tokens,
            recompute_tokens=r.fused.recompute_tokens,
            n_sources=r.fused.n_sources,
            jit_hit=r.fused.jit_hit,
        )
    root.children.append(
        Span(
            "prefill", load_end, ttft_end, req_id=rid, replica=replica,
            attrs=prefill_attrs,
        )
    )
    if r.writeback is not None:
        wb = r.writeback
        root.children.append(
            Span(
                "write_back", wb.t_s, wb.t_s, req_id=rid, replica=replica,
                attrs={
                    "entry_id": wb.entry_id,
                    "tier": wb.tier,
                    "nbytes": wb.nbytes,
                },
            )
        )
    root.children.append(
        Span(
            "decode", ttft_end, rec.finish_s, req_id=rid, replica=replica,
            attrs={"tokens": len(rec.tokens), "busy_s": rec.decode_s},
        )
    )
    return root


def _infra_span(e: ev.Event, replica: int) -> Span:
    if isinstance(e, ev.TierMigrated):
        return Span(
            f"migration:{e.reason}", e.t_s, e.t_s, replica=replica,
            attrs={
                "entry_id": e.entry_id, "from_tier": e.from_tier,
                "to_tier": e.to_tier, "nbytes": e.nbytes,
            },
        )
    if isinstance(e, ev.ReplicaRebalanced):
        return Span(
            "rebalance", e.t_s, e.t_s, replica=replica,
            attrs={
                "content_key": e.content_key,
                "from_replica": e.from_replica,
                "to_replica": e.to_replica,
                "nbytes": e.nbytes,
                "hits": e.hits,
            },
        )
    assert isinstance(e, ev.BatchAdmitted), e
    return Span(
        "batch", e.t_s, e.t_s, replica=replica,
        attrs={
            "n_requests": len(e.req_ids),
            "q_tokens": e.q_tokens,
            "q_len": e.q_len,
            "kv_len": e.kv_len,
            "jit_hit": e.jit_hit,
        },
    )


def build_spans(
    events: Iterable[ev.Event], *, replica: int = 0
) -> List[Span]:
    """Span trees for one engine's event stream: one root per FINISHED
    request (req_id order), then the engine's infrastructure spans in
    stream order."""
    reqs, batches, infra = _collect(events)
    out: List[Span] = []
    for rid in sorted(reqs):
        tree = _request_tree(rid, reqs[rid], rid in batches, replica)
        if tree is not None:
            out.append(tree)
    out.extend(_infra_span(e, replica) for e in infra)
    return out


def build_cluster_spans(
    tagged_events: Iterable[Tuple[int, ev.Event]],
) -> List[Span]:
    """Span trees for a replica-tagged cluster stream
    (``ServingCluster.events``): per-replica request trees — each with its
    ``route`` child carrying the router's prediction — then every replica's
    infrastructure spans.  Replica order, then req_id order, so live and
    trace-replayed streams produce identical lists."""
    by_replica: Dict[int, List[ev.Event]] = {}
    for rep, e in tagged_events:
        by_replica.setdefault(rep, []).append(e)
    out: List[Span] = []
    infra_all: List[Span] = []
    for rep in sorted(by_replica):
        reqs, batches, infra = _collect(by_replica[rep])
        for rid in sorted(reqs):
            tree = _request_tree(rid, reqs[rid], rid in batches, rep)
            if tree is not None:
                out.append(tree)
        infra_all.extend(_infra_span(e, rep) for e in infra)
    return out + infra_all


# --------------------------------------------------------------------------- #
# Chrome trace-event export (Perfetto)
# --------------------------------------------------------------------------- #
def _span_events(s: Span) -> List[dict]:
    tid = s.req_id + 1 if s.req_id >= 0 else 0  # tid 0 = infrastructure lane
    base = {
        "name": s.name,
        "pid": s.replica,
        "tid": tid,
        "cat": "serving",
        "args": dict(s.attrs),
    }
    ts = s.start_s * 1e6  # trace-event timestamps are microseconds
    if s.duration_s > 0:
        out = [{**base, "ph": "X", "ts": ts, "dur": s.duration_s * 1e6}]
    else:
        out = [{**base, "ph": "i", "ts": ts, "s": "t"}]
    for c in s.children:
        out.extend(_span_events(c))
    return out


def chrome_trace(spans: List[Span]) -> dict:
    """Chrome trace-event JSON (the object form Perfetto/chrome://tracing
    load): one complete ("X") event per timed span, instants ("i") for the
    zero-duration ones, pid = replica, tid = request."""
    events: List[dict] = []
    pids = sorted({s.replica for sp in spans for s in sp.walk()})
    for pid in pids:
        events.append(
            {
                "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                "args": {"name": f"replica {pid}"},
            }
        )
    for sp in spans:
        events.extend(_span_events(sp))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, spans: List[Span]) -> pathlib.Path:
    p = pathlib.Path(path)
    p.write_text(json.dumps(chrome_trace(spans)))
    return p
