"""The telemetry facade engines and clusters accept (off by default).

One ``Telemetry`` object bundles the three pillars:

  * ``registry`` — event-driven metrics (requests, TTFT/TBT/queue-wait
    histograms, loaded/written bytes per tier, the headline cache-hit-rate
    gauge) plus, after ``collect_engine``/``collect_cluster``, the absorbed
    engine/store/cluster counters (jit buckets, migration evals/skips,
    lookup walks, block-pool audit, packed/fused stats).
  * ``ledger`` — exact cost attribution: compute entries copy each finished
    record's accrued dollars, transfer entries arrive through the
    ``TransferModel`` fee hook (the engine brackets fetches/write-backs
    with an attribution context), storage settles from the store's per-tier
    meters at summary time.
  * ``events`` — the replica-tagged event buffer span trees build from.

Everything here is host-side Python on the engine's already-materialized
event objects: enabling telemetry launches no jax computation, so a
telemetry-on run is token-identical to a telemetry-off run and compiles
nothing extra (asserted in tests/test_obs.py and the serve_bench gate).

Usage::

    tel = Telemetry()
    eng = ServingEngine(cfg, params, ..., telemetry=tel)
    eng.run()
    tel.check(eng.summary())              # conservation at 1e-9
    print(tel.registry.to_prometheus())
    spans = tel.spans()
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.ledger import CostLedger, check_conservation
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import Span, build_cluster_spans, build_spans
from repro.serving import events as ev

# decode-step gaps sit well under the latency buckets' floor
TBT_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    0.01, 0.025, 0.05, 0.1, 0.5,
)


class Telemetry:
    def __init__(self) -> None:
        self.registry = MetricsRegistry()
        self.ledger = CostLedger()
        self.events: List[Tuple[int, ev.Event]] = []
        self._last_token_t: Dict[Tuple[int, int], float] = {}
        self._hits = 0
        self._finished = 0

        r = self.registry
        self._m_requests = r.counter(
            "serving_requests_total", "Finished requests", ("replica", "action")
        )
        self._m_hit_rate = r.gauge(
            "kv_cache_hit_rate",
            "Headline gauge: fraction of finished requests served from "
            "stored KV (load/partial/fused)",
        )
        self._m_ttft = r.histogram(
            "ttft_seconds", "Time to first token", ("replica",)
        )
        self._m_tbt = r.histogram(
            "tbt_seconds", "Time between tokens (per-request decode gaps)",
            ("replica",), buckets=TBT_BUCKETS,
        )
        self._m_queue = r.histogram(
            "queue_wait_seconds", "Admission queue wait", ("replica",)
        )
        self._m_e2e = r.histogram(
            "e2e_seconds", "Request end-to-end latency", ("replica",)
        )
        self._m_tokens = r.counter(
            "tokens_emitted_total", "Generated tokens", ("replica",)
        )
        self._m_loaded = r.counter(
            "kv_loaded_bytes_total", "Billed KV fetch bytes",
            ("replica", "tier"),
        )
        self._m_writeback = r.counter(
            "kv_writeback_bytes_total", "KV write-back bytes",
            ("replica", "tier"),
        )
        self._m_migrations = r.counter(
            "tier_migrations_total", "Entries moved between tiers",
            ("replica", "reason"),
        )
        self._m_batches = r.counter(
            "packed_batches_total", "Packed admission batches",
            ("replica", "jit"),
        )
        self._m_fused = r.counter(
            "fused_admissions_total", "Fused (CacheBlend-style) admissions",
            ("replica", "jit"),
        )
        self._m_routed = r.counter(
            "requests_routed_total", "Router placements", ("replica",)
        )
        self._m_rebalanced = r.counter(
            "rebalances_total", "Copy-then-keep rebalance copies",
            ("replica",),
        )
        self._m_gossip = r.counter(
            "gossip_ticks_total", "Digest gossip rounds", ()
        )
        self._m_fetch_failed = r.counter(
            "kv_fetch_failures_total", "Failed KV fetch attempts",
            ("replica", "tier", "reason"),
        )
        self._m_fetch_retried = r.counter(
            "kv_fetch_retries_total",
            "Fetch attempts re-issued by the cost-aware retry policy",
            ("replica", "tier"),
        )
        self._m_degraded = r.counter(
            "requests_degraded_total",
            "Requests that fell back to exact recompute after fetch failure",
            ("replica",),
        )
        self._m_fetch_wasted = r.counter(
            "kv_fetch_wasted_bytes_total",
            "Bytes moved by fetch attempts that then failed",
            ("replica", "tier"),
        )
        self._m_crashes = r.counter(
            "replica_crashes_total", "Replicas lost mid-run", ("replica",)
        )
        self._m_purchases = r.counter(
            "kv_purchases_total", "Marketplace KV purchases settled",
            ("replica", "seller"),
        )
        self._m_purchased_bytes = r.counter(
            "kv_purchased_bytes_total", "Bytes bought from marketplace peers",
            ("replica", "seller"),
        )
        self._m_verifications = r.counter(
            "seller_verifications_total",
            "Purchased-payload verifications (checksum and/or spot check)",
            ("replica", "ok"),
        )
        self._m_blacklists = r.counter(
            "sellers_blacklisted_total",
            "Sellers ejected for corrupt deliveries", ("seller",),
        )

    # ------------------------------------------------------------------ #
    # Event-driven feed (engines call this from step())
    # ------------------------------------------------------------------ #
    def on_events(self, events: Iterable[ev.Event], *, replica: int = 0) -> None:
        for e in events:
            self.events.append((replica, e))
            self._observe(e, replica)

    def _observe(self, e: ev.Event, replica: int) -> None:
        if isinstance(e, ev.TokenEmitted):
            self._m_tokens.inc(replica=replica)
            key = (replica, e.req_id)
            last = self._last_token_t.get(key)
            if last is not None:
                self._m_tbt.observe(e.t_s - last, replica=replica)
            self._last_token_t[key] = e.t_s
        elif isinstance(e, ev.RequestAdmitted):
            self._m_queue.observe(e.queue_s, replica=replica)
        elif isinstance(e, ev.KVLoaded):
            self._m_loaded.inc(e.nbytes, replica=replica, tier=e.tier)
        elif isinstance(e, ev.StoreWriteBack):
            self._m_writeback.inc(e.nbytes, replica=replica, tier=e.tier)
        elif isinstance(e, ev.BatchAdmitted):
            self._m_batches.inc(
                replica=replica, jit="hit" if e.jit_hit else "miss"
            )
        elif isinstance(e, ev.FusedAdmitted):
            self._m_fused.inc(
                replica=replica, jit="hit" if e.jit_hit else "miss"
            )
        elif isinstance(e, ev.TierMigrated):
            self._m_migrations.inc(replica=replica, reason=e.reason)
            # uncharged byte movement: a zero-dollar entry keeps the "where
            # did the bytes go" view complete without breaking conservation
            self.ledger.add(
                "transfer", "migration", 0.0, replica=replica,
                tier=e.to_tier, nbytes=e.nbytes, kind="store",
            )
        elif isinstance(e, ev.FetchFailed):
            self._m_fetch_failed.inc(
                replica=replica, tier=e.tier, reason=e.reason
            )
            self._m_fetch_wasted.inc(
                e.wasted_bytes, replica=replica, tier=e.tier
            )
        elif isinstance(e, ev.FetchRetried):
            self._m_fetch_retried.inc(replica=replica, tier=e.tier)
        elif isinstance(e, ev.DegradedToRecompute):
            self._m_degraded.inc(replica=replica)
        elif isinstance(e, ev.ReplicaCrashed):
            self._m_crashes.inc(replica=e.replica)
        elif isinstance(e, ev.KVPurchased):
            self._m_purchases.inc(replica=replica, seller=e.seller)
            self._m_purchased_bytes.inc(
                e.nbytes, replica=replica, seller=e.seller
            )
            # purchase dollars settle in the marketplace's own
            # SettlementLedger (buyer debit == seller credit + fee at 1e-9);
            # a zero-dollar marker here keeps the bytes queryable per
            # request without double-billing the engine's conservation law
            self.ledger.add(
                "transfer", "kv_purchase", 0.0, replica=replica,
                req_id=e.req_id, tier=e.tier, nbytes=e.nbytes, kind="load",
            )
        elif isinstance(e, ev.SellerVerified):
            self._m_verifications.inc(
                replica=replica, ok="ok" if e.ok else "corrupt"
            )
        elif isinstance(e, ev.SellerBlacklisted):
            self._m_blacklists.inc(seller=e.seller)
        elif isinstance(e, ev.RequestRouted):
            self._m_routed.inc(replica=replica)
        elif isinstance(e, ev.ReplicaRebalanced):
            self._m_rebalanced.inc(replica=e.to_replica)
        elif isinstance(e, ev.RequestFinished):
            rec = e.record
            self._m_requests.inc(replica=replica, action=rec.action)
            self._m_ttft.observe(rec.ttft_s, replica=replica)
            self._m_e2e.observe(rec.e2e_s, replica=replica)
            self._finished += 1
            if rec.action in ("load", "partial", "fused"):
                self._hits += 1
            self._m_hit_rate.set(self._hits / max(self._finished, 1))
            self._last_token_t.pop((replica, e.req_id), None)
            # compute attribution: the record's accrued dollars are exactly
            # the engine's per-request prefill share + decode shares
            self.ledger.add(
                "compute", "request", rec.compute_cost,
                replica=replica, req_id=rec.req_id,
            )

    def note_gossip(self, nbytes: float = 0.0) -> None:
        """One gossip round (cluster digest rebuild): host-side, unbilled —
        a zero-dollar ledger entry records the digest bytes moved."""
        self._m_gossip.inc()
        self.ledger.add("transfer", "gossip", 0.0, nbytes=nbytes)

    # ------------------------------------------------------------------ #
    # Settlement + counter absorption
    # ------------------------------------------------------------------ #
    def settle_engine(self, engine, *, replica: int = 0) -> None:
        """Replace this replica's storage hold entries with the store's
        current per-tier accrual (called by ``ServingEngine.summary``)."""
        store = engine.store
        self.ledger.settle_storage(
            store.storage_cost_by_tier(engine.pricing),
            replica=replica,
            bytes_by_tier={
                n: t.used_bytes for n, t in store.tiers.items()
            },
        )

    def collect_engine(self, engine, *, replica: int = 0) -> None:
        """Absorb the engine's scattered counters into the registry (gauges
        set from the source of truth — idempotent, latest wins)."""
        r = self.registry
        rep = str(replica)
        info = r.gauge(
            "engine_info", "Engine identity", ("replica", "arch", "cost_arch")
        )
        info.set(
            1, replica=rep, arch=engine.cfg.name,
            cost_arch=engine.cost_cfg.name,
        )

        ps = engine.packed_stats()
        g = r.gauge("packed_occupancy", "Useful/padded packed tokens", ("replica",))
        g.set(ps["occupancy"], replica=rep)
        g = r.gauge("lookup_walks", "Real trie walks at admission", ("replica",))
        g.set(ps["lookup_walks"], replica=rep)
        g = r.gauge(
            "lookup_reuses", "Admissions served from the prefetch walk",
            ("replica",),
        )
        g.set(ps["lookup_reuses"], replica=rep)
        g = r.gauge("admission_busy_seconds", "Modeled load+prefill time", ("replica",))
        g.set(ps["admission_busy_s"], replica=rep)

        ds = engine.decode_stats()
        g = r.gauge("decode_busy_seconds", "Modeled decode time", ("replica",))
        g.set(ds["decode_busy_s"], replica=rep)
        g = r.gauge("decode_tokens", "Tokens emitted by decode steps", ("replica",))
        g.set(ds["decode_tokens"], replica=rep)
        if ds.get("paged"):
            for k in ("pool_blocks", "pool_blocks_used", "pool_blocks_peak",
                      "shared_block_hits"):
                g = r.gauge(k, "Shared KV block pool audit", ("replica",))
                g.set(ds[k], replica=rep)

        for path, jit in (
            ("packed", engine.jit_stats), ("fused", engine.fused_jit),
        ):
            g = r.gauge(
                "jit_cache_hits", "Jit bucket cache hits", ("replica", "path")
            )
            g.set(jit.hits, replica=rep, path=path)
            g = r.gauge(
                "jit_cache_misses", "Jit bucket compiles", ("replica", "path")
            )
            g.set(jit.misses, replica=rep, path=path)
            g = r.gauge(
                "jit_calls_since_miss",
                "Consecutive jit-cache hits since the last compile "
                "(zero-steady-state-recompile surface)",
                ("replica", "path"),
            )
            g.set(jit.calls_since_miss, replica=rep, path=path)
            bg = r.gauge(
                "jit_bucket_calls", "Calls per (q_len, kv_len) jit bucket",
                ("replica", "path", "bucket"),
            )
            for bucket, n in jit.labeled_calls().items():
                bg.set(n, replica=rep, path=path, bucket=bucket)

        fs = engine.fused_stats()
        g = r.gauge("fused_reused_tokens", "Context tokens served from chunk KV", ("replica",))
        g.set(fs["reused_tokens"], replica=rep)
        g = r.gauge("fused_recompute_tokens", "Context tokens recomputed in fused launches", ("replica",))
        g.set(fs["recompute_tokens"], replica=rep)

        fls = engine.fault_stats()
        for k in ("fetch_failures", "fetch_retries", "degraded_requests",
                  "fetch_wasted_s", "fetch_wasted_bytes"):
            g = r.gauge(f"fault_{k}", "Failure-handling audit", ("replica",))
            g.set(fls[k], replica=rep)

        ss = engine.store.stats()
        for k in ("entries", "evictions", "rejected_puts", "migration_evals",
                  "migration_skips", "migration_queue", "content_chunks",
                  "failed_puts", "discards"):
            g = r.gauge(f"store_{k}", "Tiered store audit", ("replica",))
            g.set(ss[k], replica=rep)
        tg = r.gauge("tier_used_gb", "Resident GB per tier", ("replica", "tier"))
        hg = r.gauge("tier_gb_hours", "Accrued GB-hours per tier", ("replica", "tier"))
        for name, t in ss["tiers"].items():
            tg.set(t["used_gb"], replica=rep, tier=name)
            hg.set(t["gb_hours"], replica=rep, tier=name)

        self.settle_engine(engine, replica=replica)

    def collect_cluster(self, cluster) -> None:
        for i, eng in enumerate(cluster.replicas):
            self.collect_engine(eng, replica=i)
        r = self.registry
        g = r.gauge("cluster_gossip_ticks", "Digest gossip rounds run")
        g.set(cluster.gossip_ticks)
        g = r.gauge("cluster_rebalances", "Copy-then-keep rebalance copies")
        g.set(cluster.rebalances)
        rs = getattr(cluster.router, "stats", None)
        if callable(rs):
            for k, v in rs().items():
                g = r.gauge(f"router_{k}", "Router decision audit")
                g.set(v)
        if cluster.core is not None:
            cs = cluster.core.stats()
            g = r.gauge(
                "shared_tier_dedup_hits",
                "Write-backs deduped by the shared content-addressed core",
            )
            g.set(cs["dedup_hits"])
            g = r.gauge("shared_tier_contents", "Distinct shared payloads")
            g.set(cs["n_contents"])

    # ------------------------------------------------------------------ #
    # Views
    # ------------------------------------------------------------------ #
    def spans(self) -> List[Span]:
        """Span trees over everything observed so far (cluster-aware: the
        buffer is replica-tagged)."""
        return build_cluster_spans(self.events)

    def engine_spans(self, *, replica: int = 0) -> List[Span]:
        return build_spans(
            [e for rep, e in self.events if rep == replica], replica=replica
        )

    def check(self, summary, *, replica: Optional[int] = None,
              atol: float = 1e-9) -> Dict[str, float]:
        """Conservation law against a ServingSummary (see ledger module)."""
        return check_conservation(
            self.ledger, summary, replica=replica, atol=atol
        )

    def check_cluster(self, summary, *, atol: float = 1e-9) -> Dict[int, Dict[str, float]]:
        """Conservation per replica against a ``ClusterSummary`` (each
        replica's ledger slice vs its own ServingSummary)."""
        return {
            i: self.check(s, replica=i, atol=atol)
            for i, s in enumerate(summary.replicas)
        }

    def snapshot(self) -> dict:
        """JSON-ready dump: metrics + ledger aggregations."""
        return {
            "metrics": self.registry.snapshot(),
            "ledger": self.ledger.as_dict(),
        }
