"""Step-driven serving engine with stored-KV-cache reuse (plan/execute API)."""
from repro.serving import audit  # noqa: F401
from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.planner import (  # noqa: F401
    AlwaysReusePlanner,
    BlendPlanner,
    CostAwarePlanner,
    ReusePlan,
    ReusePlanner,
    StoreLookup,
)
from repro.serving.request import Request  # noqa: F401
