"""Step-driven serving engine with stored-KV-cache reuse (plan/execute API)."""
from repro.serving import audit  # noqa: F401
from repro.serving.cluster import ClusterConfig, ServingCluster  # noqa: F401
from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.planner import (  # noqa: F401
    AlwaysReusePlanner,
    BlendPlanner,
    CostAwarePlanner,
    ReusePlan,
    ReusePlanner,
    StoreLookup,
)
from repro.serving.request import Request  # noqa: F401
from repro.serving.trace import (  # noqa: F401
    TraceWriter,
    read_events,
    read_tagged_events,
    read_trace,
)
from repro.serving.router import (  # noqa: F401
    AffinityRouter,
    BloomDigest,
    ConsistentHashRing,
    ReplicaView,
    RoundRobinRouter,
    RouteDecision,
)
