"""Continuous-batching serving engine with stored-KV-cache reuse."""
from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.request import Request  # noqa: F401
