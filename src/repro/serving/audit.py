"""Per-request SLO audit over the engine's typed event stream.

Folds the events of a serving run into one row per request — where its TTFT
went (queue / load / prefill), which storage tier served it, and whether it
met its TTFT SLO — without touching engine internals.  Any consumer that
kept the event stream (a live trace, a replayed log) can produce the same
table; ``examples/serve_reuse.py`` prints it after each run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from repro.serving import events as ev
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class AuditRow:
    req_id: int
    action: str  # recompute | load | partial
    tier: Optional[str]  # storage tier served from (None = recompute)
    queue_s: float
    load_s: float
    prefill_s: float
    ttft_s: float
    e2e_s: float
    slo_ttft_s: Optional[float]
    # the planned fetch failed and this request fell back to exact recompute
    # (tokens unaffected; load_s carries the burned fetch time)
    degraded: bool = False

    @property
    def slo_met(self) -> Optional[bool]:
        """True/False against the TTFT SLO; None when the request has none."""
        if self.slo_ttft_s is None:
            return None
        return self.ttft_s <= self.slo_ttft_s


def audit(
    events: Iterable[ev.Event],
    requests: Optional[Iterable[Request]] = None,
) -> List[AuditRow]:
    """One row per finished request, in req_id order.  ``requests`` (when
    given) supplies the TTFT SLOs; the event stream alone carries the rest."""
    slo: Dict[int, Optional[float]] = {}
    for r in requests or ():
        slo[r.req_id] = r.slo_ttft_s
    tier: Dict[int, str] = {}
    rows: List[AuditRow] = []
    for e in events:
        if isinstance(e, ev.KVLoaded):
            tier[e.req_id] = e.tier
        elif isinstance(e, ev.RequestFinished):
            rec = e.record
            rows.append(
                AuditRow(
                    req_id=rec.req_id,
                    action=rec.action,
                    tier=tier.get(rec.req_id),
                    queue_s=rec.queue_s,
                    load_s=rec.load_s,
                    prefill_s=rec.prefill_s,
                    ttft_s=rec.ttft_s,
                    e2e_s=rec.e2e_s,
                    slo_ttft_s=slo.get(rec.req_id),
                    degraded=getattr(rec, "degraded", False),
                )
            )
    return sorted(rows, key=lambda r: r.req_id)


def audit_from_trace(path, requests: Optional[Iterable[Request]] = None) -> List[AuditRow]:
    """The same audit rows from a SAVED trace file: replay parity means a
    trace on disk answers the same SLO questions as the live stream."""
    from repro.serving.trace import read_events

    return audit(read_events(path), requests)


def cluster_audit_from_trace(
    path, requests: Optional[Iterable[Request]] = None,
) -> Dict[int, List[AuditRow]]:
    """Per-replica audit rows from a saved replica-tagged cluster trace."""
    from repro.serving.trace import read_tagged_events

    tagged = read_tagged_events(path)
    n = max((rep for rep, _ in tagged), default=-1) + 1
    streams: List[List[ev.Event]] = [[] for _ in range(n)]
    for rep, e in tagged:
        streams[rep].append(e)
    return cluster_audit(streams, requests)


def slo_summary(rows: List[AuditRow]) -> Dict[str, int]:
    met = sum(1 for r in rows if r.slo_met is True)
    violated = sum(1 for r in rows if r.slo_met is False)
    return {
        "requests": len(rows),
        "slo_met": met,
        "slo_violated": violated,
        "no_slo": len(rows) - met - violated,
        "degraded": sum(1 for r in rows if r.degraded),
    }


def cluster_audit(
    events_by_replica: List[List[ev.Event]],
    requests: Optional[Iterable[Request]] = None,
) -> Dict[int, List[AuditRow]]:
    """Per-replica audit over a cluster's replica-tagged event streams
    (``ServingCluster.events_by_replica``).  The SLO source is shared: a
    request's SLO is known at submit time, not per replica."""
    reqs = list(requests or ())
    return {
        i: audit(evs, reqs) for i, evs in enumerate(events_by_replica)
    }


def format_cluster_table(rows_by_replica: Dict[int, List[AuditRow]]) -> str:
    """Per-replica audit tables plus one aggregate SLO line — the cluster
    version of ``format_table`` (``examples/serve_reuse.py --replicas N``)."""
    sections: List[str] = []
    all_rows: List[AuditRow] = []
    for i in sorted(rows_by_replica):
        rows = rows_by_replica[i]
        if not rows:
            continue
        s = slo_summary(rows)
        sections.append(
            f"-- replica {i}: {s['requests']} requests, "
            f"{s['slo_met']} SLO ok, {s['slo_violated']} missed --"
        )
        sections.append(format_table(rows))
        all_rows.extend(rows)
    agg = slo_summary(all_rows)
    sections.append(
        f"== cluster: {agg['requests']} requests, {agg['slo_met']} SLO ok, "
        f"{agg['slo_violated']} missed, {agg['no_slo']} no-SLO =="
    )
    return "\n".join(sections)


def format_table(rows: List[AuditRow]) -> str:
    """Fixed-width text table of the audit (the example's printout)."""
    header = (
        f"{'req':>4s} {'action':<10s} {'tier':<11s} {'queue s':>8s} "
        f"{'load s':>8s} {'prefill s':>9s} {'TTFT s':>8s} {'SLO s':>7s} "
        f"{'SLO':>4s} {'deg':>4s}"
    )
    lines = [header]
    for r in rows:
        slo = f"{r.slo_ttft_s:7.2f}" if r.slo_ttft_s is not None else f"{'-':>7s}"
        verdict = {True: "ok", False: "MISS", None: "-"}[r.slo_met]
        lines.append(
            f"{r.req_id:>4d} {r.action:<10s} {(r.tier or '-'):<11s} "
            f"{r.queue_s:8.3f} {r.load_s:8.3f} {r.prefill_s:9.3f} "
            f"{r.ttft_s:8.3f} {slo} {verdict:>4s} "
            f"{'DEG' if r.degraded else '-':>4s}"
        )
    return "\n".join(lines)
