"""Cluster serving: N engine replicas, private hot tiers, one shared cold.

The paper prices KV reuse for a single engine; a fleet changes two terms:

  * Reuse frequency is PER REPLICA.  A cache-oblivious router that scatters
    a context's requests over N replicas divides its frequency by N — enough
    to push stored KV below break-even.  The ``AffinityRouter`` keeps a
    context's traffic on the replica that holds (or will hold) its KV.
  * Cold storage need not be replicated.  All replicas mount one
    content-addressed ``SharedBackendCore`` as their last tier: identical
    write-backs dedup to a single payload, and refcounted ownership means
    one replica's eviction (or crash) can never orphan an entry another
    replica still serves from.

Topology (``ClusterConfig.n_replicas`` = N, ``shared_tier`` = "s3"):

    requests ──> router ──> engine r0: host_dram -> local_nvme ─┐
                       ──> engine r1: host_dram -> local_nvme ─┼──> shared s3
                       ──> engine rN: host_dram -> local_nvme ─┘    (one core)

Every replica runs on a PRIVATE SimClock + TransferModel: its queueing,
link fees, and storage accrual are its own bill.  The cluster advances the
simulation by always stepping the busy replica whose local clock is
furthest behind, so cross-replica state (gossip digests, routing views,
rebalancing) is only ever read at the cluster frontier
``min(busy clocks)`` — never from a replica's future.

Routing happens at ARRIVAL time, against the latest gossiped
``BloomDigest`` of each replica's stored hashes (staleness-tolerant: a
stale or false-positive digest bit mis-prices a route; the landing replica
recomputes on the miss and tokens are unaffected).  Rebalancing is
copy-then-keep: when a context's routed traffic concentrates on a replica
that does not hold its KV, the donor's bytes are copied over the shared
tier into the target's hot tier while the donor keeps serving — replicated
residency, no window where the entry is unreachable from either replica.

A 1-replica cluster with the affinity router is bit- and bill-identical to
a bare ``ServingEngine`` (tests/test_cluster.py replays the golden seed
trace through it)."""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.perf_model import PerfModel, tpu_v5e
from repro.core.pricing import Pricing, tpu_v5e_pod
from repro.kvcache import compression
from repro.kvcache.hierarchy import (
    _BACKEND_KINDS,
    _default_kind,
    ConcurrencyLimitedBackend,
    SharedBackendCore,
    SharedTierBackend,
    StoredEntry,
    TierSpec,
)
from repro.kvcache.transfer import SimClock, TransferModel
from repro.serving import events as ev
from repro.serving import metrics as metrics_mod
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request
from repro.serving.router import AffinityRouter, BloomDigest, ReplicaView


@dataclasses.dataclass
class ClusterConfig:
    n_replicas: int = 2
    # Tier name (from the engine's tier specs) backed by ONE shared
    # SharedBackendCore across all replicas.  A name absent from the specs
    # (e.g. the default EngineConfig's host_dram/io2 hierarchy) silently
    # disables sharing — which is what keeps a 1-replica cluster on the
    # seed configuration bit-identical to a bare engine.
    shared_tier: Optional[str] = "s3"
    # Digest gossip cadence in cluster time; <=0 disables gossip (the
    # affinity router then routes on the consistent-hash ring alone).
    gossip_interval_s: float = 1.0
    digest_bits: int = 1 << 14
    digest_hashes: int = 4
    # Copy-then-keep rebalancing cadence; <=0 disables.  A context is copied
    # toward a replica once that replica has absorbed ``rebalance_min_hits``
    # routed requests for it without holding its KV.
    rebalance_interval_s: float = 0.0
    rebalance_min_hits: int = 3
    # Router view: expected per-request service time used to estimate the
    # queue wait of a replica with no free capacity.
    est_service_s: float = 0.05
    # Tenant tags, one per replica (marketplace runs: each replica serves a
    # tenant, and its shared-tier namespace carries the tenant's name so
    # dedup'd bytes stay attributable).  None = anonymous "r{i}" namespaces.
    tenants: Optional[List[str]] = None


class ServingCluster:
    """N ``ServingEngine`` replicas behind one router over a shared cold tier.

    Same surface shape as the engine: ``submit`` requests, ``step``/``run``
    the simulation, read ``events`` / ``records`` / ``summary()``.  Events
    come back replica-tagged: ``events`` is the merged cluster stream of
    ``(replica, event)`` pairs in emission order, ``events_by_replica[i]``
    each replica's own stream (cluster-level routing/rebalance events are
    filed under the replica they concern)."""

    def __init__(
        self,
        cfg,
        params,
        *,
        cluster_cfg: Optional[ClusterConfig] = None,
        engine_cfg: Optional[EngineConfig] = None,
        router=None,
        planner_factory=None,
        pricing: Optional[Pricing] = None,
        perf: Optional[PerfModel] = None,
        trace=None,
        on_token=None,
        telemetry=None,
        market=None,
    ):
        self.cc = cluster_cfg or ClusterConfig()
        self.ec = engine_cfg or EngineConfig()
        self.trace = trace
        # Marketplace (repro.market.Marketplace): each replica joins as its
        # tenant; a MarketPlanner built by planner_factory gets its session
        # bound here.  None = no market (the default cluster, unchanged).
        self.market = market
        # obs.Telemetry: replica engines feed their own events from step();
        # the cluster feeds ONLY its cluster-level events (routing/rebalance)
        # plus gossip ticks, so nothing is double-counted
        self.telemetry = telemetry
        n = self.cc.n_replicas
        assert n >= 1, n

        if self.ec.tier_specs is not None:
            specs = list(self.ec.tier_specs)
        else:
            specs = [
                TierSpec(nm, gb) for nm, gb in self.ec.tier_capacities_gb.items()
            ]
        shared = self.cc.shared_tier
        self.core: Optional[SharedBackendCore] = (
            SharedBackendCore()
            if shared is not None and any(s.name == shared for s in specs)
            else None
        )

        self.tenants: List[str] = (
            list(self.cc.tenants)
            if self.cc.tenants is not None
            else [f"r{i}" for i in range(n)]
        )
        assert len(self.tenants) == n, (self.tenants, n)
        self.replicas: List[ServingEngine] = [
            self._build_replica(
                i, cfg, params, specs, planner_factory, pricing, perf, on_token
            )
            for i in range(n)
        ]

        self._alive: List[bool] = [True] * n
        self._digests: List[Optional[BloomDigest]] = [None] * n
        # delta gossip: replica -> (store digest_epoch, log cursor) at the
        # last tick, so put-only windows ship just the add-set
        self._digest_state: Dict[int, Tuple[int, int]] = {}
        self.gossip_ticks = 0
        self.gossip_full_syncs = 0  # ticks that had to rebuild a digest
        self.gossip_delta_hashes = 0  # hashes shipped as deltas instead
        self._next_gossip = (
            self.cc.gossip_interval_s if self.cc.gossip_interval_s > 0
            else float("inf")
        )
        self._next_rebalance = (
            self.cc.rebalance_interval_s if self.cc.rebalance_interval_s > 0
            else float("inf")
        )

        self.router = router or AffinityRouter()
        r0 = self.replicas[0]
        self.router.configure(
            cost_cfg=r0.cost_cfg,
            pricing=r0.pricing,
            perf=r0.perf,
            chunk_tokens=self.ec.chunk_tokens,
            replica_ids=list(range(n)),
        )

        # pending heap: (arrival_s, seq, Request) — routed at arrival time so
        # gossip that lands between now and then can inform the decision
        self._pending: List[Tuple[float, int, Request]] = []
        self._seq = itertools.count()
        self.events: List[Tuple[int, ev.Event]] = []
        self.events_by_replica: List[List[ev.Event]] = [[] for _ in range(n)]
        # content_key -> routed-request counts per replica, and the tokens
        # needed to re-materialize the context on a rebalance target
        self._route_hits: Dict[str, Dict[int, int]] = {}
        self._ctx_tokens: Dict[str, Tuple[int, ...]] = {}
        self.rebalances = 0

    # ------------------------------------------------------------------ #
    # Construction
    # ------------------------------------------------------------------ #
    def _build_replica(
        self, i, cfg, params, specs, planner_factory, pricing, perf, on_token,
    ) -> ServingEngine:
        """One engine with a PRIVATE clock/transfer and private hot backends;
        the shared tier (if configured) is a namespaced view onto the one
        cluster core, billed through this replica's own transfer model."""
        clock = SimClock()
        eng_perf = perf
        eng_pricing = pricing
        # The engine defaults pricing/perf itself; to hand backends a
        # transfer model consistent with the engine's, resolve defaults the
        # same way the engine does.
        if eng_pricing is None or eng_perf is None:
            eng_pricing = eng_pricing or tpu_v5e_pod(8)
            eng_perf = eng_perf or PerfModel(tpu_v5e(8, hosts=1))
        transfer = TransferModel(eng_perf, eng_pricing)

        backends: Dict[str, Any] = {}
        for spec in specs:
            if self.core is not None and spec.name == self.cc.shared_tier:
                b = SharedTierBackend(
                    spec.name, core=self.core, namespace=self.tenants[i],
                    transfer=transfer, clock=clock, faults=self.ec.faults,
                )
            else:
                kind = _BACKEND_KINDS[spec.backend or _default_kind(spec.name)]
                b = kind(
                    spec.name, transfer=transfer, clock=clock,
                    hedge=self.ec.hedge if kind.hedgeable else None,
                    faults=self.ec.faults,
                )
            if spec.concurrency is not None:
                b = ConcurrencyLimitedBackend(b, spec.concurrency, clock=clock)
            backends[spec.name] = b

        planner = planner_factory() if planner_factory else None
        session = None
        if self.market is not None:
            session = self.market.join(self.tenants[i])
            if (
                planner is not None
                and getattr(planner, "session", False) is None
            ):
                # a MarketPlanner built bare by the factory shops through
                # this replica's own session
                planner.session = session
        return ServingEngine(
            cfg,
            params,
            engine_cfg=self.ec,
            planner=planner,
            backends=backends,
            pricing=pricing,
            perf=perf,
            clock=clock,
            transfer=transfer,
            on_token=((lambda e, _i=i: on_token(_i, e)) if on_token else None),
            telemetry=self.telemetry,
            telemetry_replica=i,
            market=session,
        )

    # ------------------------------------------------------------------ #
    # Public API
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        heapq.heappush(self._pending, (req.arrival_s, next(self._seq), req))

    @property
    def idle(self) -> bool:
        return not self._pending and all(
            e.idle for e, a in zip(self.replicas, self._alive) if a
        )

    def cluster_now(self) -> Optional[float]:
        """The simulation frontier: the furthest-behind busy replica's local
        time (None = every replica idle)."""
        busy = [
            e.clock.now
            for e, a in zip(self.replicas, self._alive)
            if a and not e.idle
        ]
        return min(busy) if busy else None

    def step(self) -> List[Tuple[int, ev.Event]]:
        """One cluster scheduling step: dispatch due arrivals through the
        router, run due gossip/rebalance ticks, then step the busy replica
        with the smallest local clock.  Returns that step's replica-tagged
        events (also appended to ``events``)."""
        out: List[Tuple[int, ev.Event]] = []
        now = self.cluster_now()
        if now is None:
            if not self._pending:
                return out  # fully drained
            now = self._pending[0][0]  # all idle: jump to the next arrival

        # injected replica crashes fire at the cluster frontier, before any
        # replica steps past them
        if self.ec.faults is not None:
            for plan in self.ec.faults.due_crashes(now):
                if 0 <= plan.replica < len(self.replicas) and self._alive[
                    plan.replica
                ]:
                    self.crash_replica(plan.replica, now, out)

        # at most one tick per step: a long idle jump re-arms from `now`
        # instead of replaying every missed cadence slot
        if now >= self._next_gossip:
            self.gossip_now()
            self._next_gossip = now + self.cc.gossip_interval_s
        if now >= self._next_rebalance:
            self._rebalance(now, out)
            self._next_rebalance = now + self.cc.rebalance_interval_s

        while self._pending and self._pending[0][0] <= now:
            _, _, req = heapq.heappop(self._pending)
            self._dispatch(req, out)

        busy = [
            e for e, a in zip(self.replicas, self._alive) if a and not e.idle
        ]
        if busy:
            eng = min(busy, key=lambda e: e.clock.now)
            i = self.replicas.index(eng)
            for e_ in eng.step():
                self._emit(i, e_, out)
        self.events.extend(out)
        return out

    def run(self) -> metrics_mod.ClusterSummary:
        while not self.idle:
            self.step()
        return self.summary()

    def summary(self) -> metrics_mod.ClusterSummary:
        return metrics_mod.ClusterSummary(
            replicas=[e.summary() for e in self.replicas],
            tokens_generated=sum(
                len(r.tokens) for e in self.replicas for r in e.records
            ),
        )

    @property
    def records(self):
        return [r for e in self.replicas for r in e.records]

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "gossip_ticks": self.gossip_ticks,
            "rebalances": self.rebalances,
            "per_replica": [e.packed_stats() for e in self.replicas],
        }
        if self.core is not None:
            out["shared"] = self.core.stats()
        return out

    # ------------------------------------------------------------------ #
    # Routing
    # ------------------------------------------------------------------ #
    def views(self) -> List[ReplicaView]:
        """Live router view: load/capacity are current (the cluster owns
        both), digests are the last gossiped ones — stale by design."""
        vs = []
        for i, eng in enumerate(self.replicas):
            if not self._alive[i]:
                continue
            load = eng.load()
            free = eng.free_capacity()
            queue_s = (
                0.0 if free > 0
                else (load - eng.ec.max_slots + 1) * self.cc.est_service_s
            )
            vs.append(
                ReplicaView(
                    replica=i, load=load, free_slots=free, queue_s=queue_s,
                    digest=self._digests[i],
                    hit_tier=eng.store.tier_order[0],
                )
            )
        return vs

    def _dispatch(self, req: Request, out) -> None:
        d = self.router.decide(req, self.views())
        eng = self.replicas[d.replica]
        eng.submit(req)
        ck = eng.store.content_key(req.context_tokens)
        self._route_hits.setdefault(ck, {}).setdefault(d.replica, 0)
        self._route_hits[ck][d.replica] += 1
        self._ctx_tokens[ck] = tuple(req.context_tokens)
        self._emit_cluster(
            d.replica,
            ev.RequestRouted(
                t_s=req.arrival_s, req_id=req.req_id, replica=d.replica,
                matched_tokens=d.matched_tokens, score=d.score,
                ring_owner=d.ring_owner,
            ),
            out,
        )

    def _emit(self, replica: int, event: ev.Event, out) -> None:
        out.append((replica, event))
        self.events_by_replica[replica].append(event)
        if self.trace is not None:
            self.trace.write(event, replica=replica)

    def _emit_cluster(self, replica: int, event: ev.Event, out) -> None:
        """Emit a CLUSTER-originated event (routing/rebalance): engine events
        reach telemetry from the engine's own step(), these only from here."""
        self._emit(replica, event, out)
        if self.telemetry is not None:
            self.telemetry.on_events([event], replica=replica)

    # ------------------------------------------------------------------ #
    # Gossip
    # ------------------------------------------------------------------ #
    def gossip_now(self) -> None:
        """Refresh every live replica's bloom digest from its store's hash
        surface — incrementally.  Bloom adds are idempotent and commutative,
        so a put-only window ships just the ADD-SET since the last tick
        (``TieredStore.digest_view``); a removal (evict/discard) bumps the
        store's digest epoch — bloom bits cannot be cleared — forcing one
        full rebuild, after which deltas resume.  Either way the resulting
        bits are identical to a from-scratch rebuild every tick (the
        staleness-equivalence test in tests/test_cluster.py).  Pure
        host-side work: no jit traffic, so steady-state serving compiles
        nothing extra (asserted in the cluster bench)."""
        nbytes = 0.0
        for i, eng in enumerate(self.replicas):
            if not self._alive[i]:
                continue
            epoch, log = eng.store.digest_view()
            state = self._digest_state.get(i)
            d = self._digests[i]
            if d is None or state is None or state[0] != epoch:
                d = BloomDigest(self.cc.digest_bits, self.cc.digest_hashes)
                d.update(log)
                self._digests[i] = d
                self.gossip_full_syncs += 1
                nbytes += self.cc.digest_bits / 8.0
            else:
                added = log[state[1]:]
                if added:
                    d.update(added)
                    self.gossip_delta_hashes += len(added)
                    # delta gossip ships the new hash ids, not the bitmap
                    nbytes += 16.0 * len(added)
            self._digest_state[i] = (epoch, len(log))
        self.gossip_ticks += 1
        if self.telemetry is not None:
            # digest traffic is host-side and unbilled: a zero-dollar ledger
            # entry records the (now mostly delta-sized) bytes on the wire
            self.telemetry.note_gossip(nbytes=nbytes)

    # ------------------------------------------------------------------ #
    # Rebalancing (copy-then-keep)
    # ------------------------------------------------------------------ #
    def _find_entry(self, eng: ServingEngine, ck: str) -> Optional[StoredEntry]:
        for e in eng.store.entries.values():
            if e.content_key == ck:
                return e
        return None

    def _rebalance(self, now: float, out) -> None:
        """Move hot entries toward their traffic: for every context whose
        routed requests concentrate on a replica that does not hold its KV,
        copy the donor's bytes into the target's fastest tier.  The donor
        keeps its copy (replicated residency) — at no point is the entry
        unreachable from either replica."""
        for ck, hits in self._route_hits.items():
            target = max(
                hits, key=lambda r: (hits[r], -r),
            )
            if not self._alive[target]:
                continue
            if hits[target] < self.cc.rebalance_min_hits:
                continue
            t_eng = self.replicas[target]
            if self._find_entry(t_eng, ck) is not None:
                continue  # traffic already lands where the bytes are
            tokens = self._ctx_tokens.get(ck)
            if tokens is None:
                continue
            donor = None
            d_entry = None
            for i, eng in enumerate(self.replicas):
                if i == target or not self._alive[i]:
                    continue
                e = self._find_entry(eng, ck)
                if e is not None and e.pins == 0:
                    donor, d_entry = i, e
                    break
            if donor is None:
                continue
            d_eng = self.replicas[donor]
            payload = d_eng.store.backends[d_entry.tier].peek(d_entry.entry_id)
            if payload is None:
                continue
            art = (
                compression.decompress_tree(payload)
                if d_entry.compressed else payload
            )
            with t_eng._attr("rebalance"):
                eid, _ = t_eng.store.put(
                    list(tokens), art,
                    tier=t_eng.store.tier_order[0],
                    saved_per_use=d_entry.saved_per_use,
                )
            if eid is None:
                continue
            self.rebalances += 1
            self._emit_cluster(
                target,
                ev.ReplicaRebalanced(
                    t_s=now, req_id=-1, content_key=ck,
                    from_replica=donor, to_replica=target,
                    nbytes=t_eng.store.entries[eid].nbytes,
                    hits=hits[target],
                ),
                out,
            )

    # ------------------------------------------------------------------ #
    # Membership
    # ------------------------------------------------------------------ #
    def crash_replica(self, idx: int, now: float, out) -> None:
        """Kill a replica mid-run and recover its work: harvest its in-flight
        (active-slot) and queued requests, release its shared-tier namespace
        and digest (``remove_replica``), and resubmit the harvested requests
        through the router to the survivors.  In-flight partial generations
        are discarded and replayed from scratch on the landing replica —
        decode is greedy and deterministic, so the resubmitted request's
        tokens are identical to the run where the crash never happened."""
        eng = self.replicas[idx]
        inflight = [
            s.request for s in eng.slots if s.active and s.request is not None
        ]
        queued = eng.queue.drain()
        released = self.remove_replica(idx)
        for req in inflight + queued:
            self.submit(dataclasses.replace(req, arrival_s=max(req.arrival_s, now)))
        self._emit_cluster(
            idx,
            ev.ReplicaCrashed(
                t_s=now, req_id=-1, replica=idx,
                inflight=len(inflight), queued=len(queued),
                released_keys=released,
            ),
            out,
        )

    def remove_replica(self, idx: int) -> int:
        """Take a replica out of the cluster (crash or drain-down): release
        every shared-tier key it owned — refcounting in the core keeps any
        content other replicas still reference alive — and drop it from the
        router's ring and view set.  Returns the number of shared keys
        released."""
        assert self._alive[idx], f"replica {idx} already removed"
        self._alive[idx] = False
        self._digests[idx] = None
        self._digest_state.pop(idx, None)
        released = 0
        for b in self.replicas[idx].backends.values():
            rel = getattr(b, "release_namespace", None)
            if callable(rel):
                released += rel()
        ring = getattr(self.router, "ring", None)
        if ring is not None:
            ring.remove(idx)
        return released
