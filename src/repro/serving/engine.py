"""Continuous-batching serving engine, structured as plan -> execute.

The paper's pipeline, end to end: on admission a request's context is looked
up in the tiered ContextStore (chain-hash prefix match); a pluggable
``ReusePlanner`` turns (request, lookup, workload) into a declarative
``ReusePlan`` (recompute / load / partial-load, + write-back); the engine
*executes* the plan — storage fetch through the tier's ``StorageBackend``,
(suffix-)prefill of the unmatched tail + prompt, break-even-gated write-back
— and decode runs batched across slots.

The engine is step-driven: ``submit()`` enqueues, ``step()`` performs one
scheduling step (admit one request, or one batched decode step, or a clock
jump to the next arrival) and returns the typed ``events`` it produced;
``drain()`` iterates steps to completion; ``run()`` is the thin
drain-then-summarize loop.  Traces, streaming callers, and the benchmarks
all drive this one surface.

Time/cost accounting: compute is real JAX execution with *modeled* durations
(PerfModel — this container has no TPU), storage/network delays flow through
the backends' TransferModel.  Numerics are real: reused-KV outputs are
bit-comparable to recompute outputs (tests/test_serving.py asserts it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost_model import Workload, s_storage_bytes
from repro.core.perf_model import PerfModel, tpu_v5e
from repro.core.pricing import Pricing, tpu_v5e_pod
from repro.kvcache import paged
from repro.kvcache.backend import StorageBackend
from repro.kvcache.hierarchy import (
    BreakEvenMigrator,
    TieredStore,
    TierSpec,
    build_backends,
)
from repro.kvcache.transfer import SimClock, TransferModel
from repro.models import registry
from repro.serving import events as ev
from repro.serving import metrics as metrics_mod
from repro.serving.planner import (
    CostAwarePlanner,
    ReusePlan,
    ReusePlanner,
    StoreLookup,
)
from repro.serving.request import Request, RequestRecord, Slot
from repro.serving.scheduler import AdmissionQueue, HedgePolicy


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 512
    chunk_tokens: int = 16
    reuse_enabled: bool = True
    tier_capacities_gb: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"host_dram": 64.0, "io2": 1024.0}
    )
    # Full hierarchy declaration (fastest first); overrides tier_capacities_gb
    # and enables per-tier backend kinds + link concurrency limits.
    tier_specs: Optional[List[TierSpec]] = None
    # Tier write-backs land in (default: the last/cheapest tier).
    store_tier: Optional[str] = None
    # >0 enables the clock-driven break-even migration pass at this cadence;
    # migrations surface as TierMigrated events.
    migration_interval_s: float = 0.0
    migration_policy: Optional[BreakEvenMigrator] = None
    # Under capacity pressure, demote the least valuable entry one tier down
    # instead of deleting it outright.
    spill_on_pressure: bool = False
    compress_tier: Optional[str] = None  # e.g. "io2" for the int8 tier
    overlap_load: bool = False  # beyond-paper prefetch overlap
    hedge: Optional[HedgePolicy] = None
    eviction: str = "cost"
    store_write_back: bool = True
    # Economics-at-scale: model times/costs (prefill, decode, KV bytes) as if
    # serving this FULL arch while the actual compute uses a reduced config —
    # functional tests and CPU examples get paper-scale $ and delays with
    # real token-level numerics. None = model the served config itself.
    cost_arch: Optional[str] = None
    # Lookahead prefetch (beyond-paper): when admitting a request, start
    # fetching the stored contexts of the next queued requests so their loads
    # overlap the current request's compute.  The paper's pipeline loads
    # at admission (TTFT pays the full fetch); with lookahead only the
    # not-yet-arrived remainder shows up in TTFT.
    prefetch_lookahead: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        engine_cfg: Optional[EngineConfig] = None,
        planner: Optional[ReusePlanner] = None,
        backends: Optional[Dict[str, StorageBackend]] = None,
        pricing: Optional[Pricing] = None,
        perf: Optional[PerfModel] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg or EngineConfig()
        self.pricing = pricing or tpu_v5e_pod(8)
        self.perf = perf or PerfModel(tpu_v5e(8, hosts=1))
        self.api = registry.get_model(cfg)
        if self.ec.cost_arch is not None:
            from repro.configs import get_config

            self.cost_cfg = get_config(self.ec.cost_arch)
        else:
            self.cost_cfg = cfg

        self.clock = SimClock()
        self.transfer = TransferModel(self.perf, self.pricing)
        self._c_gpu_s = self.pricing.compute.cost_per_hour / 3600.0
        if self.ec.tier_specs is not None:
            specs = list(self.ec.tier_specs)
        else:
            specs = [TierSpec(n, gb) for n, gb in self.ec.tier_capacities_gb.items()]
        self.backends = backends or build_backends(
            specs, transfer=self.transfer, clock=self.clock, hedge=self.ec.hedge,
        )
        migration = self.ec.migration_policy
        if migration is None and self.ec.migration_interval_s > 0:
            migration = BreakEvenMigrator(compute_cost_per_s=self._c_gpu_s)
        self.store = TieredStore(
            tiers=specs,
            transfer=self.transfer,
            clock=self.clock,
            chunk_tokens=self.ec.chunk_tokens,
            compress_tier=self.ec.compress_tier,
            eviction=self.ec.eviction,
            backends=self.backends,
            pricing=self.pricing,
            migration=migration,
            spill_on_pressure=self.ec.spill_on_pressure,
        )
        self.planner: ReusePlanner = planner or CostAwarePlanner()
        self.planner.configure(
            cost_cfg=self.cost_cfg,
            pricing=self.pricing,
            perf=self.perf,
            write_back=self.ec.reuse_enabled and self.ec.store_write_back,
            min_store_tokens=self.ec.chunk_tokens,
        )
        self.queue = AdmissionQueue()
        self.slots = [Slot(i) for i in range(self.ec.max_slots)]
        self.records: List[RequestRecord] = []
        # req_id -> clock time its context prefetch completes
        self._prefetch_ready: Dict[int, float] = {}
        # req_id -> entry pinned on its behalf (prefetch/eviction race guard)
        self._prefetch_pins: Dict[int, str] = {}
        self._next_migration_s = self.ec.migration_interval_s

        self._state = self.api.init_state(cfg, self.ec.max_slots, self.ec.max_len)
        self._jit_prefill = jax.jit(self._prefill_impl)
        self._jit_decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------------ #
    # jit'd compute
    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, state, embeds=None):
        return self.api.prefill(params, self.cfg, tokens, state, embeds=embeds)

    def _decode_impl(self, params, tokens, state, active):
        logits, new_state = self.api.decode(params, self.cfg, tokens, state)
        # inactive slots: freeze position (their cache row writes are masked
        # by pos-based validity on the next real request).
        pos = jnp.where(active, new_state.pos, state.pos)
        new_state = new_state._replace(pos=pos)
        return logits, new_state

    # ------------------------------------------------------------------ #
    # Public API: submit / step / drain / run
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.push(req)

    @property
    def idle(self) -> bool:
        """Nothing queued and nothing decoding."""
        return len(self.queue) == 0 and not any(s.active for s in self.slots)

    def step(self) -> List[ev.Event]:
        """Advance the engine by one scheduling step and return its events:
        admit one request if a slot and an arrived request exist, else run one
        batched decode step, else jump the clock to the next arrival.  A due
        migration pass (EngineConfig.migration_interval_s) piggybacks on the
        step and surfaces as TierMigrated events."""
        events: List[ev.Event] = []
        self._run_migrations(events)
        if self._admit_one(events):
            return events
        if any(s.active for s in self.slots):
            self._decode_step(events)
            return events
        nxt = self.queue.next_arrival()
        if nxt is None:
            return events  # fully drained
        self.clock.at_least(nxt)
        events.append(ev.ClockAdvanced(t_s=self.clock.now, req_id=-1, to_s=nxt))
        return events

    def drain(self) -> Iterator[ev.Event]:
        """Iterate events until every submitted request has finished."""
        while not self.idle:
            yield from self.step()

    def run(self) -> metrics_mod.ServingSummary:
        """Serve everything submitted; returns the summary."""
        for _ in self.drain():
            pass
        return self.summary()

    def summary(self) -> metrics_mod.ServingSummary:
        return metrics_mod.summarize(
            self.records,
            storage_cost=self.store.storage_cost(self.pricing),
            transfer_cost=self.transfer.transfer_fees(),
        )

    # ------------------------------------------------------------------ #
    # Tier migration (clock-driven economics pass)
    # ------------------------------------------------------------------ #
    def _run_migrations(self, events: List[ev.Event]) -> None:
        if (
            self.ec.migration_interval_s <= 0
            or self.store.migration is None
            or self.clock.now < self._next_migration_s
        ):
            return
        self.store.run_migrations()
        self._next_migration_s = self.clock.now + self.ec.migration_interval_s
        self._emit_migrations(events)

    def _emit_migrations(self, events: List[ev.Event]) -> None:
        """Surface store migrations (policy passes AND pressure spills) as
        typed events, stamped with the move's own SimClock time."""
        for m in self.store.drain_migrations():
            events.append(
                ev.TierMigrated(
                    t_s=m.t_s, req_id=-1, entry_id=m.entry_id,
                    from_tier=m.from_tier, to_tier=m.to_tier,
                    nbytes=m.nbytes, reason=m.reason,
                )
            )

    # ------------------------------------------------------------------ #
    # Admission: pop -> plan -> execute plan
    # ------------------------------------------------------------------ #
    def _free_slot(self) -> Optional[Slot]:
        for s in self.slots:
            if not s.active:
                return s
        return None

    def _admit_one(self, events: List[ev.Event]) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.queue.pop_admissible(self.clock.now)
        if req is None:
            return False

        rec = RequestRecord(
            req_id=req.req_id,
            arrival_s=req.arrival_s,
            context_len=len(req.context_tokens),
            prompt_len=len(req.prompt_tokens),
            start_s=self.clock.now,
        )
        total_len = len(req.context_tokens) + len(req.prompt_tokens) + req.max_new_tokens
        assert total_len <= self.ec.max_len, (total_len, self.ec.max_len)
        events.append(
            ev.RequestAdmitted(
                t_s=self.clock.now, req_id=req.req_id, slot=slot.index,
                queue_s=rec.queue_s,
            )
        )

        lookup = self._lookup(req)
        workload = Workload(
            L_context=len(req.context_tokens),
            L_prompt=len(req.prompt_tokens),
            L_output=req.max_new_tokens,
            N=max(int(req.expected_reuses), 1),
            slo_ttft_s=req.slo_ttft_s,
        )
        plan = self.planner.plan(req, lookup, workload)
        events.append(ev.PlanChosen(t_s=self.clock.now, req_id=req.req_id, plan=plan))

        if plan.loads_kv and lookup.entry is not None:
            load_s, prefill_s, logits, temp = self._execute_load(
                req, plan, lookup, events
            )
            matched = plan.matched_tokens
        else:
            load_s, matched = 0.0, 0
            prefill_s, logits, temp = self._execute_recompute(req, plan, events)
        self._release_prefetch(req.req_id)

        # ---- install into the batch slot ------------------------------- #
        self._state = paged.insert_slot(self.cfg, self._state, slot.index, temp)
        first_tok = int(jnp.argmax(logits[0]))

        self.clock.advance(load_s + prefill_s)
        rec.action = plan.action if plan.loads_kv else "recompute"
        rec.plan = plan
        rec.matched_tokens = matched
        rec.load_s = load_s
        rec.prefill_s = prefill_s
        rec.compute_cost += self._c_gpu_s * prefill_s
        rec.tokens.append(first_tok)
        events.append(
            ev.TokenEmitted(t_s=self.clock.now, req_id=req.req_id, token=first_tok, index=0)
        )

        slot.request = req
        slot.record = rec
        slot.generated = 1
        slot.last_token = first_tok
        slot.active = True
        self._maybe_finish(slot, events)
        self._issue_prefetches()
        return True

    def _lookup(self, req: Request) -> StoreLookup:
        """Consult the store about the request's context; quantify how much of
        it the architecture can actually consume."""
        if not self.ec.reuse_enabled:
            return StoreLookup.miss()
        match, entry = self.store.lookup(list(req.context_tokens))
        partial_ok = paged.partial_reuse_allowed(self.cfg) and req.embeds is None
        frac = 0.0
        n_ctx = len(req.context_tokens)
        if entry is not None and match.matched_tokens > 0:
            if match.matched_tokens >= n_ctx:
                frac = 1.0
            elif partial_ok:
                frac = match.matched_tokens / n_ctx
        queue_wait: Dict[str, float] = {}
        if entry is not None and frac > 0:
            # contended-link visibility for the planner: predicted queueing
            # delay on the entry's tier (0 on uncontended links)
            wait = self.store.estimated_queue_wait(
                entry.tier, self._entry_fetch_bytes(entry, match.matched_tokens)
            )
            if wait > 0:
                queue_wait[entry.tier] = wait
        return StoreLookup(
            match=match, entry=entry, fraction=frac, partial_ok=partial_ok,
            queue_wait_s=queue_wait,
        )

    def _entry_fetch_bytes(self, e, matched_tokens: int) -> float:
        """Bytes a fetch of ``matched_tokens`` moves, at economics scale."""
        if self.cost_cfg is not self.cfg:
            return s_storage_bytes(
                self.cost_cfg, matched_tokens,
                compression=0.5 if self.ec.compress_tier == e.tier else 1.0,
            )
        return e.nbytes * matched_tokens / max(e.n_tokens, 1)

    # ------------------------------------------------------------------ #
    # Execute: the two plan interpretations
    # ------------------------------------------------------------------ #
    def _execute_load(
        self, req: Request, plan: ReusePlan, lookup: StoreLookup,
        events: List[ev.Event],
    ):
        """Fetch stored context state, insert it, prefill only the unmatched
        tail + prompt."""
        entry = lookup.entry
        matched = plan.matched_tokens
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        nbytes = plan.fetch_bytes
        override = None
        if self.cost_cfg is not self.cfg:
            # economics-at-scale: charge the FULL arch's KV bytes, and occupy
            # the tier's link for them — queueing under burst (concurrency-
            # limited backends) is modeled at the same scale as the delay.
            nbytes = self._entry_fetch_bytes(entry, matched)
            override = nbytes
        artifact, delay = self.store.fetch(
            entry.entry_id, fraction=matched / entry.n_tokens, nbytes=override
        )
        ready = self._prefetch_ready.pop(req.req_id, None)
        if ready is not None:
            # fetch was issued while earlier requests were being served:
            # only the unfinished remainder delays this request.
            delay = max(0.0, min(delay, ready - self.clock.now))
        temp = paged.insert_slot(self.cfg, temp, 0, artifact, n_tokens=matched)
        ctx = list(req.context_tokens)
        tail = [] if req.embeds is not None else ctx[matched:]
        tokens = jnp.asarray([tail + list(req.prompt_tokens)], jnp.int32)
        logits, temp = self._jit_prefill(self.params, tokens, temp)
        prefill_s = self.perf.t_prefill(
            self.cost_cfg, len(tail) + len(req.prompt_tokens)
        )
        if self.ec.overlap_load:
            load_s = max(0.0, delay - prefill_s)
        else:
            load_s = delay
        events.append(
            ev.KVLoaded(
                t_s=self.clock.now, req_id=req.req_id, tier=entry.tier,
                nbytes=nbytes, load_s=load_s, matched_tokens=matched,
            )
        )
        events.append(
            ev.PrefillDone(
                t_s=self.clock.now, req_id=req.req_id,
                n_tokens=len(tail) + len(req.prompt_tokens), prefill_s=prefill_s,
            )
        )
        return load_s, prefill_s, logits, temp

    def _execute_recompute(
        self, req: Request, plan: ReusePlan, events: List[ev.Event]
    ):
        """Full prefill; write the context state back iff the plan says so."""
        ctx, prompt = list(req.context_tokens), list(req.prompt_tokens)
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        saved = self._c_gpu_s * self.perf.t_prefill(self.cost_cfg, len(ctx))

        def write_back(artifact):
            entry_id, _ = self.store.put(
                ctx, artifact, tier=self._store_tier(), saved_per_use=saved
            )
            # capacity-pressure spills triggered by this put surface now, at
            # their own timestamp, not at the next step's drain
            self._emit_migrations(events)
            if entry_id is not None:
                e = self.store.entries[entry_id]
                events.append(
                    ev.StoreWriteBack(
                        t_s=self.clock.now, req_id=req.req_id,
                        entry_id=entry_id, tier=e.tier, nbytes=e.nbytes,
                    )
                )

        if req.embeds is not None:
            # VLM/audio context: the context IS the embeddings. Single
            # phase — positions [0, ctx) of the state depend only on the
            # embeds, so the artifact is extractable post-hoc.
            tokens = jnp.asarray([prompt], jnp.int32)
            logits, temp = self._jit_prefill(
                self.params, tokens, temp, embeds=req.embeds
            )
            if plan.store_after:
                write_back(paged.extract_slot(self.cfg, temp, 0, len(ctx)))
        elif plan.store_after:
            # Two-phase: context-only prefill -> snapshot (valid for SSM
            # state, which must not include prompt tokens) -> prompt.
            ctx_tokens = jnp.asarray([ctx], jnp.int32)
            _, temp = self._jit_prefill(self.params, ctx_tokens, temp)
            write_back(paged.extract_slot(self.cfg, temp, 0, len(ctx)))
            tokens = jnp.asarray([prompt], jnp.int32)
            logits, temp = self._jit_prefill(self.params, tokens, temp)
        else:
            tokens = jnp.asarray([ctx + prompt], jnp.int32)
            logits, temp = self._jit_prefill(self.params, tokens, temp)
        prefill_s = self.perf.t_prefill(self.cost_cfg, len(ctx) + len(prompt))
        events.append(
            ev.PrefillDone(
                t_s=self.clock.now, req_id=req.req_id,
                n_tokens=len(ctx) + len(prompt), prefill_s=prefill_s,
            )
        )
        return prefill_s, logits, temp

    def _issue_prefetches(self) -> None:
        """Lookahead: start storage fetches for queued requests whose contexts
        are stored (the fetch streams while the engine computes)."""
        if self.ec.prefetch_lookahead <= 0 or not self.ec.reuse_enabled:
            return
        for nxt in self.queue.peek_arrived(self.clock.now, self.ec.prefetch_lookahead):
            if nxt.req_id in self._prefetch_ready:
                continue
            m, e = self.store.lookup(list(nxt.context_tokens))
            if e is None or m.matched_tokens == 0:
                continue
            nbytes = self._entry_fetch_bytes(e, m.matched_tokens)
            delay = self.store.estimate_load_delay(e.tier, nbytes)
            self._prefetch_ready[nxt.req_id] = self.clock.now + delay
            # pin until admission consumes or abandons the prefetch: eviction
            # pressure (another request's write-back) and demotion must not
            # invalidate an in-flight fetch (ROADMAP prefetch/eviction race)
            self.store.pin(e.entry_id)
            self._prefetch_pins[nxt.req_id] = e.entry_id

    def _release_prefetch(self, req_id: int) -> None:
        """Admission consumed (or abandoned) this request's prefetch: drop the
        ready-time record and release the eviction pin."""
        self._prefetch_ready.pop(req_id, None)
        entry_id = self._prefetch_pins.pop(req_id, None)
        if entry_id is not None:
            self.store.unpin(entry_id)

    def _store_tier(self) -> str:
        if self.ec.store_tier is not None:
            return self.ec.store_tier
        return self.store.tier_order[-1]  # cloud tier (paper's EBS)

    # ------------------------------------------------------------------ #
    # Batched decode
    # ------------------------------------------------------------------ #
    def _decode_step(self, events: List[ev.Event]) -> None:
        active = np.array([s.active for s in self.slots])
        toks = np.array(
            [[s.last_token if s.active else 0] for s in self.slots], np.int32
        )
        logits, self._state = self._jit_decode(
            self.params, jnp.asarray(toks), self._state, jnp.asarray(active)
        )
        n_active = int(active.sum())
        ctx_len = max(
            (s.record.context_len + s.record.prompt_len + s.generated)
            for s in self.slots
            if s.active
        )
        step_s = self.perf.t_decode(self.cost_cfg, 1, ctx_len, batch=n_active)
        self.clock.advance(step_s)
        per_req_cost = self._c_gpu_s * step_s / n_active

        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in self.slots:
            if not s.active:
                continue
            tok = int(nxt[s.index])
            s.record.tokens.append(tok)
            s.record.decode_s += step_s
            s.record.compute_cost += per_req_cost
            s.last_token = tok
            events.append(
                ev.TokenEmitted(
                    t_s=self.clock.now, req_id=s.request.req_id,
                    token=tok, index=s.generated,
                )
            )
            s.generated += 1
            self._maybe_finish(s, events)

    def _maybe_finish(self, s: Slot, events: List[ev.Event]) -> None:
        req = s.request
        done = s.generated >= req.max_new_tokens or (
            req.eos_token is not None and s.last_token == req.eos_token
        )
        if done:
            s.record.finish_s = self.clock.now
            self.records.append(s.record)
            events.append(
                ev.RequestFinished(
                    t_s=self.clock.now, req_id=req.req_id, record=s.record
                )
            )
            s.active = False
            s.request = None
