"""Continuous-batching serving engine with first-class stored-KV reuse.

The paper's pipeline, end to end: on admission a request's context is looked
up in the tiered ContextStore (chain-hash prefix match); the cost-model
policy picks recompute / load / partial-load; loads insert stored state into
the slot and only the unmatched tail + prompt is (suffix-)prefilled; decode
runs batched across slots.  Write-back is break-even-gated.

Time/cost accounting: compute is real JAX execution with *modeled* durations
(PerfModel — this container has no TPU), storage/network delays flow through
TransferModel.  Numerics are real: reused-KV outputs are bit-comparable to
recompute outputs (tests/test_serving.py asserts it).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import policy as policy_mod
from repro.core.cost_model import Workload, s_storage_bytes
from repro.core.perf_model import PerfModel, tpu_v5e
from repro.core.pricing import GB, Pricing, tpu_v5e_pod
from repro.kvcache import paged
from repro.kvcache.store import ContextStore
from repro.kvcache.transfer import SimClock, TransferModel
from repro.models import registry
from repro.serving import metrics as metrics_mod
from repro.serving.request import Phase, Request, RequestRecord, Slot
from repro.serving.scheduler import AdmissionQueue, HedgePolicy


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 512
    chunk_tokens: int = 16
    reuse_enabled: bool = True
    # "cost"   — the paper's policy: store/load iff the analytical model says
    #            it pays (break-even gating).
    # "always" — store & reuse unconditionally (correctness tests, and the
    #            paper's own Fig-2 experiment which always reuses).
    policy_mode: str = "cost"
    tier_capacities_gb: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"host_dram": 64.0, "io2": 1024.0}
    )
    compress_tier: Optional[str] = None  # e.g. "io2" for the int8 tier
    overlap_load: bool = False  # beyond-paper prefetch overlap
    hedge: Optional[HedgePolicy] = None
    eviction: str = "cost"
    store_write_back: bool = True
    # Economics-at-scale: model times/costs (prefill, decode, KV bytes) as if
    # serving this FULL arch while the actual compute uses a reduced config —
    # functional tests and CPU examples get paper-scale $ and delays with
    # real token-level numerics. None = model the served config itself.
    cost_arch: Optional[str] = None
    # Lookahead prefetch (beyond-paper): when admitting a request, start
    # fetching the stored contexts of the next queued requests so their loads
    # overlap the current request's compute.  The paper's pipeline loads
    # at admission (TTFT pays the full fetch); with lookahead only the
    # not-yet-arrived remainder shows up in TTFT.
    prefetch_lookahead: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        engine_cfg: Optional[EngineConfig] = None,
        pricing: Optional[Pricing] = None,
        perf: Optional[PerfModel] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg or EngineConfig()
        self.pricing = pricing or tpu_v5e_pod(8)
        self.perf = perf or PerfModel(tpu_v5e(8, hosts=1))
        self.api = registry.get_model(cfg)
        if self.ec.cost_arch is not None:
            from repro.configs import get_config

            self.cost_cfg = get_config(self.ec.cost_arch)
        else:
            self.cost_cfg = cfg

        self.clock = SimClock()
        self.transfer = TransferModel(self.perf, self.pricing)
        self.store = ContextStore(
            tier_capacities_gb=self.ec.tier_capacities_gb,
            transfer=self.transfer,
            clock=self.clock,
            chunk_tokens=self.ec.chunk_tokens,
            compress_tier=self.ec.compress_tier,
            eviction=self.ec.eviction,
        )
        self.queue = AdmissionQueue()
        self.slots = [Slot(i) for i in range(self.ec.max_slots)]
        self.records: List[RequestRecord] = []
        self._c_gpu_s = self.pricing.compute.cost_per_hour / 3600.0
        # req_id -> clock time its context prefetch completes
        self._prefetch_ready: Dict[int, float] = {}

        self._state = self.api.init_state(cfg, self.ec.max_slots, self.ec.max_len)
        self._jit_prefill = jax.jit(self._prefill_impl)
        self._jit_decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------------ #
    # jit'd compute
    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, state, embeds=None):
        return self.api.prefill(params, self.cfg, tokens, state, embeds=embeds)

    def _decode_impl(self, params, tokens, state, active):
        logits, new_state = self.api.decode(params, self.cfg, tokens, state)
        # inactive slots: freeze position (their cache row writes are masked
        # by pos-based validity on the next real request).
        pos = jnp.where(active, new_state.pos, state.pos)
        new_state = new_state._replace(pos=pos)
        return logits, new_state

    # ------------------------------------------------------------------ #
    # Public API
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.push(req)

    def run(self) -> metrics_mod.ServingSummary:
        """Serve everything submitted; returns the summary."""
        while len(self.queue) or any(s.active for s in self.slots):
            progressed = self._admit_one()
            if progressed:
                continue
            if any(s.active for s in self.slots):
                self._decode_step()
                continue
            nxt = self.queue.next_arrival()
            assert nxt is not None
            self.clock.at_least(nxt)
        return self.summary()

    def summary(self) -> metrics_mod.ServingSummary:
        return metrics_mod.summarize(
            self.records,
            storage_cost=self.store.storage_cost(self.pricing),
            transfer_cost=self.transfer.transfer_fees(),
        )

    # ------------------------------------------------------------------ #
    # Admission + prefill (the paper's reuse path)
    # ------------------------------------------------------------------ #
    def _free_slot(self) -> Optional[Slot]:
        for s in self.slots:
            if not s.active:
                return s
        return None

    def _admit_one(self) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        req = self.queue.pop_admissible(self.clock.now)
        if req is None:
            return False

        rec = RequestRecord(
            req_id=req.req_id,
            arrival_s=req.arrival_s,
            context_len=len(req.context_tokens),
            prompt_len=len(req.prompt_tokens),
            start_s=self.clock.now,
        )

        ctx, prompt = list(req.context_tokens), list(req.prompt_tokens)
        total_len = len(ctx) + len(prompt) + req.max_new_tokens
        assert total_len <= self.ec.max_len, (total_len, self.ec.max_len)

        # ---- policy: lookup stored state, decide ---------------------- #
        match, entry = (
            self.store.lookup(ctx) if self.ec.reuse_enabled else (None, None)
        )
        partial_ok = paged.partial_reuse_allowed(self.cfg) and req.embeds is None
        frac = 0.0
        if entry is not None and match.matched_tokens > 0:
            if match.matched_tokens >= len(ctx):
                frac = 1.0
            elif partial_ok:
                frac = match.matched_tokens / len(ctx)
        w = Workload(
            L_context=len(ctx),
            L_prompt=len(prompt),
            L_output=req.max_new_tokens,
            N=max(int(req.expected_reuses), 1),
            slo_ttft_s=req.slo_ttft_s,
        )
        available = {entry.tier: frac} if (entry is not None and frac > 0) else {}
        if self.ec.policy_mode == "always" and available:
            tier_name, f = next(iter(available.items()))
            decision = policy_mod.Decision(
                action="load" if f >= 1.0 else "partial",
                tier=tier_name, reused_fraction=f, est_ttft_s=0.0, est_cost=0.0,
            )
        else:
            decision = policy_mod.decide(
                self.cost_cfg, w, self.pricing, self.perf, available=available
            )

        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        load_s = 0.0
        prefill_s = 0.0
        matched = 0

        if decision.loads_kv and entry is not None:
            matched = (
                len(ctx) if decision.action == "load" else match.matched_tokens
            )
            artifact, delay = self.store.fetch(
                entry.entry_id, fraction=matched / entry.n_tokens
            )
            if self.cost_cfg is not self.cfg:
                # economics-at-scale: charge the FULL arch's KV bytes
                nbytes = s_storage_bytes(
                    self.cost_cfg, matched,
                    compression=0.5 if self.ec.compress_tier == entry.tier else 1.0,
                )
                delay = self.perf.kv_load_time(nbytes, self.pricing.tier(entry.tier))
            if self.ec.hedge is not None:
                delay = self.ec.hedge.effective_delay(delay)
            ready = self._prefetch_ready.pop(req.req_id, None)
            if ready is not None:
                # fetch was issued while earlier requests were being served:
                # only the unfinished remainder delays this request.
                delay = max(0.0, min(delay, ready - self.clock.now))
            temp = paged.insert_slot(self.cfg, temp, 0, artifact, n_tokens=matched)
            tail = [] if req.embeds is not None else ctx[matched:]
            tokens = jnp.asarray([tail + prompt], jnp.int32)
            logits, temp = self._jit_prefill(self.params, tokens, temp)
            prefill_s = self.perf.t_prefill(self.cost_cfg, len(tail) + len(prompt))
            if self.ec.overlap_load:
                load_s = max(0.0, delay - prefill_s)
            else:
                load_s = delay
        else:
            # ---- recompute; store the context if break-even clears ----- #
            store_it = (
                self.ec.reuse_enabled
                and self.ec.store_write_back
                and entry is None
                and len(ctx) >= self.ec.chunk_tokens
                and (
                    self.ec.policy_mode == "always"
                    or policy_mod.should_store(
                        self.cost_cfg, w, self.pricing, self.perf,
                        expected_reuses=req.expected_reuses,
                    )
                )
            )
            saved = self._c_gpu_s * self.perf.t_prefill(self.cost_cfg, len(ctx))
            if req.embeds is not None:
                # VLM/audio context: the context IS the embeddings. Single
                # phase — positions [0, ctx) of the state depend only on the
                # embeds, so the artifact is extractable post-hoc.
                tokens = jnp.asarray([prompt], jnp.int32)
                logits, temp = self._jit_prefill(
                    self.params, tokens, temp, embeds=req.embeds
                )
                if store_it:
                    artifact = paged.extract_slot(self.cfg, temp, 0, len(ctx))
                    self.store.put(
                        ctx, artifact, tier=self._store_tier(), saved_per_use=saved
                    )
            elif store_it:
                # Two-phase: context-only prefill -> snapshot (valid for SSM
                # state, which must not include prompt tokens) -> prompt.
                ctx_tokens = jnp.asarray([ctx], jnp.int32)
                _, temp = self._jit_prefill(self.params, ctx_tokens, temp)
                artifact = paged.extract_slot(self.cfg, temp, 0, len(ctx))
                self.store.put(
                    ctx, artifact, tier=self._store_tier(), saved_per_use=saved
                )
                tokens = jnp.asarray([prompt], jnp.int32)
                logits, temp = self._jit_prefill(self.params, tokens, temp)
            else:
                tokens = jnp.asarray([ctx + prompt], jnp.int32)
                logits, temp = self._jit_prefill(self.params, tokens, temp)
            prefill_s = self.perf.t_prefill(self.cost_cfg, len(ctx) + len(prompt))

        # ---- install into the batch slot ------------------------------- #
        self._state = paged.insert_slot(
            self.cfg, self._state, slot.index, _as_artifact(temp)
        )
        first_tok = int(jnp.argmax(logits[0]))

        self.clock.advance(load_s + prefill_s)
        rec.action = decision.action if decision.loads_kv else "recompute"
        rec.matched_tokens = matched
        rec.load_s = load_s
        rec.prefill_s = prefill_s
        rec.compute_cost += self._c_gpu_s * prefill_s
        rec.tokens.append(first_tok)

        slot.request = req
        slot.record = rec
        slot.generated = 1
        slot.last_token = first_tok
        slot.active = True
        self._maybe_finish(slot)
        self._issue_prefetches()
        return True

    def _issue_prefetches(self) -> None:
        """Lookahead: start storage fetches for queued requests whose contexts
        are stored (the fetch streams while the engine computes)."""
        if self.ec.prefetch_lookahead <= 0 or not self.ec.reuse_enabled:
            return
        for nxt in self.queue.peek_arrived(self.clock.now, self.ec.prefetch_lookahead):
            if nxt.req_id in self._prefetch_ready:
                continue
            m, e = self.store.lookup(list(nxt.context_tokens))
            if e is None or m.matched_tokens == 0:
                continue
            if self.cost_cfg is not self.cfg:
                nbytes = s_storage_bytes(
                    self.cost_cfg, m.matched_tokens,
                    compression=0.5 if self.ec.compress_tier == e.tier else 1.0,
                )
            else:
                nbytes = e.nbytes * m.matched_tokens / max(e.n_tokens, 1)
            delay = self.perf.kv_load_time(nbytes, self.pricing.tier(e.tier))
            if self.ec.hedge is not None:
                delay = self.ec.hedge.effective_delay(delay)
            self._prefetch_ready[nxt.req_id] = self.clock.now + delay

    def _store_tier(self) -> str:
        return self.store.tier_order[-1]  # cloud tier (paper's EBS)

    # ------------------------------------------------------------------ #
    # Batched decode
    # ------------------------------------------------------------------ #
    def _decode_step(self) -> None:
        active = np.array([s.active for s in self.slots])
        toks = np.array(
            [[s.last_token if s.active else 0] for s in self.slots], np.int32
        )
        logits, self._state = self._jit_decode(
            self.params, jnp.asarray(toks), self._state, jnp.asarray(active)
        )
        n_active = int(active.sum())
        ctx_len = max(
            (s.record.context_len + s.record.prompt_len + s.generated)
            for s in self.slots
            if s.active
        )
        step_s = self.perf.t_decode(self.cost_cfg, 1, ctx_len, batch=n_active)
        self.clock.advance(step_s)
        per_req_cost = self._c_gpu_s * step_s / n_active

        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in self.slots:
            if not s.active:
                continue
            tok = int(nxt[s.index])
            s.record.tokens.append(tok)
            s.record.decode_s += step_s
            s.record.compute_cost += per_req_cost
            s.last_token = tok
            s.generated += 1
            self._maybe_finish(s)

    def _maybe_finish(self, s: Slot) -> None:
        req = s.request
        done = s.generated >= req.max_new_tokens or (
            req.eos_token is not None and s.last_token == req.eos_token
        )
        if done:
            s.record.finish_s = self.clock.now
            self.records.append(s.record)
            s.active = False
            s.request = None


def _as_artifact(temp_state):
    """A freshly prefillled batch-1 state is itself an insertable artifact."""
    return temp_state
