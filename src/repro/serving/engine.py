"""Continuous-batching serving engine, structured as plan -> execute.

The paper's pipeline, end to end: on admission a request's context is looked
up in the tiered ContextStore (chain-hash prefix match); a pluggable
``ReusePlanner`` turns (request, lookup, workload) into a declarative
``ReusePlan`` (recompute / load / partial-load, + write-back); the engine
*executes* the plan — storage fetch through the tier's ``StorageBackend``,
(suffix-)prefill of the unmatched tail + prompt, break-even-gated write-back
— and decode runs batched across slots.

The engine is step-driven: ``submit()`` enqueues, ``step()`` performs one
scheduling step (admit a batch of requests, or one batched decode step, or a
clock jump to the next arrival) and returns the typed ``events`` it produced;
``drain()`` iterates steps to completion; ``run()`` is the thin
drain-then-summarize loop.  Traces, streaming callers, and the benchmarks
all drive this one surface.

Admission is *batched and packed*: every admissible request with a free slot
is planned individually (lookup -> ReusePlan), then all unmatched context
tails + prompts execute as ONE packed ragged suffix-prefill — token runs
concatenated into a single sequence, segment ids keeping cross-request
attention masked out (``kernels/packed_prefill.py``), outputs scattered back
into per-slot paged state.  Packed lengths round up to power-of-two jit
buckets so steady traffic reuses compiled kernels (``packed_stats()`` exposes
the hit/miss counters); with ``admit_batch=1`` the packed path reproduces
per-request admission numerics and timing exactly (golden-parity tested).

Time/cost accounting: compute is real JAX execution with *modeled* durations
(PerfModel — this container has no TPU), storage/network delays flow through
the backends' TransferModel.  Numerics are real: reused-KV outputs are
bit-comparable to recompute outputs (tests/test_serving.py asserts it).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost_model import Workload, s_storage_bytes
from repro.core.perf_model import PerfModel, tpu_v5e
from repro.core.pricing import Pricing, tpu_v5e_pod
from repro.kvcache import fusion, paged
from repro.kvcache.backend import StorageBackend
from repro.kvcache.faults import FaultInjector, RetryPolicy, StorageError
from repro.kvcache.hierarchy import (
    BreakEvenMigrator,
    TieredStore,
    TierSpec,
    build_backends,
)
from repro.kvcache.transfer import SimClock, TransferModel
from repro.models import registry
from repro.serving import events as ev
from repro.serving import metrics as metrics_mod
from repro.serving.planner import (
    CostAwarePlanner,
    ReusePlan,
    ReusePlanner,
    StoreLookup,
)
from repro.serving.jit_cache import JitBucketStats
from repro.serving.request import Request, RequestRecord, Slot
from repro.serving.scheduler import AdmissionQueue, HedgePolicy


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 512
    chunk_tokens: int = 16
    reuse_enabled: bool = True
    tier_capacities_gb: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"host_dram": 64.0, "io2": 1024.0}
    )
    # Full hierarchy declaration (fastest first); overrides tier_capacities_gb
    # and enables per-tier backend kinds + link concurrency limits.
    tier_specs: Optional[List[TierSpec]] = None
    # Tier write-backs land in (default: the last/cheapest tier).
    store_tier: Optional[str] = None
    # >0 enables the clock-driven break-even migration pass at this cadence;
    # migrations surface as TierMigrated events.
    migration_interval_s: float = 0.0
    migration_policy: Optional[BreakEvenMigrator] = None
    # Under capacity pressure, demote the least valuable entry one tier down
    # instead of deleting it outright.
    spill_on_pressure: bool = False
    compress_tier: Optional[str] = None  # e.g. "io2" for the int8 tier
    overlap_load: bool = False  # beyond-paper prefetch overlap
    hedge: Optional[HedgePolicy] = None
    eviction: str = "cost"
    store_write_back: bool = True
    # Economics-at-scale: model times/costs (prefill, decode, KV bytes) as if
    # serving this FULL arch while the actual compute uses a reduced config —
    # functional tests and CPU examples get paper-scale $ and delays with
    # real token-level numerics. None = model the served config itself.
    cost_arch: Optional[str] = None
    # Lookahead prefetch (beyond-paper): when admitting a request, start
    # fetching the stored contexts of the next queued requests so their loads
    # overlap the current request's compute.  The paper's pipeline loads
    # at admission (TTFT pays the full fetch); with lookahead only the
    # not-yet-arrived remainder shows up in TTFT.
    prefetch_lookahead: int = 0
    # Max requests admitted per step as one packed ragged prefill (None =
    # every admissible request with a free slot).  1 reproduces per-request
    # admission timing exactly (the serve_bench baseline).
    admit_batch: Optional[int] = None
    # Each segment's kv span starts at a multiple of this (the flash kernel's
    # kv block): cross-segment kv blocks become fully-masked exact no-ops,
    # which is what makes packed outputs bit-identical to per-request ones.
    pack_align: int = 128
    # Smallest jit bucket for the packed q length (lengths round up to the
    # next power of two so steady-state serving stops recompiling).
    pack_bucket_min: int = 16
    # Paged batched decode: all active slots decode in ONE launch that
    # gathers each slot's live kv_block-token blocks from a shared block
    # pool (kernels/paged_decode.py) instead of streaming a dense per-slot
    # cache padded to max_len; the step is priced on the live blocks
    # (PerfModel.t_decode_paged).  Packed-prefill outputs land directly in
    # the pool (segments are kv_block-aligned, so spans ARE whole blocks);
    # batch-mates that loaded the same stored context share its full prefix
    # blocks (refcounted, copy-on-write on append).  Requires a packable
    # arch; others silently keep the dense path.  Tokens are bit-identical
    # to dense decode either way (tests/test_paged_decode.py).
    paged_decode: bool = False
    # Pool block size in tokens; must equal pack_align so packed-prefill kv
    # spans land block-aligned in the pool.
    kv_block: int = 128
    # CacheBlend-style fused non-prefix reuse: consult the store's chunk-
    # content index at lookup time (StoreLookup.composite) so a BlendPlanner
    # can plan "fused" admissions — assemble stored chunk KV out of order and
    # selectively recompute only its planner-chosen r-fraction
    # (kvcache/fusion.py + kernels/fused_prefill.py).  Off by default: the
    # seed golden trace replays untouched, and non-Blend planners ignore the
    # composite field entirely.  Packable attention archs only (assembled KV
    # needs per-position state); others never see a composite match.
    fusion_enabled: bool = False
    # Unified continuous-batching step (Sarathi-style chunked prefill): one
    # launch per step whose rows mix in-flight decode tokens with kv_block-
    # wide chunks of pending suffix-prefills, all over the shared block pool
    # (kernels/chunked_prefill.py).  Admissions stop monopolizing the device:
    # a long prefill lands incrementally while decodes keep stepping, so
    # burst arrivals no longer spike in-flight decode token gaps.  Requires
    # paged_decode and a packable arch; off by default — the seed golden
    # trace replays untouched (serve_bench's unified lane flips it on).
    unified_step: bool = False
    # Per-launch q-token quota for the unified step: decode rows always ride
    # (one token each), the remainder is granted to ready prefill chunks in
    # slot order.  Bounds the compute any single step can add on top of pure
    # decode — the knob behind the flat-decode-p99 CI gate.  160 keeps a
    # fully-granted mixed launch within ~1.17x of a pure decode step under
    # the default TPU-v5e(8) cost model (the gate's envelope is 1.2x);
    # compute-poorer hardware needs a smaller budget — serve_bench's unified
    # lane solves for it against its own PerfModel (_flat_step_budget).
    step_token_budget: int = 160
    # Seeded fault injection (kvcache/faults.FaultInjector): every storage
    # backend consults it for transient failures / brownouts / corruption,
    # and a ServingCluster for scheduled replica crashes.  None (default) =
    # no injection; the engine still verifies put/get checksums.
    faults: Optional[FaultInjector] = None
    # Cost-aware retry applied when a planned fetch fails (exponential
    # backoff; retries only while expected retry $ beats marginal recompute
    # $).  None = RetryPolicy() defaults.
    retry_policy: Optional[RetryPolicy] = None
    # Min-cacheable-size admission (the production prompt-cache rule from
    # SNIPPETS.md): contexts shorter than this many tokens are never written
    # back — a tiny entry's storage + write overhead can't repay itself.  0
    # (default) keeps the existing chunk_tokens floor and golden parity.
    min_cache_tokens: int = 0


@dataclasses.dataclass
class _Admission:
    """One request's admission in flight: plan phase fills the first five
    fields, packed execution the rest."""

    req: Request
    rec: RequestRecord
    slot: Slot
    plan: ReusePlan
    lookup: StoreLookup
    artifact: Any = None  # fetched stored state (None = recompute)
    delay: float = 0.0  # raw storage fetch delay
    load_s: float = 0.0  # delay actually charged (post-overlap)
    nbytes: float = 0.0
    matched: int = 0
    new_tokens: List[int] = dataclasses.field(default_factory=list)
    # fused admissions: source entries pinned between plan and execute (a
    # batch-mate's write-back pressure must not evict a fusion source)
    pins: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _ChunkStream:
    """One admission's pending suffix-prefill under the unified step: the
    q-token stream still to land (context tail + prompt; for fused plans the
    recompute spans + prompt) with each token's absolute target position.
    The slot's pool blocks are fully admitted up front; chunks of up to
    kv_block tokens land per unified launch until the stream drains, at
    which point the first generated token is emitted and the slot activates
    for decode."""

    a: _Admission
    tokens: np.ndarray  # int32 [n_q] q tokens still to prefill
    positions: np.ndarray  # int32 [n_q] absolute positions, increasing
    n_ctx: int  # context length (write-back row count)
    ready_s: float  # clock time the storage fetch completes
    store_after: bool = False  # write context rows back on completion
    done: int = 0  # tokens already landed

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.done


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        engine_cfg: Optional[EngineConfig] = None,
        planner: Optional[ReusePlanner] = None,
        backends: Optional[Dict[str, StorageBackend]] = None,
        pricing: Optional[Pricing] = None,
        perf: Optional[PerfModel] = None,
        clock: Optional[SimClock] = None,
        transfer: Optional[TransferModel] = None,
        on_token=None,
        telemetry=None,
        telemetry_replica: int = 0,
        market=None,
    ):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg or EngineConfig()
        self.pricing = pricing or tpu_v5e_pod(8)
        self.perf = perf or PerfModel(tpu_v5e(8, hosts=1))
        self.api = registry.get_model(cfg)
        if self.ec.cost_arch is not None:
            from repro.configs import get_config

            self.cost_cfg = get_config(self.ec.cost_arch)
        else:
            self.cost_cfg = cfg

        # clock/transfer are injectable so a ServingCluster can give every
        # replica its own simulated timeline and per-replica fee accounting
        # while tying shared backends to the right owner (serving/cluster.py)
        self.clock = clock or SimClock()
        self.transfer = transfer or TransferModel(self.perf, self.pricing)
        # streaming per-token hook (off by default): called with every
        # TokenEmitted event, in emission order — first tokens at admission
        # and each decode step's batch in slot order.
        self.on_token = on_token
        # Unified telemetry (obs.Telemetry), off by default.  Entirely
        # host-side: it observes the already-materialized event stream and
        # the transfer model's fee charges, so enabling it cannot change
        # tokens or trigger recompiles.  ``telemetry_replica`` tags this
        # engine's events/ledger entries when it serves inside a cluster.
        self.telemetry = telemetry
        self._replica = telemetry_replica
        if telemetry is not None:
            self.transfer.bind_ledger(telemetry.ledger, replica=telemetry_replica)
        self._c_gpu_s = self.pricing.compute.cost_per_hour / 3600.0
        if self.ec.tier_specs is not None:
            specs = list(self.ec.tier_specs)
        else:
            specs = [TierSpec(n, gb) for n, gb in self.ec.tier_capacities_gb.items()]
        self.backends = backends or build_backends(
            specs, transfer=self.transfer, clock=self.clock, hedge=self.ec.hedge,
            faults=self.ec.faults,
        )
        self.retry_policy = self.ec.retry_policy or RetryPolicy()
        migration = self.ec.migration_policy
        if migration is None and self.ec.migration_interval_s > 0:
            migration = BreakEvenMigrator(compute_cost_per_s=self._c_gpu_s)
        self.store = TieredStore(
            tiers=specs,
            transfer=self.transfer,
            clock=self.clock,
            chunk_tokens=self.ec.chunk_tokens,
            compress_tier=self.ec.compress_tier,
            eviction=self.ec.eviction,
            backends=self.backends,
            pricing=self.pricing,
            migration=migration,
            spill_on_pressure=self.ec.spill_on_pressure,
        )
        self.planner: ReusePlanner = planner or CostAwarePlanner()
        self.planner.configure(
            cost_cfg=self.cost_cfg,
            pricing=self.pricing,
            perf=self.perf,
            write_back=self.ec.reuse_enabled and self.ec.store_write_back,
            min_store_tokens=max(self.ec.chunk_tokens, self.ec.min_cache_tokens),
        )
        # Marketplace session (repro.market.MarketSession), duck-typed so the
        # engine never imports the market package.  Binding publishes this
        # engine's store as the tenant's catalog and hands the market the
        # bit-exactness oracle (market_spot_check).  None = no market; every
        # plan and token is exactly what it was before.
        self.market = market
        if market is not None:
            market.bind_engine(self)
            # a MarketPlanner built without an explicit session inherits
            # this engine's (duck-typed: only planners that can buy have one)
            if getattr(self.planner, "session", "no") is None:
                self.planner.session = market
        self.queue = AdmissionQueue()
        self.slots = [Slot(i) for i in range(self.ec.max_slots)]
        self.records: List[RequestRecord] = []
        # req_id -> clock time its context prefetch completes
        self._prefetch_ready: Dict[int, float] = {}
        # req_id -> entry pinned on its behalf (prefetch/eviction race guard)
        self._prefetch_pins: Dict[int, str] = {}
        # req_id -> (PrefixMatch, entry_id, trie_version): the prefetch pass's
        # trie walk, carried forward to admission so the same context is not
        # walked twice; invalidated by any trie mutation (version bump).
        self._prefetch_lookup: Dict[int, tuple] = {}
        self._next_migration_s = self.ec.migration_interval_s

        self._jit_prefill = jax.jit(self._prefill_impl)
        self._jit_decode = jax.jit(self._decode_impl)
        self._jit_packed = (
            jax.jit(self._packed_prefill_impl)
            if self.api.prefill_packed is not None
            else None
        )
        self._packable = (
            self.api.prefill_packed is not None
            and paged.packable_arch(cfg, self.ec.max_len)
        )
        # Paged batched decode over the shared KV block pool (packable archs
        # only — the paged layout needs per-position attention state and the
        # block-aligned packed-prefill spans to land admissions in place).
        self._paged_on = (
            self.ec.paged_decode
            and self._packable
            and self.api.decode_paged is not None
        )
        self._paged: Optional[paged.PagedSlots] = None
        if self._paged_on:
            assert self.ec.kv_block == self.ec.pack_align, (
                "packed spans must land block-aligned in the pool",
                self.ec.kv_block, self.ec.pack_align,
            )
            assert self.ec.max_len % self.ec.kv_block == 0, (
                self.ec.max_len, self.ec.kv_block)
            self._paged = paged.PagedSlots(
                self.ec.max_slots, self.ec.max_len, self.ec.kv_block
            )
            self._pool_caches = paged.init_pool_caches(
                cfg, self._paged.pool.n_blocks, self.ec.kv_block
            )
            self._jit_decode_paged = jax.jit(self._decode_paged_impl)
            # the paged path never touches the dense slotted cache: the pool
            # IS the device KV state (no doubled HBM footprint)
            self._state = None
        else:
            self._state = self.api.init_state(
                cfg, self.ec.max_slots, self.ec.max_len
            )
        # Fused non-prefix reuse (CacheBlend-style): chunk-composite lookups
        # + the selective-recompute launch.  Needs the packed path's arch
        # predicate (assembled KV is per-position attention state) and the
        # fused model entry point.
        self._jit_fused = (
            jax.jit(self._fused_prefill_impl)
            if self.api.prefill_fused is not None
            else None
        )
        self._fusion_on = (
            self.ec.fusion_enabled
            and self.ec.reuse_enabled
            and self._packable
            and self._jit_fused is not None
        )
        self.fused_jit = JitBucketStats()
        # Unified continuous-batching step: chunked prefill interleaved with
        # decode in one static-shape launch over the block pool.
        self._jit_chunked = (
            jax.jit(self._chunked_prefill_impl)
            if self.api.prefill_chunked is not None
            else None
        )
        self._unified_on = (
            self.ec.unified_step
            and self._paged_on
            and self._jit_chunked is not None
        )
        # slot index -> in-flight prefill stream (unified mode only)
        self._chunks: Dict[int, _ChunkStream] = {}
        # context-token tuples an unfinished chunk stream will write back:
        # the unified analogue of the packed batch's write-back dedup
        self._wb_inflight: Dict[tuple, int] = {}
        self.unified_jit = JitBucketStats()
        self.unified_steps = 0  # mixed (chunk-carrying) launches
        self.unified_chunk_tokens = 0  # prefill tokens landed via chunks
        self.unified_busy_s = 0.0  # modeled time in mixed launches
        self.fused_admissions = 0
        self.fused_reused_tokens = 0
        self.fused_recompute_tokens = 0
        self.fused_sources = 0
        self.fused_busy_s = 0.0
        # packed-admission observability (benchmarks assert on these)
        self.jit_stats = JitBucketStats()
        self.batches = 0
        self.packed_q_tokens = 0  # useful tokens through the packed kernel
        self.packed_q_len = 0  # padded (bucketed) tokens launched
        self.lookup_walks = 0  # real trie walks
        self.lookup_reuses = 0  # admissions served from the prefetch walk
        self.admission_busy_s = 0.0  # modeled time spent in load+prefill
        self.decode_busy_s = 0.0  # modeled time spent in decode steps
        self.decode_tokens = 0  # tokens emitted by decode steps
        # failure handling observability (fault injection / retry / degrade)
        self.fetch_failures = 0  # failed fetch attempts (every attempt)
        self.fetch_retries = 0  # attempts the retry policy re-issued
        self.degraded_requests = 0  # admissions that fell back to recompute
        self.fetch_wasted_s = 0.0  # time burned by failed attempts + backoff
        self.fetch_wasted_bytes = 0.0  # transfer bytes charged but unusable
        # marketplace observability (None market = all stay 0)
        self.market_purchases = 0  # plans served with bought peer KV
        self.market_failed = 0  # purchases that degraded to recompute
        self.market_spend = 0.0  # buyer dollars settled through the market

    # ------------------------------------------------------------------ #
    # jit'd compute
    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, state, embeds=None):
        return self.api.prefill(params, self.cfg, tokens, state, embeds=embeds)

    def _packed_prefill_impl(
        self, params, tokens, caches, q_pos, q_seg, q_rows, kv_pos, kv_seg, last_idx
    ):
        return self.api.prefill_packed(
            params, self.cfg, tokens, caches,
            q_pos=q_pos, q_seg=q_seg, q_rows=q_rows,
            kv_pos=kv_pos, kv_seg=kv_seg, last_idx=last_idx,
        )

    def _fused_prefill_impl(self, params, tokens, caches, q_pos, q_rows, kv_pos, last_idx):
        return self.api.prefill_fused(
            params, self.cfg, tokens, caches,
            q_pos=q_pos, q_rows=q_rows, kv_pos=kv_pos, last_idx=last_idx,
        )

    def _decode_impl(self, params, tokens, state, active):
        logits, new_state = self.api.decode(params, self.cfg, tokens, state)
        # inactive slots: freeze position (their cache row writes are masked
        # by pos-based validity on the next real request).
        pos = jnp.where(active, new_state.pos, state.pos)
        new_state = new_state._replace(pos=pos)
        return logits, new_state

    def _decode_paged_impl(self, params, tokens, caches, tables, pos):
        # positions/tables are host-managed (PagedSlots); freed slots carry
        # zeroed tables, routing their stale writes onto the dump block.
        return self.api.decode_paged(
            params, self.cfg, tokens, caches,
            block_table=tables, pos=pos, block=self.ec.kv_block,
        )

    def _chunked_prefill_impl(self, params, tokens, caches, tables, q_pos, last_idx):
        # the unified step's mixed launch: every row is a [C]-token window —
        # a prefill chunk, a decode token at index 0, or all padding.  All
        # shapes are static ([B, C] tokens, [B, nb] tables), so steady
        # unified serving compiles exactly once.
        return self.api.prefill_chunked(
            params, self.cfg, tokens, caches,
            block_table=tables, q_pos=q_pos, last_idx=last_idx,
            block=self.ec.kv_block,
        )

    # ------------------------------------------------------------------ #
    # Public API: submit / step / drain / run
    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.push(req)

    @property
    def idle(self) -> bool:
        """Nothing queued, nothing decoding, no prefill chunks in flight."""
        return (
            len(self.queue) == 0
            and not any(s.active for s in self.slots)
            and not self._chunks
        )

    def load(self) -> int:
        """Requests this replica currently owes work to (queued + in a slot,
        including slots mid-chunked-prefill) — the router's load signal."""
        return (
            len(self.queue)
            + sum(1 for s in self.slots if s.active)
            + len(self._chunks)
        )

    def free_capacity(self) -> int:
        """Slots not yet spoken for by queued or active requests (floor 0)."""
        return max(0, self.ec.max_slots - self.load())

    def step(self) -> List[ev.Event]:
        """Advance the engine by one scheduling step and return its events:
        admit every admissible request with a free slot as one packed batch
        (one ragged suffix-prefill launch), else run one batched decode step,
        else jump the clock to the next arrival.  A due migration pass
        (EngineConfig.migration_interval_s) piggybacks on the step and
        surfaces as TierMigrated events."""
        events = self._step()
        if self.telemetry is not None and events:
            self.telemetry.on_events(events, replica=self._replica)
        return events

    def _step(self) -> List[ev.Event]:
        if self._unified_on:
            return self._step_unified()
        events: List[ev.Event] = []
        self._run_migrations(events)
        if self._admit_batch(events):
            return events
        if any(s.active for s in self.slots):
            self._decode_step(events)
            return events
        nxt = self.queue.next_arrival()
        if nxt is None:
            return events  # fully drained
        self._advance_clock(nxt, events)
        return events

    def _advance_clock(self, to_s: float, events: List[ev.Event]) -> None:
        """Jump the idle clock to ``to_s``, stepping through every migration
        pass whose scheduled time falls inside the gap.  Each missed pass
        runs AT its own due time (the clock walks to each crossing before
        the final jump), so a diurnal idle gap accrues storage dollars and
        demotes cold entries on schedule — instead of collapsing all missed
        passes into one late one at the far edge of the gap."""
        if self.ec.migration_interval_s > 0 and self.store.migration is not None:
            while self._next_migration_s <= to_s:
                at = self._next_migration_s
                self.clock.at_least(at)
                self.store.run_migrations()
                self._next_migration_s = at + self.ec.migration_interval_s
                self._emit_migrations(events)
        self.clock.at_least(to_s)
        events.append(ev.ClockAdvanced(t_s=self.clock.now, req_id=-1, to_s=to_s))

    def drain(self) -> Iterator[ev.Event]:
        """Iterate events until every submitted request has finished."""
        while not self.idle:
            yield from self.step()

    def run(self) -> metrics_mod.ServingSummary:
        """Serve everything submitted; returns the summary."""
        for _ in self.drain():
            pass
        return self.summary()

    def summary(self) -> metrics_mod.ServingSummary:
        if self.telemetry is not None:
            # settle accrued GB-hours into the ledger at the same instant the
            # summary reads them, so the conservation check is exact
            self.telemetry.settle_engine(self, replica=self._replica)
        return metrics_mod.summarize(
            self.records,
            storage_cost=self.store.storage_cost(self.pricing),
            transfer_cost=self.transfer.transfer_fees(),
        )

    def _attr(self, activity: str, req_id: Optional[int] = None):
        """Attribution scope for transfer fees charged inside; a nullcontext
        when telemetry is off (the common case pays one ``is None``)."""
        if self.telemetry is None:
            return contextlib.nullcontext()
        return self.transfer.attributed(activity=activity, req_id=req_id)

    # ------------------------------------------------------------------ #
    # Tier migration (clock-driven economics pass)
    # ------------------------------------------------------------------ #
    def _run_migrations(self, events: List[ev.Event]) -> None:
        if (
            self.ec.migration_interval_s <= 0
            or self.store.migration is None
            or self.clock.now < self._next_migration_s
        ):
            return
        self.store.run_migrations()
        self._next_migration_s = self.clock.now + self.ec.migration_interval_s
        self._emit_migrations(events)

    def _emit_migrations(self, events: List[ev.Event]) -> None:
        """Surface store migrations (policy passes AND pressure spills) as
        typed events, stamped with the move's own SimClock time."""
        for m in self.store.drain_migrations():
            events.append(
                ev.TierMigrated(
                    t_s=m.t_s, req_id=-1, entry_id=m.entry_id,
                    from_tier=m.from_tier, to_tier=m.to_tier,
                    nbytes=m.nbytes, reason=m.reason,
                )
            )

    # ------------------------------------------------------------------ #
    # Admission: pop -> plan (per request) -> execute (one packed batch)
    # ------------------------------------------------------------------ #
    def _free_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.active]

    def _admit_batch(self, events: List[ev.Event]) -> bool:
        """Admit every admissible request with a free slot (up to
        ``admit_batch``): plan each individually, then execute all packable
        suffix-prefills as ONE packed ragged kernel launch.  Requests the
        packed path cannot carry (SSM/hybrid/enc-dec state, embeds, ring
        caches) fall back to the per-request path, one per step."""
        free = self._free_slots()
        if not free:
            return False
        limit = min(len(free), self.ec.admit_batch or self.ec.max_slots)
        reqs: List[Request] = []
        while len(reqs) < limit:
            nxt = self.queue.peek_next(self.clock.now)
            if nxt is None:
                break
            if not (self._packable and nxt.embeds is None):
                if reqs:
                    break  # pack what we have; the odd one waits a step
                req = self.queue.pop_admissible(self.clock.now)
                return self._admit_single(req, free[0], events)
            reqs.append(self.queue.pop_admissible(self.clock.now))
        if not reqs:
            return False

        # Plan sequentially, carrying each planned fetch's bytes forward so
        # batch-mate i's predicted queue wait sees mates 0..i-1 on the same
        # contended link — at execute time their reservations land in this
        # order at one shared instant, and the planner must price that.
        pending: Dict[str, List[float]] = {}
        admissions: List[_Admission] = []
        for req, slot in zip(reqs, free):
            a = self._plan_admission(req, slot, events, pending=pending)
            admissions.append(a)
            if a.plan.action == "fused":
                # pin every fusion source now: a batch-mate's write-back
                # could otherwise evict it before the fused fetch executes
                for eid in a.plan.fused.source_entries:
                    if eid in self.store.entries:
                        self.store.pin(eid)
                        a.pins.append(eid)
                # the fused fetches hit their tiers' links at the shared
                # admission instant too: later batch-mates must price them
                for tier, b in a.lookup.fused_bytes_by_tier.items():
                    pending.setdefault(tier, []).append(b)
            if a.plan.loads_kv and a.lookup.entry is not None:
                pending.setdefault(a.lookup.entry.tier, []).append(
                    self._entry_fetch_bytes(a.lookup.entry, a.plan.matched_tokens)
                )
        packed = [a for a in admissions if a.plan.action != "fused"]
        if packed:
            self._execute_packed(packed, events)
        for a in admissions:
            if a.plan.action == "fused":
                self._execute_fused(a, events)
        self._issue_prefetches()
        return True

    def _plan_admission(
        self,
        req: Request,
        slot: Slot,
        events: List[ev.Event],
        pending: Optional[Dict[str, List[float]]] = None,
    ):
        rec = RequestRecord(
            req_id=req.req_id,
            arrival_s=req.arrival_s,
            context_len=len(req.context_tokens),
            prompt_len=len(req.prompt_tokens),
            start_s=self.clock.now,
        )
        total_len = len(req.context_tokens) + len(req.prompt_tokens) + req.max_new_tokens
        assert total_len <= self.ec.max_len, (total_len, self.ec.max_len)
        events.append(
            ev.RequestAdmitted(
                t_s=self.clock.now, req_id=req.req_id, slot=slot.index,
                queue_s=rec.queue_s,
            )
        )
        lookup = self._lookup(req, pending)
        workload = Workload(
            L_context=len(req.context_tokens),
            L_prompt=len(req.prompt_tokens),
            L_output=req.max_new_tokens,
            N=max(int(req.expected_reuses), 1),
            slo_ttft_s=req.slo_ttft_s,
        )
        plan = self.planner.plan(req, lookup, workload)
        events.append(ev.PlanChosen(t_s=self.clock.now, req_id=req.req_id, plan=plan))
        return _Admission(req=req, rec=rec, slot=slot, plan=plan, lookup=lookup)

    def _finish_admission(
        self, a: "_Admission", first_tok: int, events: List[ev.Event]
    ) -> None:
        """Shared admission epilogue (post clock-advance): record fields that
        are common to both execute paths, emit the first token, activate."""
        a.rec.action = (
            a.plan.action if (a.plan.reuses_kv and not a.rec.degraded)
            else "recompute"
        )
        a.rec.plan = a.plan
        a.rec.tokens.append(first_tok)
        tok_ev = ev.TokenEmitted(
            t_s=self.clock.now, req_id=a.req.req_id, token=first_tok, index=0
        )
        events.append(tok_ev)
        if self.on_token is not None:
            self.on_token(tok_ev)
        a.slot.request = a.req
        a.slot.record = a.rec
        a.slot.generated = 1
        a.slot.last_token = first_tok
        a.slot.active = True
        self._maybe_finish(a.slot, events)

    # -- per-request (fallback) execution ------------------------------- #
    def _admit_single(self, req: Request, slot: Slot, events: List[ev.Event]) -> bool:
        a = self._plan_admission(req, slot, events)
        if a.plan.market is not None:
            self._market_fetch(a, events)
        elif a.plan.loads_kv and a.lookup.entry is not None:
            self._fetch_kv_resilient(a, events)
        if a.artifact is not None:
            load_s, prefill_s, logits, temp = self._execute_load(req, a, events)
            matched = a.matched
        else:
            # plain recompute, or a degraded fetch falling back to exact
            # recompute mid-admission — the burned fetch time rides on load_s
            # (a.delay is 0.0 on the plain path)
            load_s, matched = a.delay, 0
            prefill_s, logits, temp = self._execute_recompute(req, a.plan, events)
        self._release_prefetch(req.req_id)

        # ---- install into the batch slot ------------------------------- #
        if self._paged_on:
            self._land_state_in_pool(slot, temp)
        else:
            self._state = paged.insert_slot(self.cfg, self._state, slot.index, temp)
        first_tok = int(jnp.argmax(logits[0]))

        self.clock.advance(load_s + prefill_s)
        self.admission_busy_s += load_s + prefill_s
        a.rec.matched_tokens = matched
        a.rec.load_s = load_s
        a.rec.prefill_s = prefill_s
        a.rec.compute_cost += self._c_gpu_s * prefill_s
        self._finish_admission(a, first_tok, events)
        self._issue_prefetches()
        return True

    # -- packed batch execution ----------------------------------------- #
    def _execute_packed(
        self, admissions: List["_Admission"], events: List[ev.Event]
    ) -> None:
        """Execute a whole admission batch as one packed ragged suffix-prefill:
        per-request storage fetches (queueing on contended links is modeled at
        the shared admission instant), one kernel launch over the concatenated
        token runs, outputs scattered back into each request's batch slot."""
        t0 = self.clock.now
        for a in admissions:
            if a.plan.market is not None:
                self._market_fetch(a, events)
            elif a.plan.loads_kv and a.lookup.entry is not None:
                self._fetch_kv_resilient(a, events)
            self._release_prefetch(a.req.req_id)
            ctx = list(a.req.context_tokens)
            a.new_tokens = ctx[a.matched:] + list(a.req.prompt_tokens)

        layout = paged.pack_layout(
            [a.slot.index for a in admissions],
            [a.matched for a in admissions],
            [len(a.new_tokens) for a in admissions],
            align=self.ec.pack_align,
            bucket_min=self.ec.pack_bucket_min,
        )
        arrays = paged.pack_arrays(layout, [a.new_tokens for a in admissions])
        caches = paged.build_packed_caches(
            self.cfg, layout, [a.artifact for a in admissions]
        )
        last_idx = np.zeros((self.ec.max_slots,), np.int32)
        for i, seg in enumerate(layout.segments):
            last_idx[i] = seg.q_last
        jit_hit = self.jit_stats.record((layout.q_len, layout.kv_len))
        self.batches += 1
        self.packed_q_tokens += layout.q_tokens
        self.packed_q_len += layout.q_len
        events.append(
            ev.BatchAdmitted(
                t_s=t0, req_id=-1,
                req_ids=tuple(a.req.req_id for a in admissions),
                q_tokens=layout.q_tokens, q_len=layout.q_len,
                kv_len=layout.kv_len, jit_hit=jit_hit,
            )
        )

        logits, new_caches = self._jit_packed(
            self.params,
            jnp.asarray(arrays["tokens"]),
            caches,
            jnp.asarray(arrays["q_pos"]),
            jnp.asarray(arrays["q_seg"]),
            jnp.asarray(arrays["q_rows"]),
            jnp.asarray(arrays["kv_pos"]),
            jnp.asarray(arrays["kv_seg"]),
            jnp.asarray(last_idx),
        )

        lens = [len(a.new_tokens) for a in admissions]
        prefill_s = self.perf.t_prefill_packed(self.cost_cfg, lens)
        total_new = sum(lens)
        written = set()  # contexts written back within THIS batch (dedup:
        # several batch-mates recomputing the same context store it once)
        for a, seg in zip(admissions, layout.segments):
            if a.artifact is not None:
                a.load_s = (
                    max(0.0, a.delay - prefill_s) if self.ec.overlap_load else a.delay
                )
                # KVLoaded carries THIS request's own fetch remainder; the
                # batch-barrier wait it actually experiences lands on the
                # record below.
                events.append(
                    ev.KVLoaded(
                        t_s=t0, req_id=a.req.req_id,
                        tier=(
                            a.lookup.entry.tier
                            if a.lookup.entry is not None
                            else (a.plan.tier or "market")
                        ),
                        nbytes=a.nbytes, load_s=a.load_s,
                        matched_tokens=a.matched,
                    )
                )
            else:
                if a.rec.degraded:
                    # the burned fetch time still delays this request (and,
                    # through the batch barrier below, its batch-mates)
                    a.load_s = a.delay
                if a.plan.store_after and tuple(a.req.context_tokens) not in written:
                    written.add(tuple(a.req.context_tokens))
                    ctx_len = len(a.req.context_tokens)
                    art = paged.packed_to_artifact(self.cfg, new_caches, seg, ctx_len)
                    self._write_back(
                        a.req, jax.tree_util.tree_map(np.asarray, art), events
                    )
            events.append(
                ev.PrefillDone(
                    t_s=t0, req_id=a.req.req_id,
                    n_tokens=len(a.new_tokens), prefill_s=prefill_s,
                )
            )

        batch_load = max((a.load_s for a in admissions), default=0.0)
        self.clock.advance(batch_load + prefill_s)
        self.admission_busy_s += batch_load + prefill_s

        if self._paged_on:
            # packed outputs land DIRECTLY in the shared block pool: one
            # scatter for the whole batch, no per-slot re-materialization.
            self._land_packed_in_pool(admissions, layout, new_caches)
        for i, (a, seg) in enumerate(zip(admissions, layout.segments)):
            if not self._paged_on:
                self._state = paged.insert_slot(
                    self.cfg, self._state, seg.slot,
                    paged.packed_to_artifact(self.cfg, new_caches, seg, seg.n_total),
                )
            a.rec.matched_tokens = a.matched
            # every batch member waits the load BARRIER (max of the batch's
            # fetches) before the shared kernel: record the realized wait so
            # ttft_s agrees with the TokenEmitted timeline and the SLO audit
            a.rec.load_s = batch_load
            a.rec.prefill_s = prefill_s
            a.rec.compute_cost += (
                self._c_gpu_s * prefill_s * (len(a.new_tokens) / total_new)
            )
            self._finish_admission(a, int(jnp.argmax(logits[i])), events)

    # -- fused (chunk-composite) execution ------------------------------ #
    def _execute_fused(self, a: "_Admission", events: List[ev.Event]) -> None:
        """Execute a ``"fused"`` plan: fetch each source entry's matched
        rows (fetches issue concurrently — the request waits the slowest),
        assemble one query-ordered KV buffer with the reused spans preloaded
        (K delta-RoPE'd to its target position), run ONE selective-recompute
        launch over just the recompute spans + prompt, and land the full
        context+prompt state in the slot (block pool or dense).  At
        ``recompute_frac=1.0`` this is bit-identical to a full recompute
        admission (tests/test_fusion.py)."""
        t0 = self.clock.now
        req, schedule = a.req, a.plan.fused
        ctx, prompt = list(req.context_tokens), list(req.prompt_tokens)

        out = self._fetch_fused_sources(a, events)
        if out is None:
            # one lost source spoils the composite: the whole fused
            # admission degrades to exact recompute (time already burned
            # on earlier sources rides along, on a.delay)
            self._degrade_fused(a, events)
            return
        sources, fetched = out
        delays = [d for _, _, d, _ in fetched]

        layout = fusion.fused_layout(
            schedule, len(prompt),
            align=self.ec.pack_align, bucket_min=self.ec.pack_bucket_min,
        )
        caches = fusion.build_fused_caches(
            self.cfg, schedule, sources, layout.kv_len
        )
        arrays = fusion.fused_arrays(schedule, ctx, prompt, layout)
        jit_hit = self.fused_jit.record((layout.q_len, layout.kv_len))
        logits, new_caches = self._jit_fused(
            self.params,
            jnp.asarray(arrays["tokens"]),
            caches,
            jnp.asarray(arrays["q_pos"]),
            jnp.asarray(arrays["q_rows"]),
            jnp.asarray(arrays["kv_pos"]),
            jnp.asarray(arrays["last_idx"]),
        )

        prefill_s = self.perf.t_prefill_fused(
            self.cost_cfg, layout.total, layout.n_q
        )
        load_s = max(delays, default=0.0)
        if self.ec.overlap_load:
            load_s = max(0.0, load_s - prefill_s)
        for tier, nbytes, delay, rows in fetched:
            # like the prefix-load path, each KVLoaded carries the delay
            # charged post-overlap, not the raw link time
            events.append(
                ev.KVLoaded(
                    t_s=t0, req_id=req.req_id, tier=tier, nbytes=nbytes,
                    load_s=(
                        max(0.0, delay - prefill_s)
                        if self.ec.overlap_load else delay
                    ),
                    matched_tokens=rows,
                )
            )
        events.append(
            ev.FusedAdmitted(
                t_s=t0, req_id=req.req_id, slot=a.slot.index,
                reused_tokens=schedule.reused_tokens,
                recompute_tokens=schedule.recompute_tokens,
                n_spans=len(schedule.spans), n_sources=len(sources),
                q_len=layout.q_len, kv_len=layout.kv_len, jit_hit=jit_hit,
            )
        )
        events.append(
            ev.PrefillDone(
                t_s=t0, req_id=req.req_id,
                n_tokens=layout.n_q, prefill_s=prefill_s,
            )
        )

        # land the assembled+recomputed state: rows [0, total) ARE the
        # context+prompt state in sequence order.  The artifact carries
        # whole-kv_block row coverage (the pool landing copies whole blocks)
        # while pos stays the true token count.
        seg = paged.PackSegment(
            slot=a.slot.index, kv_start=0, q_start=0,
            matched=schedule.reused_tokens, n_new=layout.n_q,
            n_total=layout.total,
        )
        n_rows = -(-layout.total // self.ec.kv_block) * self.ec.kv_block
        art = paged.packed_to_artifact(
            self.cfg, new_caches, seg, min(n_rows, layout.kv_len)
        )._replace(pos=jnp.full((1,), layout.total, jnp.int32))
        if self._paged_on:
            self._land_state_in_pool(a.slot, art)
        else:
            self._state = paged.insert_slot(
                self.cfg, self._state, a.slot.index, art
            )

        self.clock.advance(load_s + prefill_s)
        self.admission_busy_s += load_s + prefill_s
        self.fused_busy_s += load_s + prefill_s
        self.fused_admissions += 1
        self.fused_reused_tokens += schedule.reused_tokens
        self.fused_recompute_tokens += schedule.recompute_tokens
        self.fused_sources += len(sources)
        a.rec.matched_tokens = schedule.reused_tokens
        a.rec.load_s = load_s
        a.rec.prefill_s = prefill_s
        a.rec.compute_cost += self._c_gpu_s * prefill_s
        self._finish_admission(a, int(jnp.argmax(logits[0])), events)

    def _fetch_fused_sources(self, a: "_Admission", events: List[ev.Event]):
        """Fetch every fused source entry's matched rows (pinned at plan
        time) under the retry policy.  On success returns ``(sources,
        fetched)`` — ``sources[entry_id]`` the artifact, ``fetched`` one
        (tier, nbytes, delay_s, rows) tuple per source — with pins and the
        prefetch released.  On exhaustion of any source, degrades the
        admission in place (record marked, DegradedToRecompute emitted, the
        burned time left on ``a.delay``) and returns None: the caller falls
        back to exact recompute, so tokens match the fault-free run."""
        req, schedule = a.req, a.plan.fused
        sources: Dict[str, Any] = {}
        fetched: List[tuple] = []  # (tier, nbytes, delay, rows) per source
        wasted_total = 0.0
        for eid, rows in schedule.rows_by_entry().items():
            e = self.store.entries[eid]  # pinned at plan time: must exist
            nbytes = self._entry_fetch_bytes(e, rows)
            override = nbytes if self.cost_cfg is not self.cfg else None

            def attempt(activity, eid=eid, e=e, rows=rows, override=override):
                with self._attr(activity, req.req_id):
                    return self.store.fetch(
                        eid, fraction=rows / max(e.n_tokens, 1), nbytes=override
                    )

            out, wasted, attempts = self._retry_fetch(
                req, tier=e.tier, entry_id=eid, matched=rows, nbytes=nbytes,
                attempt_fn=attempt, events=events,
            )
            wasted_total += wasted
            if out is None:
                for pid in a.pins:
                    self.store.unpin(pid)
                a.pins.clear()
                self._release_prefetch(req.req_id)
                self.degraded_requests += 1
                a.rec.degraded = True
                a.delay = wasted_total
                events.append(ev.DegradedToRecompute(
                    t_s=self.clock.now, req_id=req.req_id, tier=e.tier,
                    entry_id=eid, attempts=attempts, wasted_s=wasted_total,
                    reason="fused_source_failed",
                ))
                return None
            art, delay = out
            sources[eid] = art
            fetched.append((e.tier, nbytes, wasted + delay, rows))
        for eid in a.pins:
            self.store.unpin(eid)
        a.pins.clear()
        self._release_prefetch(req.req_id)
        return sources, fetched

    def _degrade_fused(self, a: "_Admission", events: List[ev.Event]) -> None:
        """A fused source fetch exhausted its retries (record already marked
        by ``_fetch_fused_sources``, burned time on ``a.delay``): run the
        request as one exact full recompute (tokens unchanged — recompute is
        the ground truth the fusion approximates from)."""
        req, wasted_s = a.req, a.delay
        prefill_s, logits, temp = self._execute_recompute(req, a.plan, events)
        if self._paged_on:
            self._land_state_in_pool(a.slot, temp)
        else:
            self._state = paged.insert_slot(
                self.cfg, self._state, a.slot.index, temp
            )
        self.clock.advance(wasted_s + prefill_s)
        self.admission_busy_s += wasted_s + prefill_s
        a.rec.matched_tokens = 0
        a.rec.load_s = wasted_s
        a.rec.prefill_s = prefill_s
        a.rec.compute_cost += self._c_gpu_s * prefill_s
        self._finish_admission(a, int(jnp.argmax(logits[0])), events)

    # -- shared-block-pool landings (paged decode) ---------------------- #
    def _pool_update(self, dst: np.ndarray, sources) -> None:
        """Land KV rows at pool rows ``dst``: ``sources`` yields one
        (k_rows, v_rows) pair per layer kind, aligned with the pool caches —
        the single scatter shared by every landing path."""
        self._pool_caches = tuple(
            paged.BlockCache(
                paged.KVCache(
                    pc.attn.k.at[:, dst].set(ks), pc.attn.v.at[:, dst].set(vs)
                ),
                None,
            )
            for pc, (ks, vs) in zip(self._pool_caches, sources)
        )

    def _land_packed_in_pool(
        self, admissions: List["_Admission"], layout: paged.PackLayout, new_caches
    ) -> None:
        """Move every segment's kv span from the packed buffers into the
        shared block pool.  Segments are kv_block-aligned (pack_align ==
        kv_block), so a span IS a run of whole blocks: the whole batch lands
        as ONE device scatter per layer kind.  Batch-mates that loaded the
        same stored entry point their table prefixes at one refcounted copy
        of its full blocks (the write-back dedup, carried into the pool);
        only each segment's own blocks are copied."""
        block = self.ec.kv_block
        src_blocks: List[int] = []
        dst_blocks: List[int] = []
        leaders: Dict[str, tuple] = {}  # entry_id -> (slot, matched)
        for a, seg in zip(admissions, layout.segments):
            shared_from, shared = None, 0
            if a.artifact is not None and a.lookup.entry is not None:
                led = leaders.get(a.lookup.entry.entry_id)
                if led is not None:
                    shared_from, led_matched = led
                    # a block is shareable iff BOTH mates' reused prefixes
                    # cover it fully; the boundary block stays private (the
                    # copy-on-write line at the shared-suffix boundary)
                    shared = min(a.matched, led_matched) // block
                else:
                    leaders[a.lookup.entry.entry_id] = (seg.slot, a.matched)
            own = self._paged.admit(
                seg.slot, seg.n_total, shared_from=shared_from,
                shared_blocks=shared,
            )
            first = seg.kv_start // block
            for j, bid in enumerate(own, start=shared):
                src_blocks.append(first + j)
                dst_blocks.append(bid)
        src = paged.block_rows(src_blocks, block)
        dst = paged.block_rows(dst_blocks, block)
        self._pool_update(
            dst, ((nc.attn.k[:, 0, src], nc.attn.v[:, 0, src]) for nc in new_caches)
        )

    def _land_state_in_pool(self, slot: Slot, temp) -> None:
        """Per-request fallback admissions (embeds) under paged decode: copy
        the freshly prefilled batch-1 state's rows into newly allocated pool
        blocks (the single-segment analogue of ``_land_packed_in_pool``)."""
        block = self.ec.kv_block
        n_total = int(np.asarray(temp.pos)[0])
        own = self._paged.admit(slot.index, n_total)
        dst = paged.block_rows(own, block)
        n_rows = len(own) * block  # <= max_len (max_len % kv_block == 0)
        self._pool_update(
            dst,
            (
                (tc.attn.k[:, 0, :n_rows], tc.attn.v[:, 0, :n_rows])
                for tc in temp.caches
            ),
        )

    def _copy_pool_blocks(self, splits: List[paged.CowSplit]) -> None:
        """Copy-on-write: duplicate shared boundary blocks onto private ones
        before a decode write touches them (one gather/scatter pair)."""
        block = self.ec.kv_block
        src = paged.block_rows([s.src for s in splits], block)
        dst = paged.block_rows([s.dst for s in splits], block)
        self._pool_update(
            dst,
            ((pc.attn.k[:, src], pc.attn.v[:, src]) for pc in self._pool_caches),
        )

    def _fetch_kv(self, req: Request, plan: ReusePlan, lookup: StoreLookup,
                  activity: str = "fetch"):
        """Charge + execute the storage fetch of a load/partial plan; returns
        (artifact, delay_s, billed_nbytes).  A lookahead prefetch already in
        flight shrinks the delay to its unfinished remainder.  ``activity``
        tags the ledger attribution ("fetch_retry" on re-issued attempts, so
        retry dollars are separable)."""
        entry = lookup.entry
        matched = plan.matched_tokens
        nbytes = plan.fetch_bytes
        override = None
        if self.cost_cfg is not self.cfg:
            # economics-at-scale: charge the FULL arch's KV bytes, and occupy
            # the tier's link for them — queueing under burst (concurrency-
            # limited backends) is modeled at the same scale as the delay.
            nbytes = self._entry_fetch_bytes(entry, matched)
            override = nbytes
        with self._attr(activity, req.req_id):
            artifact, delay = self.store.fetch(
                entry.entry_id, fraction=matched / entry.n_tokens, nbytes=override
            )
        ready = self._prefetch_ready.pop(req.req_id, None)
        if ready is not None:
            # fetch was issued while earlier requests were being served:
            # only the unfinished remainder delays this request.
            delay = max(0.0, min(delay, ready - self.clock.now))
        return artifact, delay, nbytes

    # -- failure handling: cost-aware retry + graceful degradation -------- #
    def _retry_fetch(self, req: Request, *, tier: str, entry_id: str,
                     matched: int, nbytes: float, attempt_fn,
                     events: List[ev.Event]):
        """Run one storage fetch (``attempt_fn(activity)``) under the
        cost-aware retry policy.  Returns (result | None, wasted_s, attempts):
        result is whatever ``attempt_fn`` returned on success; None means
        every attempt failed (or retrying stopped beating recompute) and the
        caller must degrade.  ``wasted_s`` accumulates the failed attempts'
        charged delays plus backoff waits; the dollars those attempts burned
        were already charged to the transfer model when their bytes moved."""
        policy = self.retry_policy
        wasted = 0.0
        attempt = 0
        while True:
            attempt += 1
            try:
                out = attempt_fn("fetch" if attempt == 1 else "fetch_retry")
                return out, wasted, attempt
            except StorageError as exc:
                wasted += exc.delay_s
                self.fetch_failures += 1
                self.fetch_wasted_s += exc.delay_s
                self.fetch_wasted_bytes += exc.wasted_bytes
                events.append(ev.FetchFailed(
                    t_s=self.clock.now, req_id=req.req_id, tier=tier,
                    entry_id=entry_id, attempt=attempt, reason=exc.reason,
                    wasted_s=exc.delay_s, wasted_bytes=exc.wasted_bytes,
                ))
                if self.telemetry is not None:
                    # zero-$ marker: the wasted transfer dollars themselves
                    # were charged (stats AND ledger) when the bytes moved,
                    # so conservation already covers them — this entry makes
                    # the waste queryable per request/tier
                    self.telemetry.ledger.add(
                        "transfer", "fetch_failed", 0.0,
                        replica=self._replica, req_id=req.req_id,
                        tier=tier, nbytes=exc.wasted_bytes, kind="load",
                    )
                backoff = policy.backoff(attempt)
                retry_cost = policy.retry_cost(
                    backoff_s=backoff,
                    est_load_s=self.store.estimate_load_delay(tier, nbytes),
                    nbytes=nbytes,
                    gpu_cost_per_s=self._c_gpu_s,
                    per_gb_fee=self.pricing.tier(tier).per_gb_transfer_fee,
                )
                recompute_cost = self._c_gpu_s * self.perf.t_prefill(
                    self.cost_cfg, max(matched, 1)
                )
                if policy.should_retry(exc, attempt, tier=tier,
                                       retry_cost=retry_cost,
                                       recompute_cost=recompute_cost):
                    wasted += backoff
                    self.fetch_wasted_s += backoff
                    self.fetch_retries += 1
                    events.append(ev.FetchRetried(
                        t_s=self.clock.now, req_id=req.req_id, tier=tier,
                        entry_id=entry_id, attempt=attempt + 1,
                        backoff_s=backoff,
                    ))
                    continue
                return None, wasted, attempt

    def _fetch_kv_resilient(self, a: "_Admission", events: List[ev.Event]) -> None:
        """Execute a load/partial plan's fetch with retries.  On success
        fills ``a.artifact/delay/nbytes/matched`` (wasted time from failed
        attempts folded into the delay); on exhaustion leaves ``a.artifact``
        None with the wasted time on ``a.delay`` and marks the record
        degraded — the caller falls back to exact recompute, so tokens are
        bit-identical to the fault-free run."""
        req, plan, entry = a.req, a.plan, a.lookup.entry
        nbytes = plan.fetch_bytes
        if self.cost_cfg is not self.cfg:
            nbytes = self._entry_fetch_bytes(entry, plan.matched_tokens)
        out, wasted, attempts = self._retry_fetch(
            req, tier=entry.tier, entry_id=entry.entry_id,
            matched=plan.matched_tokens, nbytes=nbytes,
            attempt_fn=lambda activity: self._fetch_kv(
                req, plan, a.lookup, activity=activity
            ),
            events=events,
        )
        if out is None:
            self.degraded_requests += 1
            a.rec.degraded = True
            a.artifact, a.nbytes, a.matched = None, 0.0, 0
            a.delay = wasted
            events.append(ev.DegradedToRecompute(
                t_s=self.clock.now, req_id=req.req_id, tier=entry.tier,
                entry_id=entry.entry_id, attempts=attempts, wasted_s=wasted,
                reason="fetch_exhausted",
            ))
            return
        artifact, delay, billed = out
        a.artifact, a.nbytes = artifact, billed
        a.delay = wasted + delay
        a.matched = plan.matched_tokens

    # -- marketplace: purchased KV --------------------------------------- #
    def _market_fetch(self, a: "_Admission", events: List[ev.Event]) -> None:
        """Execute a purchased plan (``ReusePlan.market``): delivery,
        verification, and settlement run inside the marketplace; on success
        a full-entry purchase is absorbed into this engine's own store so
        repeat requests become local hits; on ANY failure (seller gone,
        fetch error, failed verification) the request degrades to exact
        recompute — tokens stay bit-identical either way."""
        req, quote = a.req, a.plan.market
        res = self.market.execute(
            quote, req_id=req.req_id, now=self.clock.now,
            context_tokens=req.context_tokens, replica=self._replica,
        )
        events.extend(res.events)
        # the spot check ran on THIS engine's device: its GPU seconds are
        # real compute this request caused, charged win or lose
        a.rec.compute_cost += res.verify_cost
        if not res.ok:
            self.degraded_requests += 1
            self.market_failed += 1
            a.rec.degraded = True
            a.artifact, a.nbytes, a.matched = None, 0.0, 0
            a.delay = res.wasted_s
            events.append(ev.DegradedToRecompute(
                t_s=self.clock.now, req_id=req.req_id, tier=a.plan.tier,
                entry_id=quote.entry_id, attempts=1, wasted_s=res.wasted_s,
                reason=f"market:{res.reason}",
            ))
            return
        a.artifact = res.artifact
        a.nbytes = res.nbytes
        a.matched = res.matched_tokens
        a.delay = res.delay_s + res.verify_s
        self.market_purchases += 1
        self.market_spend += res.price
        if self.ec.store_write_back and res.matched_tokens >= quote.n_tokens:
            # full-entry purchase: absorb it locally (the artifact's rows
            # cover exactly the matched prefix, so the stored identity is
            # sound); partial matches are served but not stored
            ctx = list(req.context_tokens[:res.matched_tokens])
            saved = self._c_gpu_s * self.perf.t_prefill(self.cost_cfg, len(ctx))
            with self._attr("market_absorb", req.req_id):
                entry_id, _ = self.store.put(
                    ctx, res.artifact, tier=self._store_tier(),
                    saved_per_use=saved,
                )
            h = self.store.last_put_handle if entry_id is not None else None
            if h is not None and h.dedup:
                # the absorbed copy deduped against bytes already in the
                # shared core: book the zero-dollar KVShare credit for the
                # bytes the core did NOT have to duplicate
                self.market.note_dedup(
                    self.store.entries[entry_id].nbytes,
                    req_id=req.req_id, replica=self._replica,
                )
            self._emit_migrations(events)
            if entry_id is not None:
                e = self.store.entries[entry_id]
                events.append(ev.StoreWriteBack(
                    t_s=self.clock.now, req_id=req.req_id,
                    entry_id=entry_id, tier=e.tier, nbytes=e.nbytes,
                ))

    def market_spot_check(self, context_tokens, artifact, n_tokens: int):
        """Bit-exactness oracle for purchased KV: prefill the first
        ``n_tokens`` of the context fresh and compare the purchased rows
        exactly (both sides canonicalized through the same slot layout).
        Returns (ok, verify_s, verify_cost) — the sample prefill's modeled
        GPU seconds and dollars, which the caller charges to the request."""
        n = int(min(n_tokens, len(context_tokens)))
        if n <= 0:
            return True, 0.0, 0.0
        tokens = jnp.asarray([list(context_tokens[:n])], jnp.int32)
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        _, fresh = self._jit_prefill(self.params, tokens, temp)
        ref = paged.extract_slot(self.cfg, fresh, 0, n)
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        temp = paged.insert_slot(self.cfg, temp, 0, artifact, n_tokens=n)
        got = paged.extract_slot(self.cfg, temp, 0, n)
        ok = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(
                jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)
            )
        )
        verify_s = self.perf.t_prefill(self.cost_cfg, n)
        return bool(ok), verify_s, self._c_gpu_s * verify_s

    def _write_back(self, req: Request, artifact: Any, events: List[ev.Event]) -> None:
        ctx = list(req.context_tokens)
        saved = self._c_gpu_s * self.perf.t_prefill(self.cost_cfg, len(ctx))
        with self._attr("write_back", req.req_id):
            entry_id, _ = self.store.put(
                ctx, artifact, tier=self._store_tier(), saved_per_use=saved
            )
        h = self.store.last_put_handle if entry_id is not None else None
        if h is not None and h.dedup and self.market is not None:
            # KVShare multi-tenant dedup: another tenant already holds these
            # exact bytes in the shared core — settle the skipped upload as
            # a zero-dollar market credit carrying the SAVED bytes (the
            # handle's nbytes is bytes moved, which a dedup makes zero)
            self.market.note_dedup(
                self.store.entries[entry_id].nbytes,
                req_id=req.req_id, replica=self._replica,
            )
        if self.telemetry is not None and h is not None and h.dedup:
            # a content-addressed shared tier already held these bytes: no
            # upload happened, no fee accrued — record the dedup'd write-back
            # as an explicit zero-$ entry so the saving is visible per request
            self.telemetry.ledger.add(
                "transfer", "write_back_dedup", 0.0,
                replica=self._replica, req_id=req.req_id,
                tier=h.tier, nbytes=0.0, kind="store",
            )
        # capacity-pressure spills triggered by this put surface now, at
        # their own timestamp, not at the next step's drain
        self._emit_migrations(events)
        if entry_id is not None:
            e = self.store.entries[entry_id]
            events.append(
                ev.StoreWriteBack(
                    t_s=self.clock.now, req_id=req.req_id,
                    entry_id=entry_id, tier=e.tier, nbytes=e.nbytes,
                )
            )

    def _lookup(
        self, req: Request, pending: Optional[Dict[str, List[float]]] = None
    ) -> StoreLookup:
        """Consult the store about the request's context; quantify how much of
        it the architecture can actually consume.  A lookup already walked by
        the prefetch pass is carried forward (no second trie walk) as long as
        the store's trie has not mutated since.  ``pending`` — per-tier fetch
        bytes already planned by earlier batch-mates this admission instant,
        folded into the predicted queue wait."""
        if not self.ec.reuse_enabled:
            return StoreLookup.miss()
        cached = self._prefetch_lookup.pop(req.req_id, None)
        if cached is not None and cached[2] == self.store.trie_version:
            match = cached[0]
            entry = self.store.entries.get(cached[1]) if cached[1] else None
            self.lookup_reuses += 1
        else:
            match, entry = self.store.lookup(list(req.context_tokens))
            self.lookup_walks += 1
        partial_ok = paged.partial_reuse_allowed(self.cfg) and req.embeds is None
        unavailable = frozenset(
            t for t in self.store.tier_order
            if self.ec.faults is not None
            and self.ec.faults.browned_out(t, self.clock.now)
        )
        frac = 0.0
        n_ctx = len(req.context_tokens)
        if entry is not None and match.matched_tokens > 0:
            if match.matched_tokens >= n_ctx:
                frac = 1.0
            elif partial_ok:
                frac = match.matched_tokens / n_ctx
        queue_wait: Dict[str, float] = {}
        if entry is not None and frac > 0:
            # contended-link visibility for the planner: predicted queueing
            # delay on the entry's tier (0 on uncontended links)
            ahead = () if pending is None else tuple(pending.get(entry.tier, ()))
            wait = self.store.estimated_queue_wait(
                entry.tier,
                self._entry_fetch_bytes(entry, match.matched_tokens),
                pending=ahead,
            )
            if wait > 0:
                queue_wait[entry.tier] = wait
        composite = None
        fused_bytes: Dict[str, float] = {}
        if self._fusion_on and req.embeds is None and frac < 1.0:
            comp = self.store.lookup_composite(list(req.context_tokens))
            if comp.matched_tokens > 0 and not any(
                (e := self.store.entries.get(eid)) is not None
                and e.tier in unavailable
                for eid in comp.rows_by_entry()
            ):
                # a composite touching a browned-out tier is unplannable —
                # one dead source spoils the whole assembly
                composite = comp
                for eid, rows in comp.rows_by_entry().items():
                    src = self.store.entries.get(eid)
                    if src is None:
                        continue
                    fused_bytes[src.tier] = fused_bytes.get(src.tier, 0.0) + (
                        self._entry_fetch_bytes(src, rows)
                    )
                for t, b in fused_bytes.items():
                    # contended-link visibility for the fused option (and
                    # batch-mates planned behind it): predicted queueing
                    # delay for this tier's fused fetch
                    ahead = () if pending is None else tuple(pending.get(t, ()))
                    wait = self.store.estimated_queue_wait(t, b, pending=ahead)
                    if wait > 0:
                        queue_wait[t] = max(queue_wait.get(t, 0.0), wait)
        return StoreLookup(
            match=match, entry=entry, fraction=frac, partial_ok=partial_ok,
            queue_wait_s=queue_wait, composite=composite,
            fused_bytes_by_tier=fused_bytes, unavailable_tiers=unavailable,
        )

    def _entry_fetch_bytes(self, e, matched_tokens: int) -> float:
        """Bytes a fetch of ``matched_tokens`` moves, at economics scale."""
        if self.cost_cfg is not self.cfg:
            return s_storage_bytes(
                self.cost_cfg, matched_tokens,
                compression=0.5 if self.ec.compress_tier == e.tier else 1.0,
            )
        return e.nbytes * matched_tokens / max(e.n_tokens, 1)

    # ------------------------------------------------------------------ #
    # Execute: the two plan interpretations
    # ------------------------------------------------------------------ #
    def _execute_load(
        self, req: Request, a: "_Admission", events: List[ev.Event]
    ):
        """Insert the already-fetched stored context state (see
        ``_fetch_kv_resilient``), prefill only the unmatched tail + prompt."""
        entry = a.lookup.entry
        matched = a.matched
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)
        temp = paged.insert_slot(self.cfg, temp, 0, a.artifact, n_tokens=matched)
        ctx = list(req.context_tokens)
        tail = [] if req.embeds is not None else ctx[matched:]
        tokens = jnp.asarray([tail + list(req.prompt_tokens)], jnp.int32)
        logits, temp = self._jit_prefill(self.params, tokens, temp)
        prefill_s = self.perf.t_prefill(
            self.cost_cfg, len(tail) + len(req.prompt_tokens)
        )
        if self.ec.overlap_load:
            load_s = max(0.0, a.delay - prefill_s)
        else:
            load_s = a.delay
        events.append(
            ev.KVLoaded(
                t_s=self.clock.now, req_id=req.req_id,
                tier=(
                    entry.tier if entry is not None
                    else (a.plan.tier or "market")
                ),
                nbytes=a.nbytes, load_s=load_s, matched_tokens=matched,
            )
        )
        events.append(
            ev.PrefillDone(
                t_s=self.clock.now, req_id=req.req_id,
                n_tokens=len(tail) + len(req.prompt_tokens), prefill_s=prefill_s,
            )
        )
        return load_s, prefill_s, logits, temp

    def _execute_recompute(
        self, req: Request, plan: ReusePlan, events: List[ev.Event]
    ):
        """Full prefill; write the context state back iff the plan says so."""
        ctx, prompt = list(req.context_tokens), list(req.prompt_tokens)
        temp = self.api.init_state(self.cfg, 1, self.ec.max_len)

        def write_back(artifact):
            self._write_back(req, artifact, events)

        if req.embeds is not None:
            # VLM/audio context: the context IS the embeddings. Single
            # phase — positions [0, ctx) of the state depend only on the
            # embeds, so the artifact is extractable post-hoc.
            tokens = jnp.asarray([prompt], jnp.int32)
            logits, temp = self._jit_prefill(
                self.params, tokens, temp, embeds=req.embeds
            )
            if plan.store_after:
                write_back(paged.extract_slot(self.cfg, temp, 0, len(ctx)))
        elif plan.store_after:
            # Two-phase: context-only prefill -> snapshot (valid for SSM
            # state, which must not include prompt tokens) -> prompt.
            ctx_tokens = jnp.asarray([ctx], jnp.int32)
            _, temp = self._jit_prefill(self.params, ctx_tokens, temp)
            write_back(paged.extract_slot(self.cfg, temp, 0, len(ctx)))
            tokens = jnp.asarray([prompt], jnp.int32)
            logits, temp = self._jit_prefill(self.params, tokens, temp)
        else:
            tokens = jnp.asarray([ctx + prompt], jnp.int32)
            logits, temp = self._jit_prefill(self.params, tokens, temp)
        prefill_s = self.perf.t_prefill(self.cost_cfg, len(ctx) + len(prompt))
        events.append(
            ev.PrefillDone(
                t_s=self.clock.now, req_id=req.req_id,
                n_tokens=len(ctx) + len(prompt), prefill_s=prefill_s,
            )
        )
        return prefill_s, logits, temp

    def _issue_prefetches(self) -> None:
        """Lookahead: start storage fetches for queued requests whose contexts
        are stored (the fetch streams while the engine computes)."""
        if self.ec.prefetch_lookahead <= 0 or not self.ec.reuse_enabled:
            return
        for nxt in self.queue.peek_arrived(self.clock.now, self.ec.prefetch_lookahead):
            if nxt.req_id in self._prefetch_ready:
                continue
            cached = self._prefetch_lookup.get(nxt.req_id)
            if cached is not None and cached[2] == self.store.trie_version:
                # an earlier pass already walked this context and the trie has
                # not mutated since — necessarily a miss (hits sit in
                # _prefetch_ready above), so there is nothing new to fetch
                continue
            m, e = self.store.lookup(list(nxt.context_tokens))
            self.lookup_walks += 1
            # carry this walk forward to admission (hits AND misses): the
            # admission-time lookup reuses it unless the trie mutated since
            self._prefetch_lookup[nxt.req_id] = (
                m, e.entry_id if e is not None else None, self.store.trie_version
            )
            if e is None or m.matched_tokens == 0:
                continue
            nbytes = self._entry_fetch_bytes(e, m.matched_tokens)
            delay = self.store.estimate_load_delay(e.tier, nbytes)
            self._prefetch_ready[nxt.req_id] = self.clock.now + delay
            # pin until admission consumes or abandons the prefetch: eviction
            # pressure (another request's write-back) and demotion must not
            # invalidate an in-flight fetch (ROADMAP prefetch/eviction race)
            self.store.pin(e.entry_id)
            self._prefetch_pins[nxt.req_id] = e.entry_id

    def _release_prefetch(self, req_id: int) -> None:
        """Admission consumed (or abandoned) this request's prefetch: drop the
        ready-time record and release the eviction pin."""
        self._prefetch_ready.pop(req_id, None)
        self._prefetch_lookup.pop(req_id, None)
        entry_id = self._prefetch_pins.pop(req_id, None)
        if entry_id is not None:
            self.store.unpin(entry_id)

    def packed_stats(self) -> Dict[str, Any]:
        """Packed-admission counters: jit bucket hit/miss, packing occupancy,
        trie-walk savings, and modeled admission busy time (the denominator
        of admission throughput)."""
        return {
            "jit": self.jit_stats.as_dict(),
            "batches": self.batches,
            "packed_q_tokens": self.packed_q_tokens,
            "packed_q_len": self.packed_q_len,
            "occupancy": self.packed_q_tokens / max(self.packed_q_len, 1),
            "lookup_walks": self.lookup_walks,
            "lookup_reuses": self.lookup_reuses,
            "admission_busy_s": self.admission_busy_s,
        }

    def decode_stats(self) -> Dict[str, Any]:
        """Decode-side counters: modeled decode busy time (the denominator of
        decode throughput), tokens decoded, and — under paged decode — block
        pool occupancy and cross-slot shared-block savings."""
        out: Dict[str, Any] = {
            "paged": self._paged_on,
            "decode_busy_s": self.decode_busy_s,
            "decode_tokens": self.decode_tokens,
        }
        if self._paged_on:
            ps = self._paged.stats()
            out.update(kv_block=ps.pop("block"), **ps)
        return out

    def fused_stats(self) -> Dict[str, Any]:
        """Fusion-path counters: fused admissions, reused-vs-recomputed
        context tokens (the realized CacheBlend ratio), distinct source
        entries fetched, modeled fused busy time, and the fused launch's own
        jit bucket hit/miss split."""
        return {
            "enabled": self._fusion_on,
            "admissions": self.fused_admissions,
            "reused_tokens": self.fused_reused_tokens,
            "recompute_tokens": self.fused_recompute_tokens,
            "sources": self.fused_sources,
            "busy_s": self.fused_busy_s,
            "jit": self.fused_jit.as_dict(),
        }

    def fault_stats(self) -> Dict[str, Any]:
        """Failure-handling counters: failed/retried fetch attempts, requests
        degraded to recompute, burned fetch time/bytes, store-side rollbacks
        and discards, plus the injector's own tally when one is wired."""
        out = {
            "fetch_failures": self.fetch_failures,
            "fetch_retries": self.fetch_retries,
            "degraded_requests": self.degraded_requests,
            "fetch_wasted_s": self.fetch_wasted_s,
            "fetch_wasted_bytes": self.fetch_wasted_bytes,
            "failed_puts": self.store.failed_puts,
            "discards": self.store.discards,
        }
        if self.ec.faults is not None:
            out["injector"] = self.ec.faults.stats()
        return out

    def _store_tier(self) -> str:
        if self.ec.store_tier is not None:
            return self.ec.store_tier
        return self.store.tier_order[-1]  # cloud tier (paper's EBS)

    # ------------------------------------------------------------------ #
    # Unified continuous-batching step (chunked prefill + decode)
    # ------------------------------------------------------------------ #
    def _step_unified(self) -> List[ev.Event]:
        """One unified scheduling step: intake admissible requests as chunk
        streams (plan + fetch + pool-block admission, no compute yet), then
        launch — decode rows co-scheduled with every ready prefill chunk in
        ONE kernel over the block pool.  Admission never monopolizes the
        device: a long suffix-prefill lands kv_block tokens at a time while
        in-flight decodes keep stepping in the same launches."""
        events: List[ev.Event] = []
        self._run_migrations(events)
        admitted = self._unified_intake(events)
        if self._unified_launch(events) or admitted:
            return events
        # idle: jump to the next actionable instant — the next arrival
        # (only if a slot could take it) or the earliest fetch completion.
        targets = [
            c.ready_s for c in self._chunks.values() if c.ready_s > self.clock.now
        ]
        nxt = self.queue.next_arrival()
        if nxt is not None and nxt > self.clock.now:
            targets.append(nxt)
        if not targets:
            return events  # fully drained
        self._advance_clock(min(targets), events)
        return events

    def _unified_intake(self, events: List[ev.Event]) -> bool:
        """Admit every admissible request with a free slot as a pending
        chunk stream: plan, execute the storage fetch (its delay becomes the
        stream's ready time — loads overlap other slots' compute for free),
        admit the slot's pool blocks up front and land any reused rows.
        No prefill compute happens here; chunks land in subsequent unified
        launches.  Requests the pool cannot carry (embeds) fall back to the
        legacy per-request admission."""
        free = [
            s for s in self.slots
            if not s.active and s.index not in self._chunks
        ]
        if not free:
            return False
        limit = min(len(free), self.ec.admit_batch or self.ec.max_slots)
        pending: Dict[str, List[float]] = {}
        admitted = False
        n = 0
        while n < limit:
            nxt = self.queue.peek_next(self.clock.now)
            if nxt is None:
                break
            slot = free[n]
            req = self.queue.pop_admissible(self.clock.now)
            if req.embeds is not None:
                self._admit_single(req, slot, events)
                n += 1
                admitted = True
                continue
            a = self._plan_admission(req, slot, events, pending=pending)
            if a.plan.action == "fused":
                for eid in a.plan.fused.source_entries:
                    if eid in self.store.entries:
                        self.store.pin(eid)
                        a.pins.append(eid)
                for tier, b in a.lookup.fused_bytes_by_tier.items():
                    pending.setdefault(tier, []).append(b)
            if a.plan.loads_kv and a.lookup.entry is not None:
                pending.setdefault(a.lookup.entry.tier, []).append(
                    self._entry_fetch_bytes(a.lookup.entry, a.plan.matched_tokens)
                )
            self._start_chunk_stream(a, events)
            n += 1
            admitted = True
        if admitted:
            self._issue_prefetches()
        return admitted

    def _start_chunk_stream(self, a: "_Admission", events: List[ev.Event]) -> None:
        """Turn one planned admission into a pending chunk stream: fetch
        stored KV (prefix or fused sources), admit the slot's pool blocks
        for the full context+prompt, land the reused rows, and queue the
        remaining q tokens for chunked landing."""
        req, t0 = a.req, self.clock.now
        ctx, prompt = list(req.context_tokens), list(req.prompt_tokens)
        n_ctx, n_total = len(ctx), len(ctx) + len(prompt)
        ps = self._paged
        block = self.ec.kv_block

        fused_out = None
        if a.plan.action == "fused":
            fused_out = self._fetch_fused_sources(a, events)
        elif a.plan.market is not None:
            self._market_fetch(a, events)
            self._release_prefetch(req.req_id)
        elif a.plan.loads_kv and a.lookup.entry is not None:
            self._fetch_kv_resilient(a, events)
            self._release_prefetch(req.req_id)
        else:
            self._release_prefetch(req.req_id)

        own = ps.admit(a.slot.index, n_total)
        if fused_out is not None:
            sources, fetched = fused_out
            schedule = a.plan.fused
            layout = fusion.fused_layout(
                schedule, len(prompt),
                align=self.ec.pack_align, bucket_min=self.ec.pack_bucket_min,
            )
            caches = fusion.build_fused_caches(
                self.cfg, schedule, sources, layout.kv_len
            )
            # land the whole assembled buffer's valid rows: reuse spans
            # carry stored (delta-RoPE'd) KV, recompute/prompt rows are
            # zero and get overwritten as their chunk tokens land
            rows = paged.block_rows(
                ps.tables[a.slot.index, : len(own)], block
            )[:n_total]
            self._pool_update(
                rows,
                (
                    (c.attn.k[:, 0, :n_total], c.attn.v[:, 0, :n_total])
                    for c in caches
                ),
            )
            arrays = fusion.fused_arrays(schedule, ctx, prompt, layout)
            tokens = np.asarray(arrays["tokens"][0, : layout.n_q], np.int32)
            positions = np.asarray(arrays["q_pos"][0, : layout.n_q], np.int32)
            a.delay = max((d for _, _, d, _ in fetched), default=0.0)
            a.matched = schedule.reused_tokens
            for tier, nbytes, delay, rows_n in fetched:
                events.append(ev.KVLoaded(
                    t_s=t0, req_id=req.req_id, tier=tier, nbytes=nbytes,
                    load_s=delay, matched_tokens=rows_n,
                ))
            events.append(ev.FusedAdmitted(
                t_s=t0, req_id=req.req_id, slot=a.slot.index,
                reused_tokens=schedule.reused_tokens,
                recompute_tokens=schedule.recompute_tokens,
                n_spans=len(schedule.spans), n_sources=len(sources),
                q_len=layout.n_q, kv_len=n_total, jit_hit=True,
            ))
            self.fused_admissions += 1
            self.fused_reused_tokens += schedule.reused_tokens
            self.fused_recompute_tokens += schedule.recompute_tokens
            self.fused_sources += len(sources)
        elif a.artifact is not None:
            matched = a.matched
            rows = paged.block_rows(
                ps.tables[a.slot.index, : -(-matched // block)], block
            )[:matched]
            self._pool_update(
                rows,
                (
                    (
                        jnp.asarray(c.attn.k[:, 0, :matched]),
                        jnp.asarray(c.attn.v[:, 0, :matched]),
                    )
                    for c in a.artifact.caches
                ),
            )
            events.append(ev.KVLoaded(
                t_s=t0, req_id=req.req_id,
                tier=(
                    a.lookup.entry.tier if a.lookup.entry is not None
                    else (a.plan.tier or "market")
                ),
                nbytes=a.nbytes, load_s=a.delay, matched_tokens=matched,
            ))
            tokens = np.asarray(ctx[matched:] + prompt, np.int32)
            positions = np.arange(matched, n_total, dtype=np.int32)
        else:
            # plain recompute, or a degraded fetch falling back to exact
            # recompute (the burned time rides on a.delay -> ready_s)
            tokens = np.asarray(ctx + prompt, np.int32)
            positions = np.arange(0, n_total, dtype=np.int32)

        store_after = (
            a.plan.store_after and a.artifact is None and fused_out is None
        )
        if store_after:
            key = tuple(ctx)
            if key in self._wb_inflight:
                # a still-pending batch-mate already owes this context's
                # write-back (the packed batch's dedup, carried over)
                store_after = False
            else:
                self._wb_inflight[key] = a.slot.index
        self._chunks[a.slot.index] = _ChunkStream(
            a=a, tokens=tokens, positions=positions, n_ctx=n_ctx,
            ready_s=t0 + a.delay, store_after=store_after,
        )

    def _unified_launch(self, events: List[ev.Event]) -> bool:
        """Run one launch if there is anything to run: a mixed chunked
        launch when any chunk stream is ready, else a plain paged decode
        step (identical numerics, pricing and billing to legacy — the
        delegation anchor)."""
        now = self.clock.now
        ready = [
            self._chunks[i] for i in sorted(self._chunks)
            if self._chunks[i].ready_s <= now
        ]
        if not ready:
            if any(s.active for s in self.slots):
                self._decode_step(events)
                return True
            return False
        self._unified_mixed_step(ready, events)
        return True

    def _unified_mixed_step(
        self, ready: List[_ChunkStream], events: List[ev.Event]
    ) -> None:
        """ONE launch over the block pool mixing decode rows (every active
        slot, one token each — always granted) with prefill chunks of the
        ready streams (up to kv_block tokens each, under the step token
        budget).  Priced additively (PerfModel.t_step_unified: parameters
        stream once for the whole launch) and billed per row by normalized
        standalone-cost shares, so the step's dollars are conserved
        exactly."""
        ps = self._paged
        B, C = self.ec.max_slots, self.ec.kv_block
        t0 = self.clock.now
        decoding = [s for s in self.slots if s.active]
        splits = []
        for s in decoding:
            cow = ps.prepare_append(s.index)
            if cow is not None:
                splits.append(cow)
        if splits:
            self._copy_pool_blocks(splits)

        toks = np.zeros((B, C), np.int32)
        q_pos = np.full((B, C), -(2 ** 30), np.int32)
        last_idx = np.zeros((B,), np.int32)
        decode_lens = []
        for s in decoding:
            toks[s.index, 0] = s.last_token
            q_pos[s.index, 0] = int(ps.lens[s.index])
            decode_lens.append(
                s.record.context_len + s.record.prompt_len + s.generated
            )
        budget = max(self.ec.step_token_budget - len(decoding), 0)
        grants: List[tuple] = []  # (stream, n granted this step)
        chunk_desc: List[tuple] = []  # (n_new, L_end) for pricing
        for c in ready:
            g = min(C, c.remaining, budget)
            if g <= 0:
                if grants or decoding:
                    continue  # budget spent; this stream waits a step
                g = min(C, c.remaining)  # guarantee progress
            budget -= g
            sl = c.a.slot.index
            toks[sl, :g] = c.tokens[c.done : c.done + g]
            q_pos[sl, :g] = c.positions[c.done : c.done + g]
            last_idx[sl] = g - 1
            grants.append((c, g))
            chunk_desc.append((g, int(c.positions[c.done + g - 1]) + 1))

        jit_hit = self.unified_jit.record((B, C, ps.nb_max))
        logits, self._pool_caches = self._jit_chunked(
            self.params, jnp.asarray(toks), self._pool_caches,
            jnp.asarray(ps.tables), jnp.asarray(q_pos), jnp.asarray(last_idx),
        )
        for s in decoding:
            ps.note_token(s.index)

        step_s = self.perf.t_step_unified(self.cost_cfg, decode_lens, chunk_desc)
        dec_sh, chk_sh = self.perf.step_unified_shares(
            self.cost_cfg, decode_lens, chunk_desc
        )
        self.clock.advance(step_s)
        n_chunk_tokens = sum(g for _, g in grants)
        self.unified_steps += 1
        self.unified_chunk_tokens += n_chunk_tokens
        self.unified_busy_s += step_s
        self.decode_tokens += len(decoding)
        dec_busy = step_s * sum(dec_sh)
        self.decode_busy_s += dec_busy
        self.admission_busy_s += step_s - dec_busy
        events.append(ev.UnifiedStep(
            t_s=t0, req_id=-1,
            req_ids=tuple(
                [s.request.req_id for s in decoding]
                + [c.a.req.req_id for c, _ in grants]
            ),
            n_decode=len(decoding), chunk_tokens=n_chunk_tokens,
            step_s=step_s, jit_hit=jit_hit,
        ))

        nxt_tok = np.asarray(jnp.argmax(logits, axis=-1))
        for s, share in zip(decoding, dec_sh):
            tok = int(nxt_tok[s.index])
            s.record.tokens.append(tok)
            s.record.decode_s += step_s
            s.record.compute_cost += self._c_gpu_s * step_s * share
            s.last_token = tok
            tok_ev = ev.TokenEmitted(
                t_s=self.clock.now, req_id=s.request.req_id,
                token=tok, index=s.generated,
            )
            events.append(tok_ev)
            if self.on_token is not None:
                self.on_token(tok_ev)
            s.generated += 1
            self._maybe_finish(s, events)
        for (c, g), share in zip(grants, chk_sh):
            a = c.a
            a.rec.compute_cost += self._c_gpu_s * step_s * share
            c.done += g
            if c.remaining > 0:
                continue
            del self._chunks[a.slot.index]
            if self._wb_inflight.get(tuple(a.req.context_tokens)) == a.slot.index:
                self._wb_inflight.pop(tuple(a.req.context_tokens))
            if c.store_after:
                art = self._pool_slot_artifact(a.slot.index, c.n_ctx)
                self._write_back(a.req, art, events)
            a.rec.matched_tokens = a.matched
            a.rec.load_s = a.delay
            # ttft_s = queue_s + load_s + prefill_s must equal the first
            # token's timeline instant: prefill_s absorbs the chunked
            # landing time INCLUDING the steps spent waiting on budget
            a.rec.prefill_s = max(0.0, self.clock.now - a.rec.start_s - a.delay)
            events.append(ev.PrefillDone(
                t_s=self.clock.now, req_id=a.req.req_id,
                n_tokens=len(c.tokens), prefill_s=a.rec.prefill_s,
            ))
            self._finish_admission(a, int(nxt_tok[a.slot.index]), events)

    def _pool_slot_artifact(self, slot: int, n_tokens: int) -> Any:
        """Gather a slot's first ``n_tokens`` pool rows as a standard
        batch-1 host artifact — the pool-side analogue of
        ``paged.extract_slot``, feeding the unified path's write-backs."""
        ps = self._paged
        block = self.ec.kv_block
        rows = paged.block_rows(
            ps.tables[slot, : -(-n_tokens // block)], block
        )[:n_tokens]
        return paged.LMState(
            pos=np.full((1,), n_tokens, np.int32),
            caches=tuple(
                paged.BlockCache(
                    paged.KVCache(
                        np.asarray(pc.attn.k[:, rows])[:, None],
                        np.asarray(pc.attn.v[:, rows])[:, None],
                    ),
                    None,
                )
                for pc in self._pool_caches
            ),
        )

    def unified_stats(self) -> Dict[str, Any]:
        """Unified-step counters: mixed launches run, prefill tokens landed
        through chunks, modeled mixed-launch busy time, and the launch's jit
        bucket hit/miss split (one static shape — steady unified serving
        must show exactly one miss)."""
        return {
            "enabled": self._unified_on,
            "steps": self.unified_steps,
            "chunk_tokens": self.unified_chunk_tokens,
            "busy_s": self.unified_busy_s,
            "jit": self.unified_jit.as_dict(),
        }

    # ------------------------------------------------------------------ #
    # Batched decode
    # ------------------------------------------------------------------ #
    def _decode_step(self, events: List[ev.Event]) -> None:
        active = np.array([s.active for s in self.slots])
        toks = np.array(
            [[s.last_token if s.active else 0] for s in self.slots], np.int32
        )
        if self._paged_on:
            logits = self._decode_paged_launch(toks)
        else:
            logits, self._state = self._jit_decode(
                self.params, jnp.asarray(toks), self._state, jnp.asarray(active)
            )
        n_active = int(active.sum())
        lens = [
            s.record.context_len + s.record.prompt_len + s.generated
            for s in self.slots
            if s.active
        ]
        if self._paged_on:
            # live-blocks pricing: each slot is billed exactly the KV bytes
            # its block table streams, not the longest slot's padded length.
            step_s = self.perf.t_decode_paged(self.cost_cfg, lens)
        else:
            step_s = self.perf.t_decode(self.cost_cfg, 1, max(lens), batch=n_active)
        self.decode_busy_s += step_s
        self.decode_tokens += n_active
        self.clock.advance(step_s)
        if self._paged_on:
            # bill each slot proportional to the KV bytes its own live
            # blocks stream through the step, not an equal split — a
            # short-context slot no longer subsidizes a long batch-mate.
            # Uniform lengths give equal weights, so this agrees with the
            # dense split exactly in the uniform case.  The weights are
            # normalized, so the split conserves the step's dollars.
            w = [self.perf.decode_kv_bytes(self.cost_cfg, l) for l in lens]
            total_w = sum(w)
            costs = [self._c_gpu_s * step_s * wi / total_w for wi in w]
        else:
            costs = [self._c_gpu_s * step_s / n_active] * n_active

        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        cost_it = iter(costs)
        for s in self.slots:
            if not s.active:
                continue
            tok = int(nxt[s.index])
            s.record.tokens.append(tok)
            s.record.decode_s += step_s
            s.record.compute_cost += next(cost_it)
            s.last_token = tok
            tok_ev = ev.TokenEmitted(
                t_s=self.clock.now, req_id=s.request.req_id,
                token=tok, index=s.generated,
            )
            events.append(tok_ev)
            if self.on_token is not None:
                self.on_token(tok_ev)
            s.generated += 1
            self._maybe_finish(s, events)

    def _decode_paged_launch(self, toks: np.ndarray) -> jax.Array:
        """One paged decode launch across all active slots: grow/CoW-split
        block tables for the incoming token, run the shared-pool kernel, and
        append in place (tables/lens are host-side; shapes are static, so
        steady decode never recompiles)."""
        ps = self._paged
        splits = []
        for s in self.slots:
            if s.active:
                cow = ps.prepare_append(s.index)
                if cow is not None:
                    splits.append(cow)
        if splits:
            self._copy_pool_blocks(splits)
        logits, self._pool_caches = self._jit_decode_paged(
            self.params, jnp.asarray(toks), self._pool_caches,
            jnp.asarray(ps.tables), jnp.asarray(ps.lens, jnp.int32),
        )
        for s in self.slots:
            if s.active:
                ps.note_token(s.index)
        return logits

    def _maybe_finish(self, s: Slot, events: List[ev.Event]) -> None:
        req = s.request
        done = s.generated >= req.max_new_tokens or (
            req.eos_token is not None and s.last_token == req.eos_token
        )
        if done:
            s.record.finish_s = self.clock.now
            self.records.append(s.record)
            events.append(
                ev.RequestFinished(
                    t_s=self.clock.now, req_id=req.req_id, record=s.record
                )
            )
            s.active = False
            s.request = None
            if self._paged_on:
                # completion returns the slot's blocks to the shared pool
                # (shared-prefix blocks on their LAST reference) and zeroes
                # its table so stale writes land on the dump block.
                self._paged.free(s.index)
