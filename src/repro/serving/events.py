"""Typed events emitted by the step-driven serving engine.

Every observable state change in a request's lifecycle is an event carrying
the SimClock time at which it happened.  ``ServingEngine.step()`` returns the
events of one scheduling step; traces, streaming callers, benchmarks, and
tests all consume the same stream instead of poking engine internals.

Lifecycle of one request:

    RequestAdmitted -> PlanChosen -> ([KVLoaded] | [StoreWriteBack])
        -> PrefillDone -> TokenEmitted* -> RequestFinished

(StoreWriteBack precedes PrefillDone because the two-phase recompute path
snapshots the context state between the context and prompt prefills.  A
fused admission emits one KVLoaded per source entry followed by a
FusedAdmitted before its PrefillDone.)

``ClockAdvanced`` appears between requests when the engine is idle and jumps
simulated time to the next arrival.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

from repro.serving.planner import ReusePlan
from repro.serving.request import RequestRecord


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: SimClock time + the request it concerns (-1 = engine)."""

    t_s: float
    req_id: int


@dataclasses.dataclass(frozen=True)
class RequestAdmitted(Event):
    slot: int
    queue_s: float  # time spent waiting for a slot


@dataclasses.dataclass(frozen=True)
class PlanChosen(Event):
    plan: ReusePlan


@dataclasses.dataclass(frozen=True)
class BatchAdmitted(Event):
    """One packed admission batch (req_id is -1: the batch is an engine-level
    act; each member request still gets its own RequestAdmitted/PlanChosen).
    ``q_tokens``/``q_len`` expose packing occupancy, ``jit_hit`` whether the
    (q_len, kv_len) bucket reused an already-compiled kernel."""

    req_ids: tuple
    q_tokens: int  # useful new tokens across all segments
    q_len: int  # bucketed (padded) packed q length
    kv_len: int  # bucketed packed kv length
    jit_hit: bool


@dataclasses.dataclass(frozen=True)
class UnifiedStep(Event):
    """One unified continuous-batching launch (req_id is -1): decode rows
    co-scheduled with prefill-chunk rows in a single kernel over the shared
    block pool.  ``chunk_tokens`` is the prefill quota actually granted this
    step; ``jit_hit`` whether the (static) launch shape reused an
    already-compiled kernel — steady-state unified serving must never
    recompile."""

    req_ids: tuple  # decode participants first, then chunk participants
    n_decode: int  # decode rows in the launch
    chunk_tokens: int  # prefill-chunk tokens granted this step
    step_s: float  # modeled duration (PerfModel.t_step_unified)
    jit_hit: bool


@dataclasses.dataclass(frozen=True)
class KVLoaded(Event):
    tier: str
    nbytes: float
    load_s: float  # delay charged to this request (post-hedge/prefetch/overlap)
    matched_tokens: int


@dataclasses.dataclass(frozen=True)
class FusedAdmitted(Event):
    """One fused selective-recompute admission (CacheBlend-style non-prefix
    reuse): the request's context was assembled from stored chunk spans
    (one KVLoaded per source entry precedes this event) and only the
    recompute spans + prompt ran through the fused prefill launch."""

    slot: int
    reused_tokens: int  # context tokens served from stored chunk KV
    recompute_tokens: int  # context tokens recomputed (selected + unmatched)
    n_spans: int  # execution spans in the schedule
    n_sources: int  # distinct source entries fetched
    q_len: int  # bucketed fused launch length (query side)
    kv_len: int  # bucketed assembled-buffer length
    jit_hit: bool


@dataclasses.dataclass(frozen=True)
class PrefillDone(Event):
    n_tokens: int  # tokens actually prefilled (context tail + prompt)
    prefill_s: float


@dataclasses.dataclass(frozen=True)
class StoreWriteBack(Event):
    entry_id: str
    tier: str
    nbytes: float


@dataclasses.dataclass(frozen=True)
class TokenEmitted(Event):
    token: int
    index: int  # 0-based position in the generation


@dataclasses.dataclass(frozen=True)
class RequestFinished(Event):
    record: RequestRecord


@dataclasses.dataclass(frozen=True)
class ClockAdvanced(Event):
    to_s: float


@dataclasses.dataclass(frozen=True)
class TierMigrated(Event):
    """An entry moved between storage tiers (req_id is -1: the clock-driven
    economics pass or a capacity-pressure spill, not a request)."""

    entry_id: str
    from_tier: str
    to_tier: str
    nbytes: float
    reason: str  # "promote" | "demote" | "spill"


@dataclasses.dataclass(frozen=True)
class RequestRouted(Event):
    """A cluster router chose a replica for this request (emitted by
    ``ServingCluster`` before the replica's own RequestAdmitted).
    ``matched_tokens`` is the DIGEST-predicted overlap at routing time — a
    stale/false-positive prediction shows up here larger than the landing
    replica's realized KVLoaded, which is exactly the staleness cost."""

    replica: int
    matched_tokens: int  # digest-predicted overlap (not the realized one)
    score: float  # marginal routing cost of the chosen replica ($)
    ring_owner: int  # consistent-hash baseline placement (-1: oblivious)


@dataclasses.dataclass(frozen=True)
class ReplicaRebalanced(Event):
    """Cluster rebalancing copied a hot entry toward its traffic: the target
    replica now holds its own hot-tier copy (replicated residency — the
    donor keeps serving until then, so there is no unreachable window).
    req_id is -1: an economics pass, not a request."""

    content_key: str
    from_replica: int
    to_replica: int
    nbytes: float
    hits: int  # routed hits at the target that justified the copy


@dataclasses.dataclass(frozen=True)
class FetchFailed(Event):
    """One planned KV fetch attempt failed (transient drop, brownout,
    corruption, or a vanished key).  ``wasted_s``/``wasted_bytes`` are what
    the failed attempt burned — already charged to the transfer model when
    bytes actually moved (brownouts fail fast and free)."""

    tier: str
    entry_id: str
    attempt: int  # 1-based attempt number that failed
    reason: str  # "unavailable" | "brownout" | "corrupt" | "corrupt_at_rest" | "not_found"
    wasted_s: float
    wasted_bytes: float


@dataclasses.dataclass(frozen=True)
class FetchRetried(Event):
    """The cost-aware retry policy decided another attempt still beats
    recomputing: attempt ``attempt`` will run after ``backoff_s``."""

    tier: str
    entry_id: str
    attempt: int  # the attempt about to run (>= 2)
    backoff_s: float


@dataclasses.dataclass(frozen=True)
class DegradedToRecompute(Event):
    """All fetch attempts failed (or retrying stopped beating recompute):
    the request falls back to exact recompute mid-admission.  Tokens are
    bit-identical to the fault-free run; the price is ``wasted_s`` of burned
    fetch time plus the full prefill."""

    tier: Optional[str]
    entry_id: Optional[str]
    attempts: int  # fetch attempts made before degrading
    wasted_s: float
    reason: str


@dataclasses.dataclass(frozen=True)
class KVPurchased(Event):
    """The request's stored-KV fetch was bought from a marketplace peer
    instead of served from the engine's own store (``repro.market``).  The
    purchase settled — buyer debited, seller credited — through the
    ``SettlementLedger``; ``price`` is the buyer's total spend including the
    market's transaction fee."""

    seller: str  # tenant id of the selling peer
    buyer: str
    entry_id: str  # entry in the SELLER's store
    tier: str  # seller-side tier the bytes came from
    nbytes: float
    price: float  # buyer spend in $ (ask x risk multiplier + flat fee)
    matched_tokens: int


@dataclasses.dataclass(frozen=True)
class SellerVerified(Event):
    """A purchased payload was verified before being served: checksum
    against the catalog stamp always, plus (``deep=True``) a spot
    recompute of a prefix sample compared bit-exactly against the
    delivered KV.  ``ok=False`` means the payload was corrupt/stale — it
    was NEVER served; the request degrades to exact recompute."""

    seller: str
    entry_id: str
    ok: bool
    deep: bool  # the spot recompute-sample oracle ran (vs checksum-only)


@dataclasses.dataclass(frozen=True)
class SellerBlacklisted(Event):
    """The reputation book ejected a seller caught serving corrupt/stale
    payloads: no future quote will ever name it again."""

    seller: str
    corrupt_count: int  # failed verifications that earned the ejection


@dataclasses.dataclass(frozen=True)
class ReplicaCrashed(Event):
    """A replica died mid-run (req_id is -1: a cluster-level act).  Its
    in-flight and queued requests were harvested and resubmitted to the
    surviving replicas through the router; its shared-tier namespace was
    released and its digest invalidated."""

    replica: int
    inflight: int  # active-slot requests resubmitted
    queued: int  # admission-queue requests resubmitted
    released_keys: int  # shared-tier keys released by the crash


AnyEvent = Union[
    RequestAdmitted, PlanChosen, BatchAdmitted, KVLoaded, FusedAdmitted,
    PrefillDone, StoreWriteBack, TokenEmitted, RequestFinished, ClockAdvanced,
    TierMigrated, RequestRouted, ReplicaRebalanced, FetchFailed, FetchRetried,
    DegradedToRecompute, KVPurchased, SellerVerified, SellerBlacklisted,
    ReplicaCrashed,
]


def actions_from_events(events: List[Event]) -> dict:
    """req_id -> executed action, reconstructed from the plan stream (the
    event-trace view of what RequestRecord.action records)."""
    out = {}
    for ev in events:
        if isinstance(ev, PlanChosen):
            out[ev.req_id] = ev.plan.action
    return out


def tokens_from_events(events: List[Event]) -> dict:
    """req_id -> generated tokens, reconstructed from TokenEmitted events."""
    out: dict = {}
    for ev in events:
        if isinstance(ev, TokenEmitted):
            out.setdefault(ev.req_id, []).append(ev.token)
    return out
