"""Bucketed jit-compile cache accounting for the packed prefill path.

jax.jit retraces/recompiles whenever an argument *shape* is new, and a packed
ragged prefill has a different total length for almost every admission batch.
The engine therefore rounds the packed q/kv lengths up to power-of-two
buckets (``kvcache.paged.pack_bucket``) so steady-state traffic lands on a
small closed set of shapes.  This module is the observability half: it
mirrors jax's per-shape cache keys and counts hits vs misses (compiles), so
benchmarks can assert "zero steady-state recompiles" from the outside
instead of guessing from wall time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass
class JitBucketStats:
    """Hit/miss bookkeeping over (q_len, kv_len) jit buckets."""

    hits: int = 0
    misses: int = 0
    # consecutive hits since the last compile — "zero steady-state
    # recompiles" means this covers the whole steady phase of a run
    calls_since_miss: int = 0
    # bucket key -> number of calls that landed on it
    calls: Dict[Tuple[int, int], int] = dataclasses.field(default_factory=dict)

    def record(self, key: Tuple[int, int]) -> bool:
        """Account one packed call on ``key``; True iff the compiled kernel
        for this bucket already existed (a jit cache hit)."""
        hit = key in self.calls
        self.calls[key] = self.calls.get(key, 0) + 1
        if hit:
            self.hits += 1
            self.calls_since_miss += 1
        else:
            self.misses += 1
            self.calls_since_miss = 0
        return hit

    @property
    def n_buckets(self) -> int:
        return len(self.calls)

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "n_buckets": self.n_buckets,
            "hit_rate": self.hits / max(self.hits + self.misses, 1),
            "calls_since_miss": self.calls_since_miss,
        }

    def labeled_calls(self) -> Dict[str, int]:
        """``calls`` with metric-label-friendly "QxK" bucket keys — the shape
        the telemetry registry exports (``jit_bucket_calls{bucket="QxK"}``)."""
        return {f"{q}x{k}": n for (q, k), n in self.calls.items()}
