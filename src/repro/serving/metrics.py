"""Serving metrics: delay distributions + the paper's cost breakdown.

Two entry points: ``summarize`` over the engine's records, and
``summarize_events`` over a typed event stream (``serving/events.py``) — the
latter lets streaming consumers that only kept the events produce the same
summary the engine would."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

import numpy as np

from repro.serving.request import RequestRecord


@dataclasses.dataclass
class ServingSummary:
    n_requests: int
    reuse_hits: int
    mean_ttft_s: float
    p50_ttft_s: float
    p99_ttft_s: float
    mean_e2e_s: float
    p99_e2e_s: float
    compute_cost: float
    storage_cost: float
    transfer_cost: float
    horizon_s: float

    @property
    def total_cost(self) -> float:
        return self.compute_cost + self.storage_cost + self.transfer_cost

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["total_cost"] = self.total_cost
        return d


def summarize(
    records: List[RequestRecord],
    *,
    storage_cost: float,
    transfer_cost: float,
) -> ServingSummary:
    # empty runs report NaN latency stats, never a fake 0.0: a consumer
    # averaging summaries must not mistake "no requests" for "instant TTFT"
    ttft = np.array([r.ttft_s for r in records]) if records else np.full(1, np.nan)
    e2e = np.array([r.e2e_s for r in records]) if records else np.full(1, np.nan)
    return ServingSummary(
        n_requests=len(records),
        reuse_hits=sum(
            1 for r in records if r.action in ("load", "partial", "fused")
        ),
        mean_ttft_s=float(ttft.mean()),
        p50_ttft_s=float(np.percentile(ttft, 50)),
        p99_ttft_s=float(np.percentile(ttft, 99)),
        mean_e2e_s=float(e2e.mean()),
        p99_e2e_s=float(np.percentile(e2e, 99)),
        compute_cost=float(sum(r.compute_cost for r in records)),
        storage_cost=storage_cost,
        transfer_cost=transfer_cost,
        horizon_s=float(max((r.finish_s for r in records), default=0.0)),
    )


@dataclasses.dataclass
class ClusterSummary:
    """Aggregate view over N replicas' serving summaries.  Latency stats are
    request-weighted means of the per-replica stats; costs add; the horizon
    is the latest replica's (replicas run on private clocks)."""

    replicas: List[ServingSummary]
    tokens_generated: int = 0

    @property
    def n_requests(self) -> int:
        return sum(s.n_requests for s in self.replicas)

    @property
    def reuse_hits(self) -> int:
        return sum(s.reuse_hits for s in self.replicas)

    @property
    def hit_rate(self) -> float:
        return self.reuse_hits / max(self.n_requests, 1)

    @property
    def total_cost(self) -> float:
        return sum(s.total_cost for s in self.replicas)

    @property
    def horizon_s(self) -> float:
        return max((s.horizon_s for s in self.replicas), default=0.0)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.horizon_s, 1e-9)

    @property
    def mean_ttft_s(self) -> float:
        # idle replicas (0 requests) report NaN stats; they carry no weight
        # here and must not poison the cluster mean
        n = max(self.n_requests, 1)
        return sum(
            s.mean_ttft_s * s.n_requests for s in self.replicas
            if s.n_requests > 0
        ) / n

    def as_dict(self) -> Dict[str, float]:
        return {
            "n_replicas": len(self.replicas),
            "n_requests": self.n_requests,
            "reuse_hits": self.reuse_hits,
            "hit_rate": self.hit_rate,
            "mean_ttft_s": self.mean_ttft_s,
            "tokens_generated": self.tokens_generated,
            "tokens_per_s": self.tokens_per_s,
            "horizon_s": self.horizon_s,
            "total_cost": self.total_cost,
            "per_replica": [s.as_dict() for s in self.replicas],
        }


def summarize_events(
    events: Iterable,
    *,
    storage_cost: float,
    transfer_cost: float,
) -> ServingSummary:
    """Summary from a typed event stream: every finished request's record
    rides on its RequestFinished event, so the stream is self-sufficient."""
    from repro.serving.events import RequestFinished

    records = [e.record for e in events if isinstance(e, RequestFinished)]
    return summarize(
        records, storage_cost=storage_cost, transfer_cost=transfer_cost
    )
