"""Plan half of the serving engine's plan/execute split.

Per admitted request the engine asks a ``ReusePlanner`` one question — given
this request, what the store knows about its context (``StoreLookup``), and
its workload shape, what should happen?  The answer is a declarative
``ReusePlan``: recompute or load (fully/partially) from which tier, how many
bytes move, whether to write the context back after prefill, and the
analytical model's TTFT/$ estimates for the chosen option.  Planning is pure
(no store/compute side effects), so planner variants — the paper's
cost-model gating, unconditional reuse, or future CacheBlend/KVShare-style
schemes — are drop-in and unit-testable against golden plans.

Two planners ship:

  * ``CostAwarePlanner``   — the paper's policy: recompute/load/partial by
    analytical cost under the TTFT SLO (``core.policy.decide``), write-back
    iff expected reuses clear break-even (``core.policy.should_store``).
  * ``AlwaysReusePlanner`` — store & reuse unconditionally (correctness
    tests, and the paper's own Fig-2 experiment which always reuses).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, runtime_checkable

from repro.configs.base import ArchConfig
from repro.core import policy as policy_mod
from repro.core.cost_model import Workload
from repro.core.perf_model import PerfModel
from repro.core.pricing import Pricing
from repro.kvcache.chunks import PrefixMatch
from repro.kvcache.store import StoredEntry
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class StoreLookup:
    """What the store knows about a request's context at plan time."""

    match: Optional[PrefixMatch]
    entry: Optional[StoredEntry]
    # usable fraction of the request's context covered by the stored prefix
    # (0 when nothing is stored, or when a partial prefix exists but the
    # architecture cannot consume it — SSM state is all-or-nothing).
    fraction: float
    partial_ok: bool
    # tier -> predicted queueing delay on that tier's (concurrency-limited)
    # link right now; empty for uncontended links.  Tier-aware planners fold
    # this into per-tier TTFT estimates.
    queue_wait_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def hit(self) -> bool:
        return self.entry is not None and self.fraction > 0

    def available(self) -> Dict[str, float]:
        """tier name -> matched fraction, the policy's option set."""
        return {self.entry.tier: self.fraction} if self.hit else {}

    @staticmethod
    def miss() -> "StoreLookup":
        return StoreLookup(match=None, entry=None, fraction=0.0, partial_ok=False)


@dataclasses.dataclass(frozen=True)
class ReusePlan:
    """Declarative outcome of planning one request (execute interprets it)."""

    action: str  # "recompute" | "load" | "partial"
    tier: Optional[str]  # source tier when loading
    matched_tokens: int  # context tokens served from stored state
    reused_fraction: float
    fetch_bytes: float  # stored bytes that will move (0 for recompute)
    store_after: bool  # write the context state back after prefill
    est_ttft_s: float  # analytical-model estimates for the chosen option
    est_cost: float

    @property
    def loads_kv(self) -> bool:
        return self.action in ("load", "partial")


@runtime_checkable
class ReusePlanner(Protocol):
    """Pure request-level reuse policy: (request, lookup, workload) -> plan."""

    def configure(
        self,
        *,
        cost_cfg: ArchConfig,
        pricing: Pricing,
        perf: PerfModel,
        write_back: bool,
        min_store_tokens: int,
    ) -> None:
        """Bind the engine's economics environment (called once at engine
        construction; planners are created bare by callers)."""
        ...

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        ...


class _PlannerBase:
    """Environment binding + the decision->plan translation shared by the
    shipped planners."""

    def __init__(self) -> None:
        self.cost_cfg: Optional[ArchConfig] = None
        self.pricing: Optional[Pricing] = None
        self.perf: Optional[PerfModel] = None
        self.write_back: bool = True
        self.min_store_tokens: int = 1

    def configure(
        self,
        *,
        cost_cfg: ArchConfig,
        pricing: Pricing,
        perf: PerfModel,
        write_back: bool,
        min_store_tokens: int,
    ) -> None:
        self.cost_cfg = cost_cfg
        self.pricing = pricing
        self.perf = perf
        self.write_back = write_back
        self.min_store_tokens = min_store_tokens

    # -- helpers -------------------------------------------------------- #
    def _storable(self, request: Request, lookup: StoreLookup) -> bool:
        """Write-back is even on the table only when enabled, the context is
        not already stored, and it spans at least one chunk."""
        return (
            self.write_back
            and lookup.entry is None
            and len(request.context_tokens) >= self.min_store_tokens
        )

    def _to_plan(
        self,
        decision: policy_mod.Decision,
        request: Request,
        lookup: StoreLookup,
        *,
        store_after: bool,
    ) -> ReusePlan:
        matched = 0
        fetch_bytes = 0.0
        if decision.loads_kv and lookup.entry is not None:
            matched = (
                len(request.context_tokens)
                if decision.action == "load"
                else lookup.match.matched_tokens
            )
            e = lookup.entry
            fetch_bytes = e.nbytes * max(0.0, min(1.0, matched / max(e.n_tokens, 1)))
        return ReusePlan(
            action=decision.action,
            tier=decision.tier,
            matched_tokens=matched,
            reused_fraction=decision.reused_fraction,
            fetch_bytes=fetch_bytes,
            store_after=store_after and not decision.loads_kv,
            est_ttft_s=decision.est_ttft_s,
            est_cost=decision.est_cost,
        )


class CostAwarePlanner(_PlannerBase):
    """The paper's policy: cheapest SLO-satisfying option, break-even-gated
    write-back.  Tier-aware: each candidate tier's TTFT estimate includes the
    predicted queueing delay on that tier's contended link, so a burst on a
    limit-k backend can tip the decision back to recompute under a TTFT SLO."""

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        decision = policy_mod.decide(
            self.cost_cfg, workload, self.pricing, self.perf,
            available=lookup.available(),
            queue_wait_s=lookup.queue_wait_s,
        )
        store_after = self._storable(request, lookup) and policy_mod.should_store(
            self.cost_cfg, workload, self.pricing, self.perf,
            expected_reuses=request.expected_reuses,
        )
        return self._to_plan(decision, request, lookup, store_after=store_after)


class AlwaysReusePlanner(_PlannerBase):
    """Unconditional store & reuse (the paper's Fig-2 pipeline): any stored
    prefix is loaded, every new context is written back."""

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        available = lookup.available()
        if available:
            tier, frac = next(iter(available.items()))
            decision = policy_mod.Decision(
                action="load" if frac >= 1.0 else "partial",
                tier=tier, reused_fraction=frac, est_ttft_s=0.0, est_cost=0.0,
            )
        else:
            decision = policy_mod.decide(
                self.cost_cfg, workload, self.pricing, self.perf, available={}
            )
        return self._to_plan(
            decision, request, lookup, store_after=self._storable(request, lookup)
        )
