"""Plan half of the serving engine's plan/execute split.

Per admitted request the engine asks a ``ReusePlanner`` one question — given
this request, what the store knows about its context (``StoreLookup``), and
its workload shape, what should happen?  The answer is a declarative
``ReusePlan``: recompute or load (fully/partially) from which tier, how many
bytes move, whether to write the context back after prefill, and the
analytical model's TTFT/$ estimates for the chosen option.  Planning is pure
(no store/compute side effects), so planner variants — the paper's
cost-model gating, unconditional reuse, or future CacheBlend/KVShare-style
schemes — are drop-in and unit-testable against golden plans.

Three planners ship:

  * ``CostAwarePlanner``   — the paper's policy: recompute/load/partial by
    analytical cost under the TTFT SLO (``core.policy.decide``), write-back
    iff expected reuses clear break-even (``core.policy.should_store``).
  * ``AlwaysReusePlanner`` — store & reuse unconditionally (correctness
    tests, and the paper's own Fig-2 experiment which always reuses).
  * ``BlendPlanner``       — CacheBlend-style partial fusion layered over
    either of the above: when the chunk-content index finds non-prefix
    matches (``StoreLookup.composite``) that beat the prefix match, plan a
    ``"fused"`` admission — fetch the matched chunks' KV, selectively
    recompute an r-fraction — priced by ``PerfModel.t_prefill_fused`` and
    the ``core.cost_model`` fused-prefill term.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, runtime_checkable

from repro.configs.base import ArchConfig
from repro.core import cost_model
from repro.core import policy as policy_mod
from repro.core.cost_model import Workload
from repro.core.perf_model import PerfModel
from repro.core.pricing import Pricing
from repro.kvcache.chunks import PrefixMatch
from repro.kvcache.fusion import CompositeMatch, select_recompute
from repro.kvcache.store import StoredEntry
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class StoreLookup:
    """What the store knows about a request's context at plan time."""

    match: Optional[PrefixMatch]
    entry: Optional[StoredEntry]
    # usable fraction of the request's context covered by the stored prefix
    # (0 when nothing is stored, or when a partial prefix exists but the
    # architecture cannot consume it — SSM state is all-or-nothing).
    fraction: float
    partial_ok: bool
    # tier -> predicted queueing delay on that tier's (concurrency-limited)
    # link right now; empty for uncontended links.  Tier-aware planners fold
    # this into per-tier TTFT estimates.
    queue_wait_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # Chunk-content index view of the context (kvcache/fusion.py): non-prefix
    # chunk matches for CacheBlend-style fusion.  None when fusion is off or
    # the architecture cannot consume assembled KV (SSM/enc-dec/embeds).
    composite: Optional[CompositeMatch] = None
    # tier -> bytes the composite's matched chunks would fetch from it (at
    # economics scale) — the fused option's load/fee pricing surface.
    fused_bytes_by_tier: Dict[str, float] = dataclasses.field(default_factory=dict)
    # tiers browned out at lookup time (kvcache.faults.Brownout windows):
    # the planner must not plan a load from them — a fetch would fail fast.
    unavailable_tiers: frozenset = frozenset()

    @property
    def hit(self) -> bool:
        return (
            self.entry is not None
            and self.fraction > 0
            and self.entry.tier not in self.unavailable_tiers
        )

    def available(self) -> Dict[str, float]:
        """tier name -> matched fraction, the policy's option set (tiers in
        a brownout window are excluded — loads from them cannot succeed)."""
        return {self.entry.tier: self.fraction} if self.hit else {}

    @property
    def prefix_tokens(self) -> int:
        """Context tokens the architecture-usable prefix match covers."""
        if self.match is None or self.fraction <= 0:
            return 0
        return self.match.matched_tokens

    @staticmethod
    def miss() -> "StoreLookup":
        return StoreLookup(match=None, entry=None, fraction=0.0, partial_ok=False)


@dataclasses.dataclass(frozen=True)
class ReusePlan:
    """Declarative outcome of planning one request (execute interprets it)."""

    action: str  # "recompute" | "load" | "partial" | "fused"
    tier: Optional[str]  # source tier when loading (fused: the dominant one)
    matched_tokens: int  # context tokens served from stored state
    reused_fraction: float
    fetch_bytes: float  # stored bytes that will move (0 for recompute)
    store_after: bool  # write the context state back after prefill
    est_ttft_s: float  # analytical-model estimates for the chosen option
    est_cost: float
    # CacheBlend-style fused admissions: the execution schedule (reuse spans
    # + selected recompute spans, kvcache.fusion.FusedSchedule); None for
    # the classic actions.
    fused: Optional[object] = None
    # Marketplace purchases (repro.market): the accepted peer Quote when the
    # plan's KV bytes are bought from another tenant's store rather than
    # fetched from this engine's own; None for all local plans.
    market: Optional[object] = None

    @property
    def loads_kv(self) -> bool:
        """Single-entry prefix load (the classic execute path)."""
        return self.action in ("load", "partial")

    @property
    def reuses_kv(self) -> bool:
        """Any stored-KV reuse, prefix or chunk-composite."""
        return self.action in ("load", "partial", "fused")


@runtime_checkable
class ReusePlanner(Protocol):
    """Pure request-level reuse policy: (request, lookup, workload) -> plan."""

    def configure(
        self,
        *,
        cost_cfg: ArchConfig,
        pricing: Pricing,
        perf: PerfModel,
        write_back: bool,
        min_store_tokens: int,
    ) -> None:
        """Bind the engine's economics environment (called once at engine
        construction; planners are created bare by callers)."""
        ...

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        ...


class _PlannerBase:
    """Environment binding + the decision->plan translation shared by the
    shipped planners."""

    def __init__(self) -> None:
        self.cost_cfg: Optional[ArchConfig] = None
        self.pricing: Optional[Pricing] = None
        self.perf: Optional[PerfModel] = None
        self.write_back: bool = True
        self.min_store_tokens: int = 1

    def configure(
        self,
        *,
        cost_cfg: ArchConfig,
        pricing: Pricing,
        perf: PerfModel,
        write_back: bool,
        min_store_tokens: int,
    ) -> None:
        self.cost_cfg = cost_cfg
        self.pricing = pricing
        self.perf = perf
        self.write_back = write_back
        self.min_store_tokens = min_store_tokens

    # -- helpers -------------------------------------------------------- #
    def _storable(self, request: Request, lookup: StoreLookup) -> bool:
        """Write-back is even on the table only when enabled, the context is
        not already stored, and it spans at least one chunk."""
        return (
            self.write_back
            and lookup.entry is None
            and len(request.context_tokens) >= self.min_store_tokens
        )

    def _to_plan(
        self,
        decision: policy_mod.Decision,
        request: Request,
        lookup: StoreLookup,
        *,
        store_after: bool,
    ) -> ReusePlan:
        matched = 0
        fetch_bytes = 0.0
        if decision.loads_kv and lookup.entry is not None:
            matched = (
                len(request.context_tokens)
                if decision.action == "load"
                else lookup.match.matched_tokens
            )
            e = lookup.entry
            fetch_bytes = e.nbytes * max(0.0, min(1.0, matched / max(e.n_tokens, 1)))
        return ReusePlan(
            action=decision.action,
            tier=decision.tier,
            matched_tokens=matched,
            reused_fraction=decision.reused_fraction,
            fetch_bytes=fetch_bytes,
            store_after=store_after and not decision.loads_kv,
            est_ttft_s=decision.est_ttft_s,
            est_cost=decision.est_cost,
        )


class CostAwarePlanner(_PlannerBase):
    """The paper's policy: cheapest SLO-satisfying option, break-even-gated
    write-back.  Tier-aware: each candidate tier's TTFT estimate includes the
    predicted queueing delay on that tier's contended link, so a burst on a
    limit-k backend can tip the decision back to recompute under a TTFT SLO."""

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        decision = policy_mod.decide(
            self.cost_cfg, workload, self.pricing, self.perf,
            available=lookup.available(),
            queue_wait_s=lookup.queue_wait_s,
        )
        store_after = self._storable(request, lookup) and policy_mod.should_store(
            self.cost_cfg, workload, self.pricing, self.perf,
            expected_reuses=request.expected_reuses,
        )
        return self._to_plan(decision, request, lookup, store_after=store_after)


class AlwaysReusePlanner(_PlannerBase):
    """Unconditional store & reuse (the paper's Fig-2 pipeline): any stored
    prefix is loaded, every new context is written back."""

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        available = lookup.available()
        if available:
            tier, frac = next(iter(available.items()))
            decision = policy_mod.Decision(
                action="load" if frac >= 1.0 else "partial",
                tier=tier, reused_fraction=frac, est_ttft_s=0.0, est_cost=0.0,
            )
        else:
            decision = policy_mod.decide(
                self.cost_cfg, workload, self.pricing, self.perf, available={}
            )
        return self._to_plan(
            decision, request, lookup, store_after=self._storable(request, lookup)
        )


class BlendPlanner(_PlannerBase):
    """CacheBlend-style partial-fusion planning layered over a base planner.

    The base planner (``CostAwarePlanner`` by default, ``AlwaysReusePlanner``
    when ``always=True``) handles the classic prefix-reuse decision.  On top,
    when the chunk-content index reports non-prefix matches
    (``StoreLookup.composite``) covering strictly more context than the
    usable prefix, a *fused* option competes: fetch the matched chunks' KV
    from their source entries, selectively recompute ``recompute_frac`` of
    the matched tokens (plus every unmatched token and the prompt), priced by
    ``PerfModel.t_prefill_fused`` + the ``cost_model`` fused-prefill term.

    * ``always=True``  — fuse whenever a viable composite match exists (the
      fusion analogue of AlwaysReusePlanner; correctness tests, benchmarks).
    * ``always=False`` — fused competes on (SLO-feasible) marginal cost with
      the base plan, exactly how ``core.policy.decide`` weighs its options.

    Fused plans never write back: at r < 1 the assembled KV is approximate
    (missing cross-chunk attention), and storing it would pollute the store
    with state that no longer matches its chain hash's exactness contract.
    """

    def __init__(self, recompute_frac: float = 0.16, always: bool = False):
        super().__init__()
        self.recompute_frac = recompute_frac
        self.always = always
        self.base: _PlannerBase = (
            AlwaysReusePlanner() if always else CostAwarePlanner()
        )

    def configure(self, **kw) -> None:
        super().configure(**kw)
        self.base.configure(**kw)

    def _fused_plan(
        self, request: Request, lookup: StoreLookup, workload: Workload
    ) -> Optional[ReusePlan]:
        comp = lookup.composite
        if comp is None or comp.matched_tokens <= lookup.prefix_tokens:
            return None  # prefix reuse covers at least as much, exactly
        schedule = select_recompute(comp, self.recompute_frac)
        if schedule.recompute_tokens + len(request.prompt_tokens) == 0:
            return None  # nothing to launch (r=0, full match, no prompt)
        if not request.prompt_tokens and schedule.spans[-1].kind == "reuse":
            # the first generated token comes from the sequence's FINAL
            # position; with no prompt that position must be in the launch's
            # query set, which a reused tail span would exclude
            return None
        d = cost_model.delay_fused(
            self.cost_cfg, workload, self.perf, self.pricing,
            bytes_by_tier=lookup.fused_bytes_by_tier,
            n_recompute_ctx=schedule.recompute_tokens,
            queue_wait_s=lookup.queue_wait_s,
        )
        cost = cost_model.cost_fused_request(
            self.cost_cfg, workload, self.pricing, self.perf,
            bytes_by_tier=lookup.fused_bytes_by_tier,
            n_recompute_ctx=schedule.recompute_tokens,
        )
        tier = max(
            lookup.fused_bytes_by_tier, key=lookup.fused_bytes_by_tier.get,
            default=None,
        ) if lookup.fused_bytes_by_tier else None
        return ReusePlan(
            action="fused",
            tier=tier,
            matched_tokens=schedule.reused_tokens,
            reused_fraction=schedule.reused_tokens / max(comp.total_tokens, 1),
            fetch_bytes=sum(lookup.fused_bytes_by_tier.values()),
            store_after=False,
            est_ttft_s=d.ttft_s,
            est_cost=cost,
            fused=schedule,
        )

    def plan(self, request: Request, lookup: StoreLookup, workload: Workload) -> ReusePlan:
        base_plan = self.base.plan(request, lookup, workload)
        # viability is judged on the composite MATCH (r=1.0 recomputes every
        # matched token, yet must still ride the fused execute path — the
        # bit-exactness anchor)
        fused = self._fused_plan(request, lookup, workload)
        if fused is None:
            return base_plan
        if self.always:
            return fused if base_plan.action != "load" else base_plan
        slo = workload.slo_ttft_s
        if slo is not None and fused.est_ttft_s > slo >= base_plan.est_ttft_s:
            return base_plan
        return fused if fused.est_cost < base_plan.est_cost else base_plan
