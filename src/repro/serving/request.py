"""Request/slot lifecycle types for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence


class Phase(enum.Enum):
    QUEUED = "queued"
    LOADING = "loading"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    req_id: int
    context_tokens: List[int]
    prompt_tokens: List[int]
    max_new_tokens: int
    arrival_s: float = 0.0
    # expected reuses of this context within the serving period (the paper's
    # N) — drives the write-back break-even decision.
    expected_reuses: float = 1.0
    slo_ttft_s: Optional[float] = None
    eos_token: Optional[int] = None
    embeds: Optional[object] = None  # VLM patch embeddings / audio frames


@dataclasses.dataclass
class RequestRecord:
    req_id: int
    arrival_s: float
    context_len: int
    prompt_len: int
    # outcome
    tokens: List[int] = dataclasses.field(default_factory=list)
    action: str = ""  # recompute | load | partial
    matched_tokens: int = 0
    # the declarative ReusePlan this request executed (typed as object to
    # keep request types dependency-free; see serving/planner.py) — realized
    # load_s/prefill_s below can be audited against its est_ttft_s.
    plan: Optional[object] = None
    start_s: float = 0.0
    load_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    finish_s: float = 0.0
    compute_cost: float = 0.0
    # the planned fetch failed and this request fell back to exact recompute
    # mid-admission (tokens unaffected; load_s carries the wasted fetch time)
    degraded: bool = False

    @property
    def queue_s(self) -> float:
        return self.start_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        return self.queue_s + self.load_s + self.prefill_s

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class Slot:
    index: int
    request: Optional[Request] = None
    record: Optional[RequestRecord] = None
    generated: int = 0
    last_token: int = 0
    active: bool = False
