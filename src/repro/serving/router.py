"""Cache-affinity routing across serving-engine replicas.

At cluster scale the paper's reuse economics hinge on *which* replica a
request lands on: reuse frequency — the dominant workload parameter — is a
per-replica quantity, so a router that scatters identical contexts across N
replicas divides every entry's frequency by N and can push stored KV below
its break-even point.  This module is the cluster's placement brain:

  * ``ConsistentHashRing``  — baseline placement: the content space is
    consistent-hashed over replicas, so identical contexts gravitate to one
    owner even before anything is stored (and stay put as replicas join or
    leave).
  * ``BloomDigest``         — compact per-replica summary of stored chain /
    chunk-content hashes, exchanged on a gossip tick.  Digests are
    STALENESS-TOLERANT by construction: a false positive or stale bit only
    mis-prices a route (the landing replica recomputes on a miss — tokens
    are unaffected), never corrupts an answer.
  * ``AffinityRouter``      — scores each replica by the marginal cost of
    sending the request there (``cost_model.cost_routed_request``: expected
    queue + fetch + suffix-prefill + decode, GPU-idle $ and per-GB fees
    included) plus a TTFT term, and routes to the argmin — NOT argmax
    overlap: a loaded replica with a perfect digest hit loses to an idle
    one when the queue outweighs the fetch savings.
  * ``RoundRobinRouter``    — the cache-oblivious baseline the benchmark
    compares against.

Both routers enforce the capacity invariant: a request is never sent to a
replica without free capacity while another qualifying replica has some.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import itertools
from typing import List, Optional, Sequence

from repro.core.cost_model import Workload, cost_routed_request, delay_routed
from repro.kvcache.chunks import chunk_hash_chain


# --------------------------------------------------------------------------- #
# Gossip digest
# --------------------------------------------------------------------------- #
class BloomDigest:
    """Bloom filter over a replica's stored hashes (chain hashes, chunk
    content hashes, whole-context content keys — ``TieredStore.digest_hashes``).
    ``m_bits / 8`` bytes travel per gossip tick regardless of store size."""

    __slots__ = ("m", "k", "_bits", "n_added")

    def __init__(self, m_bits: int = 1 << 14, k: int = 4):
        assert m_bits > 0 and k > 0, (m_bits, k)
        self.m = int(m_bits)
        self.k = int(k)
        self._bits = 0
        self.n_added = 0

    def _points(self, h: str):
        for i in range(self.k):
            yield int(
                hashlib.sha256(f"{i}|{h}".encode()).hexdigest()[:16], 16
            ) % self.m

    def add(self, h: str) -> None:
        for p in self._points(h):
            self._bits |= 1 << p
        self.n_added += 1

    def update(self, hashes: Sequence[str]) -> None:
        for h in hashes:
            self.add(h)

    def __contains__(self, h: str) -> bool:
        return all((self._bits >> p) & 1 for p in self._points(h))

    @property
    def fill(self) -> float:
        return bin(self._bits).count("1") / self.m

    @property
    def nbytes(self) -> int:
        """Gossip payload size."""
        return self.m // 8


# --------------------------------------------------------------------------- #
# Consistent-hash baseline placement
# --------------------------------------------------------------------------- #
class ConsistentHashRing:
    """Content space -> replica, stable under membership changes: each
    replica owns ``vnodes`` points on a 2^64 ring; a key belongs to the
    first point clockwise of its hash."""

    def __init__(self, replica_ids: Sequence[int], vnodes: int = 64):
        self.vnodes = int(vnodes)
        self._ids: List[int] = []
        self._points: List[tuple] = []
        for rid in replica_ids:
            self.add(rid)

    @staticmethod
    def _hash(s: str) -> int:
        return int(hashlib.sha256(s.encode()).hexdigest()[:16], 16)

    def add(self, rid: int) -> None:
        if rid in self._ids:
            return
        self._ids.append(rid)
        for v in range(self.vnodes):
            self._points.append((self._hash(f"replica{rid}#{v}"), rid))
        self._points.sort()

    def remove(self, rid: int) -> None:
        self._ids = [r for r in self._ids if r != rid]
        self._points = [(p, r) for p, r in self._points if r != rid]

    def owner(self, key: str) -> int:
        assert self._points, "empty ring"
        h = self._hash(key)
        i = bisect.bisect_right(self._points, (h, float("inf")))
        if i == len(self._points):
            i = 0
        return self._points[i][1]


# --------------------------------------------------------------------------- #
# Router surface
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Router-visible snapshot of one replica at routing time: load and
    capacity are live (the cluster owns both), the digest is the last
    GOSSIPED one — possibly stale, by design."""

    replica: int
    load: int  # queued + active requests
    free_slots: int  # slots not yet spoken for
    queue_s: float = 0.0  # expected wait before this replica admits
    digest: Optional[BloomDigest] = None
    hit_tier: Optional[str] = None  # tier assumed to serve a digest hit


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    replica: int
    matched_tokens: int  # digest-predicted overlap at the chosen replica
    score: float  # the chosen replica's marginal routing cost ($)
    ring_owner: int  # consistent-hash baseline placement


def _qualifying(views: Sequence[ReplicaView]) -> List[ReplicaView]:
    """Capacity filter shared by every router: never pick a replica without
    free capacity while another qualifying one has some."""
    with_room = [v for v in views if v.free_slots > 0]
    return with_room or list(views)


class RoundRobinRouter:
    """Cache-oblivious baseline: cycle through replicas (capacity-filtered)."""

    def __init__(self):
        self._count = itertools.count()
        self.decisions = 0

    def configure(self, **_) -> None:
        pass

    def decide(self, req, views: Sequence[ReplicaView]) -> RouteDecision:
        cands = _qualifying(views)
        v = cands[next(self._count) % len(cands)]
        self.decisions += 1
        return RouteDecision(
            replica=v.replica, matched_tokens=0, score=0.0, ring_owner=-1
        )

    def route(self, req, views: Sequence[ReplicaView]) -> int:
        return self.decide(req, views).replica

    def stats(self) -> dict:
        return {"decisions": self.decisions}


@dataclasses.dataclass
class AffinityRouter:
    """Route to argmin(expected TTFT + $) over the qualifying replicas.

    Per replica the expected overlap is read off its gossiped digest (the
    longest chain-hash prefix of the request's context present in the
    filter), then priced with the cost model's routed-request terms: the
    replica's queue wait, the matched bytes' fetch from its assumed hit
    tier, the suffix prefill of the rest, decode, GPU-idle $ and per-GB
    fees.  The consistent-hash owner breaks score ties, so a cold cluster
    (no digests yet) still converges: identical contexts co-locate on their
    ring owner, which then starts winning on real overlap."""

    vnodes: int = 64
    # $/s weight on expected TTFT added on top of the marginal cost (which
    # already carries the GPU-idle $ of that same delay): None = the compute
    # rate, i.e. latency is deliberately double-weighted toward fast routes.
    ttft_dollars_per_s: Optional[float] = None

    def __post_init__(self):
        self.ring: Optional[ConsistentHashRing] = None
        self.cost_cfg = None
        self.pricing = None
        self.perf = None
        self.chunk_tokens = 256
        self.compression = 1.0
        # decision audit (telemetry absorbs these): how often the digest
        # predicted overlap, and how often the pick was just the ring owner
        self.decisions = 0
        self.predicted_hits = 0
        self.ring_agreements = 0

    def configure(
        self, *, cost_cfg, pricing, perf, chunk_tokens: int,
        replica_ids: Sequence[int], compression: float = 1.0,
    ) -> None:
        self.cost_cfg = cost_cfg
        self.pricing = pricing
        self.perf = perf
        self.chunk_tokens = int(chunk_tokens)
        self.compression = compression
        self.ring = ConsistentHashRing(replica_ids, vnodes=self.vnodes)
        if self.ttft_dollars_per_s is None:
            self.ttft_dollars_per_s = pricing.compute.cost_per_hour / 3600.0

    # -- digest probe ---------------------------------------------------- #
    def expected_match(self, context_tokens, digest: Optional[BloomDigest]) -> int:
        """Digest-predicted prefix overlap, in tokens: the longest chain-hash
        prefix present in the filter (mirrors the trie's longest_prefix, but
        against a stale, probabilistic summary)."""
        if digest is None or digest.n_added == 0:
            return 0
        matched = 0
        for h in chunk_hash_chain(context_tokens, self.chunk_tokens):
            if h not in digest:
                break
            matched += 1
        return matched * self.chunk_tokens

    def _score(self, req, w: Workload, v: ReplicaView) -> tuple:
        matched = self.expected_match(req.context_tokens, v.digest)
        tier = v.hit_tier if matched > 0 else None
        dollars = cost_routed_request(
            self.cost_cfg, w, self.pricing, self.perf,
            matched_tokens=matched, tier=tier, queue_s=v.queue_s,
            compression=self.compression,
        )
        d = delay_routed(
            self.cost_cfg, w, self.perf, self.pricing,
            matched_tokens=matched, tier=tier, queue_s=v.queue_s,
            compression=self.compression,
        )
        return dollars + self.ttft_dollars_per_s * d.ttft_s, matched

    def decide(self, req, views: Sequence[ReplicaView]) -> RouteDecision:
        assert self.ring is not None, "AffinityRouter.configure() first"
        cands = _qualifying(views)
        w = Workload(
            L_context=len(req.context_tokens),
            L_prompt=len(req.prompt_tokens),
            L_output=req.max_new_tokens,
            N=max(int(req.expected_reuses), 1),
            slo_ttft_s=req.slo_ttft_s,
        )
        owner = self.ring.owner(
            hashlib.sha256(
                "|".join(map(str, req.context_tokens)).encode()
            ).hexdigest()
        )
        best = min(
            cands,
            key=lambda v: (
                self._score(req, w, v)[0],
                0 if v.replica == owner else 1,
                v.replica,
            ),
        )
        score, matched = self._score(req, w, best)
        self.decisions += 1
        self.predicted_hits += 1 if matched > 0 else 0
        self.ring_agreements += 1 if best.replica == owner else 0
        return RouteDecision(
            replica=best.replica, matched_tokens=matched,
            score=score, ring_owner=owner,
        )

    def route(self, req, views: Sequence[ReplicaView]) -> int:
        return self.decide(req, views).replica

    def stats(self) -> dict:
        return {
            "decisions": self.decisions,
            "predicted_hits": self.predicted_hits,
            "ring_agreements": self.ring_agreements,
        }
