"""Admission scheduling + straggler mitigation for the serving engine.

Admission: FIFO by arrival with an SLO-aware twist — among admissible
requests, those whose TTFT SLO would be violated by further queueing are
served first (earliest-deadline-first within the arrived set).

Straggler mitigation: storage loads are *hedged* — if a fetch's modeled delay
exceeds ``threshold_s``, a duplicate fetch is issued against a replica and
the tail is served at ``parallelism``-way speed (classic tail-at-scale
request hedging, applied to the paper's KV-load path).  Decode-side straggler
handling (per-chip) lives in training/fault.py notes and DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    threshold_s: float = 0.5
    parallelism: int = 2
    # duplicate fetches cost extra transfer bytes; accounted by the caller
    extra_bytes_factor: float = 0.2

    def effective_delay(self, delay_s: float) -> float:
        if delay_s <= self.threshold_s:
            return delay_s
        return self.threshold_s + (delay_s - self.threshold_s) / self.parallelism


class AdmissionQueue:
    """Requests ordered by (deadline slack, arrival)."""

    def __init__(self):
        self._heap: List = []
        self._n = 0

    def push(self, req: Request) -> None:
        deadline = (
            req.arrival_s + req.slo_ttft_s if req.slo_ttft_s is not None else float("inf")
        )
        heapq.heappush(self._heap, (req.arrival_s, deadline, self._n, req))
        self._n += 1

    def pop_admissible(self, now: float) -> Optional[Request]:
        """Earliest-deadline-first among requests that have arrived."""
        arrived = [e for e in self._heap if e[0] <= now]
        if not arrived:
            return None
        best = min(arrived, key=lambda e: (e[1], e[0], e[2]))
        self._heap.remove(best)
        heapq.heapify(self._heap)
        return best[3]

    def next_arrival(self) -> Optional[float]:
        return min((e[0] for e in self._heap), default=None)

    def peek_arrived(self, now: float, limit: int = 4) -> List[Request]:
        """Arrived-but-unadmitted requests in admission order (no removal) —
        the prefetch lookahead window."""
        arrived = sorted(
            (e for e in self._heap if e[0] <= now), key=lambda e: (e[1], e[0], e[2])
        )
        return [e[3] for e in arrived[:limit]]

    def __len__(self) -> int:
        return len(self._heap)
