"""Admission scheduling + straggler mitigation for the serving engine.

Admission: FIFO by arrival with an SLO-aware twist — among admissible
requests, those whose TTFT SLO would be violated by further queueing are
served first (earliest-deadline-first within the arrived set).

Straggler mitigation: storage loads are *hedged* — if a fetch's modeled delay
exceeds ``threshold_s``, a duplicate fetch is issued against a replica and
the tail is served at ``parallelism``-way speed (classic tail-at-scale
request hedging, applied to the paper's KV-load path).  Decode-side straggler
handling (per-chip) lives in training/fault.py notes and DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    threshold_s: float = 0.5
    parallelism: int = 2
    # duplicate fetches cost extra transfer bytes; accounted by the caller
    extra_bytes_factor: float = 0.2

    def effective_delay(self, delay_s: float) -> float:
        if delay_s <= self.threshold_s:
            return delay_s
        return self.threshold_s + (delay_s - self.threshold_s) / self.parallelism


class AdmissionQueue:
    """Requests ordered by (deadline slack, arrival), as a two-heap scheme.

    ``_pending`` holds not-yet-arrived requests keyed by arrival time;
    ``_promote`` migrates everything whose arrival has passed into ``_ready``,
    an EDF heap keyed by (deadline, arrival, seq).  Pops and pushes are
    O(log n) — the previous implementation linearly scanned and re-heapified
    the whole queue on every pop."""

    def __init__(self):
        self._pending: List = []  # (arrival, seq, deadline, req)
        self._ready: List = []  # (deadline, arrival, seq, req)
        self._seq = 0

    def push(self, req: Request) -> None:
        deadline = (
            req.arrival_s + req.slo_ttft_s if req.slo_ttft_s is not None else float("inf")
        )
        heapq.heappush(self._pending, (req.arrival_s, self._seq, deadline, req))
        self._seq += 1

    def _promote(self, now: float) -> None:
        while self._pending and self._pending[0][0] <= now:
            arrival, seq, deadline, req = heapq.heappop(self._pending)
            heapq.heappush(self._ready, (deadline, arrival, seq, req))

    def pop_admissible(self, now: float) -> Optional[Request]:
        """Earliest-deadline-first among requests that have arrived."""
        self._promote(now)
        if not self._ready:
            return None
        return heapq.heappop(self._ready)[3]

    def next_arrival(self) -> Optional[float]:
        cands = [e[1] for e in self._ready]  # arrived but unadmitted
        if self._pending:
            cands.append(self._pending[0][0])
        return min(cands, default=None)

    def peek_next(self, now: float) -> Optional[Request]:
        """The request ``pop_admissible(now)`` would return, without removing
        it — O(1) (heap root), unlike ``peek_arrived`` which sorts."""
        self._promote(now)
        return self._ready[0][3] if self._ready else None

    def peek_arrived(self, now: float, limit: int = 4) -> List[Request]:
        """Arrived-but-unadmitted requests in admission order (no removal) —
        the prefetch lookahead window."""
        self._promote(now)
        return [e[3] for e in heapq.nsmallest(limit, self._ready)]

    def drain(self) -> List[Request]:
        """Remove and return EVERY queued request (arrived or not) in a
        deterministic order — crash harvesting: a dead replica's queue is
        resubmitted to the survivors through the router."""
        out = [e[3] for e in sorted(self._pending)] + [
            e[3] for e in sorted(self._ready)
        ]
        self._pending.clear()
        self._ready.clear()
        return out

    def __len__(self) -> int:
        return len(self._pending) + len(self._ready)
