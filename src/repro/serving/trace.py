"""JSONL live trace exporter over the engine's typed event stream.

One line per event, written (and flushed) as it arrives, so a crashed or
interrupted run still leaves a readable trace.  Each line is::

    {"event": "TokenEmitted", "t_s": 1.25, "req_id": 3, "token": 17, ...}

— the event class name plus its dataclass fields, recursively serialized
(``RequestFinished`` lines therefore embed the full ``RequestRecord``
including its executed ``ReusePlan``/``FusedSchedule``).  Extra key/values
passed to ``write``/``write_all`` are merged into every line (e.g. a
``mode`` tag when several engine runs share one file, or the ``replica``
tag ``ServingCluster`` writes).

A fresh file starts with one schema header line::

    {"__trace__": {"version": 1, "format": "repro.serving.events"}}

so consumers can detect the schema; ``read_trace`` tolerates it (header
lines never appear among the returned events — the parsed header rides on
the result's ``.header`` attribute).  Non-JSON-native leaves (numpy/jax
scalars and arrays) serialize deterministically as their Python values
instead of crashing mid-run or degrading to ``repr`` strings.

The trace is self-sufficient: ``read_events`` rebuilds TYPED events —
nested plans, fused schedules and records included — whose
``summarize_events`` / ``audit`` / span-tree views match the live stream
exactly (tests/test_obs.py), and ``read_tagged_events`` recovers a
cluster's replica-tagged stream.  ``examples/serve_reuse.py --trace PATH``
wires this exporter into the end-to-end driver.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

TRACE_FORMAT = "repro.serving.events"
TRACE_VERSION = 1
_HEADER_KEY = "__trace__"


def event_to_dict(event: Any, **extra: Any) -> Dict[str, Any]:
    """Flatten one typed event into a JSON-ready dict: class name + fields
    (nested dataclasses — records, plans, fusion schedules — recurse)."""
    out: Dict[str, Any] = {"event": type(event).__name__}
    out.update(dataclasses.asdict(event))
    out.update(extra)
    return out


def _json_default(o: Any) -> Any:
    """Deterministic serialization for non-JSON-native leaves: numpy/jax
    scalars become their Python values, arrays become nested lists, bytes
    hex-encode.  Anything else falls back to ``str`` (never crashes the
    run mid-trace)."""
    import numpy as np

    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (bytes, bytearray)):
        return o.hex()
    if hasattr(o, "__jax_array__") or type(o).__module__.startswith("jax"):
        try:
            return np.asarray(o).tolist()
        except Exception:
            pass
    return str(o)


class TraceWriter:
    """Append-mode JSONL sink for the typed event stream.

    Usage::

        with TraceWriter(path) as tw:
            for event in engine.drain():
                tw.write(event)

    Lines flush per event (live tailing works); ``n_events`` counts what was
    written.  A schema header line is emitted when the file starts empty
    (append mode onto an existing trace inherits its header)."""

    def __init__(self, path, *, append: bool = False):
        self.path = pathlib.Path(path)
        fresh = not (append and self.path.exists() and self.path.stat().st_size)
        self._f = open(self.path, "a" if append else "w")
        self.n_events = 0
        if fresh:
            json.dump(
                {_HEADER_KEY: {"version": TRACE_VERSION, "format": TRACE_FORMAT}},
                self._f,
            )
            self._f.write("\n")
            self._f.flush()

    def write(self, event: Any, **extra: Any) -> None:
        json.dump(event_to_dict(event, **extra), self._f, default=_json_default)
        self._f.write("\n")
        self._f.flush()
        self.n_events += 1

    def write_all(self, events: Iterable[Any], **extra: Any) -> int:
        n = 0
        for e in events:
            self.write(e, **extra)
            n += 1
        return n

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None


class Trace(List[Dict[str, Any]]):
    """``read_trace``'s result: a plain list of event dicts, with the parsed
    schema header (or None for headerless/legacy traces) as ``.header``."""

    header: Optional[Dict[str, Any]] = None


def read_trace(path) -> Trace:
    """Parse a JSONL trace back into event dicts (blank lines skipped).
    Header lines are tolerated and returned via the result's ``.header``
    attribute, never as events."""
    out = Trace()
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        if _HEADER_KEY in d:
            out.header = d[_HEADER_KEY]
        else:
            out.append(d)
    return out


# --------------------------------------------------------------------------- #
# Replay: trace dicts -> typed events
# --------------------------------------------------------------------------- #
def _fused_span(d: Dict[str, Any]):
    from repro.kvcache.fusion import FusedSpan

    return FusedSpan(
        start=d["start"], end=d["end"], kind=d["kind"],
        entry_id=d["entry_id"], src_start=d["src_start"],
        chunk_hashes=tuple(d["chunk_hashes"]),
    )


def _fused_schedule(d: Optional[Dict[str, Any]]):
    if d is None:
        return None
    from repro.kvcache.fusion import CompositeMatch, FusedSchedule

    m = d["match"]
    match = CompositeMatch(
        spans=tuple(_fused_span(s) for s in m["spans"]),
        total_tokens=m["total_tokens"],
        chunk_tokens=m["chunk_tokens"],
    )
    return FusedSchedule(
        match=match,
        recompute_frac=d["recompute_frac"],
        spans=tuple(_fused_span(s) for s in d["spans"]),
        reused_tokens=d["reused_tokens"],
        recompute_tokens=d["recompute_tokens"],
    )


def _plan(d: Optional[Dict[str, Any]]):
    if d is None:
        return None
    from repro.serving.planner import ReusePlan

    return ReusePlan(
        action=d["action"], tier=d["tier"],
        matched_tokens=d["matched_tokens"],
        reused_fraction=d["reused_fraction"],
        fetch_bytes=d["fetch_bytes"], store_after=d["store_after"],
        est_ttft_s=d["est_ttft_s"], est_cost=d["est_cost"],
        fused=_fused_schedule(d.get("fused")),
    )


def _record(d: Dict[str, Any]):
    from repro.serving.request import RequestRecord

    return RequestRecord(
        req_id=d["req_id"], arrival_s=d["arrival_s"],
        context_len=d["context_len"], prompt_len=d["prompt_len"],
        tokens=list(d["tokens"]), action=d["action"],
        matched_tokens=d["matched_tokens"], plan=_plan(d.get("plan")),
        start_s=d["start_s"], load_s=d["load_s"],
        prefill_s=d["prefill_s"], decode_s=d["decode_s"],
        finish_s=d["finish_s"], compute_cost=d["compute_cost"],
        degraded=d.get("degraded", False),  # absent in pre-faults traces
    )


def event_from_dict(d: Dict[str, Any]):
    """One trace line back into its typed event (extra tags — ``mode``,
    ``replica`` — are ignored; nested plans/records/schedules rebuild as
    the original dataclasses, tuples restored)."""
    from repro.serving import events as ev

    cls = getattr(ev, d["event"], None)
    if cls is None or not dataclasses.is_dataclass(cls):
        raise ValueError(f"unknown event class in trace: {d['event']!r}")
    kw: Dict[str, Any] = {}
    for f in dataclasses.fields(cls):
        v = d[f.name]
        if f.name == "plan":
            v = _plan(v)
        elif f.name == "record":
            v = _record(v)
        elif f.name == "req_ids":
            v = tuple(v)
        kw[f.name] = v
    return cls(**kw)


def events_from_dicts(dicts: Iterable[Dict[str, Any]]) -> List[Any]:
    return [event_from_dict(d) for d in dicts]


def read_events(path) -> List[Any]:
    """Typed event stream from a saved trace — the replay entry point:
    ``summarize_events``/``audit``/``obs.build_spans`` over the result
    match the live stream exactly."""
    return events_from_dicts(read_trace(path))


def read_tagged_events(path) -> List[Tuple[int, Any]]:
    """Replica-tagged typed events from a cluster trace (lines carry the
    ``replica`` extra ``ServingCluster`` writes; untagged lines land on
    replica 0) — feeds ``obs.build_cluster_spans`` and
    ``audit.cluster_audit`` the same shapes the live cluster produces."""
    return [
        (int(d.get("replica", 0)), event_from_dict(d)) for d in read_trace(path)
    ]
