"""JSONL live trace exporter over the engine's typed event stream.

One line per event, written (and flushed) as it arrives, so a crashed or
interrupted run still leaves a readable trace.  Each line is::

    {"event": "TokenEmitted", "t_s": 1.25, "req_id": 3, "token": 17, ...}

— the event class name plus its dataclass fields, recursively serialized
(``RequestFinished`` lines therefore embed the full ``RequestRecord``
including its executed ``ReusePlan``/``FusedSchedule``).  Extra key/values
passed to ``write``/``write_all`` are merged into every line (e.g. a
``mode`` tag when several engine runs share one file).

Any consumer that kept only the trace file can rebuild the same views the
in-process stream supports: ``read_trace`` parses it back into dicts, and
``serving.audit`` / ``serving.metrics.summarize_events`` keep working on the
live objects.  ``examples/serve_reuse.py --trace PATH`` wires this exporter
into the end-to-end driver.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, Iterable, List, Optional


def event_to_dict(event: Any, **extra: Any) -> Dict[str, Any]:
    """Flatten one typed event into a JSON-ready dict: class name + fields
    (nested dataclasses — records, plans, fusion schedules — recurse)."""
    out: Dict[str, Any] = {"event": type(event).__name__}
    out.update(dataclasses.asdict(event))
    out.update(extra)
    return out


class TraceWriter:
    """Append-mode JSONL sink for the typed event stream.

    Usage::

        with TraceWriter(path) as tw:
            for event in engine.drain():
                tw.write(event)

    Lines flush per event (live tailing works); ``n_events`` counts what was
    written.  Non-JSON-native leaves (numpy scalars, jax arrays) degrade to
    ``str`` rather than failing the run.
    """

    def __init__(self, path, *, append: bool = False):
        self.path = pathlib.Path(path)
        self._f = open(self.path, "a" if append else "w")
        self.n_events = 0

    def write(self, event: Any, **extra: Any) -> None:
        json.dump(event_to_dict(event, **extra), self._f, default=str)
        self._f.write("\n")
        self._f.flush()
        self.n_events += 1

    def write_all(self, events: Iterable[Any], **extra: Any) -> int:
        n = 0
        for e in events:
            self.write(e, **extra)
            n += 1
        return n

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None


def read_trace(path) -> List[Dict[str, Any]]:
    """Parse a JSONL trace back into event dicts (blank lines skipped)."""
    out: List[Dict[str, Any]] = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out
