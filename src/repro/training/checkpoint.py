"""Checkpointing: atomic, manifest-driven, elastic-reshape-capable.

Layout (one directory per step):
  <dir>/step_000123/
      manifest.json     — step, tree structure, leaf shapes/dtypes, status
      leaves.npz        — flat leaf arrays keyed by index

Guarantees:
  * atomicity — writes go to ``step_X.tmp-<pid>`` then ``os.replace`` to the
    final name; a crash mid-write never corrupts the latest checkpoint;
  * auto-resume — ``latest_step``/``restore`` pick the newest COMPLETE step;
  * elastic reshape — leaves are stored unsharded (host gathers), so a
    restore binds to ANY mesh/data-axis size: the caller re-shards via its
    current in_shardings (tested in tests/test_checkpoint.py);
  * bounded retention — ``keep`` newest checkpoints survive.

On a real multi-host pod this writes per-host shard files instead of a host
gather; the manifest/atomic-rename/resume logic is unchanged (DESIGN.md §7).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(
    ckpt_dir: str | Path,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    extra_meta: Optional[Dict] = None,
) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "leaves.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "written_at": time.time(),
        "complete": True,
        **(extra_meta or {}),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.glob("step_*") if not p.name.endswith("tmp"))
    steps = [p for p in steps if (p / "manifest.json").exists()]
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in ckpt_dir.glob("*.tmp-*"):
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    best = None
    for p in sorted(ckpt_dir.glob("step_*")):
        mf = p / "manifest.json"
        if not mf.exists():
            continue  # incomplete (crashed mid-write before publish)
        try:
            m = json.loads(mf.read_text())
        except json.JSONDecodeError:
            continue
        if m.get("complete"):
            best = m["step"]
    return best


def restore(ckpt_dir: str | Path, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked).  Returns
    (tree, step).  ``like`` may be arrays or ShapeDtypeStructs on any mesh —
    leaves come back as host numpy for the caller to device_put/shard."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "leaves.npz")

    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves_like)}"
    )
    out: List[np.ndarray] = []
    for i, tgt in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(tgt.shape), (
            f"leaf {i}: checkpoint {arr.shape} vs target {tgt.shape}"
        )
        out.append(arr.astype(tgt.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Fire-and-forget background saves (device->host copy happens on the
    caller thread; serialization runs on a worker so the train loop keeps
    stepping)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, **kw) -> None:
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # sync copy
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep": self.keep, **kw}, daemon=True,
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
