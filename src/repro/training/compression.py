"""Gradient compression for cross-pod all-reduce (shard_map-based).

At 512+ chips the ``pod`` axis crosses data-center interconnect; int8
gradient all-reduce cuts that traffic 4x vs fp32 (2x vs bf16).  Scheme:

  s      = pmax(|g|_inf) / 127        (shared scale across the axis)
  q      = round(g / s)  : int8       (wire format)
  g_hat  = psum(q) * s   / n          (mean gradient, dequantised)

Error is bounded by s/2 per element per participant (tested).  The public
entry point wraps a grads pytree; axes not present on the mesh no-op.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def compressed_pmean(x: jax.Array, axis_name: str) -> jax.Array:
    """Int8-quantised mean-all-reduce over ``axis_name`` (inside shard_map)."""
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(x.dtype)


def make_compressed_grad_sync(mesh: Mesh, axis_name: str = "pod"):
    """Returns sync(grads) -> grads with the cross-``axis_name`` mean taken
    through the int8 wire format.  Grads are assumed replicated over
    ``axis_name`` pre-sync (each pod computed its own microbatch mean)."""
    if axis_name not in mesh.axis_names:
        return lambda grads: grads

    from jax.experimental.shard_map import shard_map

    def sync(grads: Any) -> Any:
        def per_leaf(g):
            spec = P(*([None] * g.ndim))

            fn = shard_map(
                functools.partial(compressed_pmean, axis_name=axis_name),
                mesh=mesh,
                in_specs=spec,
                out_specs=spec,
                check_rep=False,
            )
            return fn(g)

        return jax.tree_util.tree_map(per_leaf, grads)

    return sync
