"""Fault-tolerant training loop: checkpoint/restart, preemption survival,
straggler mitigation.

``ResilientLoop`` wraps a jitted train step with:
  * periodic async checkpoints + auto-resume from the newest complete one;
  * preemption simulation (an injectable failure hook — tests kill the loop
    mid-run and assert bit-exact continuation after restart);
  * straggler mitigation: per-step deadline tracking with an EMA of step
    time; steps exceeding ``straggler_factor``x the EMA are counted and
    surface in metrics (on a real pod this triggers the hedged re-dispatch
    documented in DESIGN.md §7 — here the detection path is what we can
    exercise).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.training import checkpoint as ckpt


class Preempted(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    straggler_factor: float = 3.0
    ema_beta: float = 0.9


class ResilientLoop:
    def __init__(
        self,
        step_fn: Callable,  # (params, opt_state, batch) -> (params, opt_state, metrics)
        batch_fn: Callable[[int], Any],  # step -> batch
        cfg: LoopConfig,
        *,
        failure_hook: Optional[Callable[[int], None]] = None,  # may raise Preempted
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.failure_hook = failure_hook
        self.ckpt = ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.stragglers = 0
        self._ema_s: Optional[float] = None

    def run(self, params: Any, opt_state: Any) -> Dict[str, Any]:
        """Run (or resume) to total_steps.  On entry, restores the newest
        complete checkpoint if one exists — making restart-after-preemption
        a plain re-invocation."""
        start = 0
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is not None:
            (params, opt_state), start = ckpt.restore(
                self.cfg.ckpt_dir, (params, opt_state)
            )
        metrics = {}
        for step in range(start, self.cfg.total_steps):
            if self.failure_hook is not None:
                self.failure_hook(step)  # may raise Preempted mid-training

            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            if self._ema_s is not None and dt > self.cfg.straggler_factor * self._ema_s:
                self.stragglers += 1
            self._ema_s = (
                dt
                if self._ema_s is None
                else self.cfg.ema_beta * self._ema_s + (1 - self.cfg.ema_beta) * dt
            )

            done = step + 1
            if done % self.cfg.ckpt_every == 0 or done == self.cfg.total_steps:
                self.ckpt.save(done, (params, opt_state))
        self.ckpt.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "metrics": {k: np.asarray(v) for k, v in metrics.items()},
            "stragglers": self.stragglers,
            "completed": self.cfg.total_steps,
        }
