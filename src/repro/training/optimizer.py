"""AdamW (hand-rolled, dependency-free) + ZeRO-style optimizer sharding.

Moments are fp32 regardless of param dtype.  ``opt_specs`` extends the param
PartitionSpec tree so that for pure-DP archs the moments are additionally
sharded over the data axis (ZeRO-1): the largest unsharded, divisible dim of
each moment gets the ``data`` axis.  FSDP archs already shard params (and
hence moments) over ``data``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import Params


class AdamState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    # optional learning-rate schedule: step -> multiplier
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def init(self, params: Params) -> AdamState:
        zeros32 = lambda x: jnp.zeros(x.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros32, params),
            v=jax.tree_util.tree_map(zeros32, params),
        )

    def update(
        self, grads: Params, state: AdamState, params: Params
    ) -> Tuple[Params, AdamState]:
        step = state.step + 1
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

        if self.grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(g32))
            )
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, g32)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g), state.v, g32
        )
        t = step.astype(jnp.float32)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamState(step=step, m=m, v=v)


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn


# --------------------------------------------------------------------------- #
# ZeRO sharding of optimizer state
# --------------------------------------------------------------------------- #
def opt_specs(param_spec_tree: Any, params_shapes: Any, mesh: Mesh) -> Any:
    """Moment specs: param spec + ZeRO-1 data-sharding of any moment whose
    param is not already data-sharded (largest divisible unsharded dim)."""
    d = mesh.shape["data"] if "data" in mesh.axis_names else 1

    def one(spec: P, x) -> P:
        parts = list(spec) + [None] * (len(x.shape) - len(spec))
        if "data" in parts or d <= 1:
            return P(*parts)
        # pick the largest unsharded divisible dim for ZeRO-1 sharding
        best, best_dim = -1, -1
        for i, (p, dim) in enumerate(zip(parts, x.shape)):
            if p is None and dim % d == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0:
            parts[best] = "data"
        return P(*parts)

    m_specs = jax.tree_util.tree_map(
        one, param_spec_tree, params_shapes, is_leaf=lambda s: isinstance(s, P)
    )
    return AdamState(step=P(), m=m_specs, v=m_specs)
