"""Training step factory: loss, grad, optimizer update, metrics.

One factory serves every family: the batch dict keys select the forward
signature (decoder-only / VLM embeds / enc-dec frames).  MoE aux
(load-balancing) loss is folded in with a standard 0.01 coefficient,
normalised by MoE layer count.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm, registry
from repro.training.optimizer import AdamW, AdamState

AUX_COEF = 0.01


def loss_fn(
    params: Any, cfg: ArchConfig, batch: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    api = registry.get_model(cfg)
    if cfg.family == "encdec":
        logits, aux = api.forward(params, cfg, batch["frames"], batch["dec_tokens"])
    elif "embeds" in batch:
        logits, aux = api.forward(
            params, cfg, batch["tokens"], embeds=batch["embeds"]
        )
    else:
        logits, aux = api.forward(params, cfg, batch["tokens"])
    ce = lm.cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + AUX_COEF * aux / jnp.maximum(cfg.n_layers, 1)
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, opt: AdamW):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics) — pure, jit/pjit-ready."""

    def train_step(params, opt_state: AdamState, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **parts, "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_grad_accum_step(cfg: ArchConfig, opt: AdamW, accum: int):
    """Microbatched variant: splits the batch on axis 0 into ``accum`` chunks,
    accumulating fp32 grads via lax.scan (activation-memory / HBM trade)."""

    def step(params, opt_state: AdamState, batch):
        def micro(carry, mb):
            g_acc, l_acc = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            return (g_acc, l_acc + l), None

        micro_batches = jax.tree_util.tree_map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
        )
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
        grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss_sum / accum, "step": opt_state.step}

    return step
