"""Optional-hypothesis shim: property tests degrade to skips, not collection
errors, when hypothesis isn't installed (it lives in the ``test`` extra of
pyproject.toml, which not every environment installs).

Usage in test modules::

    from _hypothesis_compat import given, settings, st

With hypothesis present these are the real objects.  Without it, ``st``
builds inert placeholder strategies and ``@given`` replaces the test with a
skip — so non-property tests in the same file still collect and run.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised when dep absent
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder: supports the strategy-combinator calls made at
        module import time; never actually draws values."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed (pip install .[test])")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
