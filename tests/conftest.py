import os

# Tests and benches run on the single real CPU device; ONLY launch/dryrun.py
# forces 512 placeholder devices (per its module docstring).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
