"""Per-assigned-architecture smoke tests on REDUCED same-family configs:
one forward + one train step on CPU, asserting output shapes and no NaNs
(the FULL configs are exercised only via the dry-run, per the assignment).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced_config
from repro.models import registry
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

ARCHS = sorted(ASSIGNED)


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "encdec":
        dl = min(cfg.decoder_seq_len, 16)
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, 32, cfg.d_model)), jnp.float32
            ),
            "dec_tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, dl)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, dl)), jnp.int32),
            "mask": jnp.ones((B, dl), jnp.float32),
        }
    if cfg.family == "vlm":
        ft = cfg.frontend_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - ft)), jnp.int32),
            "embeds": jnp.asarray(
                rng.standard_normal((B, ft, cfg.d_model)) * 0.02, jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "mask": jnp.ones((B, S), jnp.float32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    # ---- forward: shape + finiteness ---------------------------------- #
    if cfg.family == "encdec":
        logits, _ = api.forward(params, cfg, batch["frames"], batch["dec_tokens"])
        want = batch["dec_tokens"].shape + (cfg.padded_vocab,)
    elif cfg.family == "vlm":
        logits, _ = api.forward(params, cfg, batch["tokens"], embeds=batch["embeds"])
        want = batch["labels"].shape + (cfg.padded_vocab,)
    else:
        logits, _ = api.forward(params, cfg, batch["tokens"])
        want = batch["tokens"].shape + (cfg.padded_vocab,)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # ---- one optimizer step -------------------------------------------- #
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params2, opt_state, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(metrics["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2)
        )
    )
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """prefill(all) == forward(last); one decode step matches forward."""
    cfg = reduced_config(get_config(arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    B, S = 2, 16

    if cfg.family == "encdec":
        frames = jnp.asarray(rng.standard_normal((B, 32, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        logits, _ = api.forward(params, cfg, frames, toks)
        state = api.init_state(cfg, B, 64, enc_len=32)
        last, state = api.prefill(params, cfg, toks, state, embeds=frames)
    else:
        kwargs = {}
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        if cfg.family == "vlm":
            kwargs["embeds"] = jnp.asarray(
                rng.standard_normal((B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        logits, _ = api.forward(params, cfg, toks, **kwargs)
        state = api.init_state(cfg, B, 64)
        last, state = api.prefill(params, cfg, toks, state, **kwargs)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits[:, -1]), rtol=3e-4, atol=3e-4
    )

    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    ld, _ = api.decode(params, cfg, nxt, state)
    assert ld.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(ld).all())
