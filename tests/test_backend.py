"""StorageBackend protocol conformance, run against both shipped backends,
plus ContextStore-over-backend integration (eviction pricing, demotion)."""
import numpy as np
import pytest

from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER, GB
from repro.kvcache.backend import (
    HostMemoryBackend,
    ObjectStoreBackend,
    StorageBackend,
    default_backends,
)
from repro.kvcache.hierarchy import DiskSpillBackend, RpcBackend
from repro.kvcache.store import ContextStore
from repro.kvcache.transfer import SimClock, TransferModel
from repro.serving.scheduler import HedgePolicy


def _transfer():
    return TransferModel(PerfModel(V100_X4_HF), AWS_PAPER)


BACKENDS = {
    "host_dram": HostMemoryBackend,
    "io2": ObjectStoreBackend,
    "local_nvme": DiskSpillBackend,
    "peer_dram": RpcBackend,
}


@pytest.fixture(params=sorted(BACKENDS))
def backend(request):
    clock = SimClock(start=5.0)
    cls = BACKENDS[request.param]
    return cls(request.param, transfer=_transfer(), clock=clock)


class TestConformance:
    def test_satisfies_protocol(self, backend):
        assert isinstance(backend, StorageBackend)

    def test_put_get_roundtrip(self, backend):
        payload = {"k": np.arange(12.0)}
        h = backend.put("a", payload, nbytes=96.0)
        assert h.kind == "store" and h.tier == backend.name and h.nbytes == 96.0
        assert h.issued_at_s == 5.0
        assert h.completes_at_s == pytest.approx(5.0 + h.delay_s)
        assert backend.contains("a") and not backend.contains("b")
        got, h2 = backend.get("a")
        np.testing.assert_array_equal(got["k"], payload["k"])  # disk: a copy
        assert h2.kind == "load" and h2.nbytes == 96.0 and h2.delay_s > 0

    def test_partial_read_bills_fraction(self, backend):
        backend.put("a", object(), nbytes=1000.0)
        _, full = backend.get("a")
        _, half = backend.get("a", nbytes=500.0)
        assert half.nbytes == 500.0
        assert half.delay_s < full.delay_s

    def test_delete_and_peek(self, backend):
        payload = [1, 2, 3]
        backend.put("a", payload, nbytes=24.0)
        loaded0 = backend.transfer.stats[backend.name].load_events
        assert backend.peek("a") == payload
        assert backend.transfer.stats[backend.name].load_events == loaded0  # free
        assert backend.delete("a") and not backend.contains("a")
        assert not backend.delete("a")

    def test_missing_key_error_names_tier_and_key(self, backend):
        with pytest.raises(KeyError, match=f"{backend.name}.*'ghost'"):
            backend.get("ghost")
        with pytest.raises(KeyError, match=f"{backend.name}.*'ghost'"):
            backend.peek("ghost")

    def test_negative_nbytes_rejected(self, backend):
        with pytest.raises(ValueError, match=f"nbytes.*{backend.name}"):
            backend.put("a", object(), nbytes=-1.0)
        assert not backend.contains("a")

    def test_transfer_accounting(self, backend):
        backend.put("a", object(), nbytes=100.0)
        backend.get("a")
        s = backend.transfer.stats[backend.name]
        assert s.stored_bytes == 100.0 and s.store_events == 1
        assert s.loaded_bytes == 100.0 and s.load_events == 1
        backend.put("b", object(), nbytes=50.0, charge=False)  # tier migration
        assert s.stored_bytes == 100.0 and s.store_events == 1

    def test_estimate_charges_nothing(self, backend):
        backend.put("a", object(), nbytes=100.0)
        s = backend.transfer.stats[backend.name]
        before = (s.loaded_bytes, s.load_events)
        est = backend.estimate_load_delay(100.0)
        _, h = backend.get("a")
        assert est == pytest.approx(h.delay_s)
        assert (s.loaded_bytes, s.load_events) == (before[0] + 100.0, before[1] + 1)

    def test_no_transfer_model_means_zero_delay(self):
        b = HostMemoryBackend()
        b.put("a", object(), nbytes=1e12)
        _, h = b.get("a")
        assert h.delay_s == 0.0 and b.estimate_load_delay(1e12) == 0.0


def test_hedged_object_store_caps_tail():
    hedge = HedgePolicy(threshold_s=1e-4, parallelism=2)
    plain = ObjectStoreBackend("s3", transfer=_transfer())
    hedged = ObjectStoreBackend("s3", transfer=_transfer(), hedge=hedge)
    nbytes = 5 * GB
    plain.put("a", object(), nbytes=nbytes)
    hedged.put("a", object(), nbytes=nbytes)
    _, hp = plain.get("a")
    _, hh = hedged.get("a")
    assert hh.delay_s == pytest.approx(hedge.effective_delay(hp.delay_s))
    assert hh.delay_s < hp.delay_s
    # the duplicate fetch doesn't hide the billed bytes
    assert hedged.transfer.stats["s3"].loaded_bytes == nbytes


def test_default_backends_tier_mapping():
    b = default_backends(["host_dram", "io2", "s3"], hedge=HedgePolicy())
    assert isinstance(b["host_dram"], HostMemoryBackend)
    assert isinstance(b["io2"], ObjectStoreBackend)
    assert isinstance(b["s3"], ObjectStoreBackend)
    assert b["host_dram"].hedge is None  # local reads have no straggler tail
    assert b["io2"].hedge is not None


class TestStoreOverBackends:
    def _store(self, **kw):
        clock = SimClock()
        return ContextStore(
            tier_capacities_gb={"host_dram": 1.0, "io2": 1.0},
            clock=clock, chunk_tokens=4, **kw,
        )

    def test_payloads_live_in_backends(self):
        s = self._store()
        art = {"k": np.ones((2, 8), np.float32)}
        eid, _ = s.put(list(range(8)), art, tier="io2")
        assert s.backends["io2"].contains(eid)
        assert not s.backends["host_dram"].contains(eid)
        got, _ = s.fetch(eid)
        np.testing.assert_array_equal(got["k"], art["k"])

    def test_demote_moves_payload_between_backends(self):
        s = self._store()
        art = {"k": np.ones((2, 8), np.float32)}
        eid, _ = s.put(list(range(8)), art, tier="host_dram")
        assert s.demote(eid, "io2")
        assert s.backends["io2"].contains(eid)
        assert not s.backends["host_dram"].contains(eid)
        assert s.entries[eid].tier == "io2"
        got, _ = s.fetch(eid)
        np.testing.assert_array_equal(got["k"], art["k"])

    def test_eviction_deletes_backend_payload_and_uses_pricing(self):
        s = ContextStore(
            tier_capacities_gb={"io2": 1e-6},  # 1 KB
            clock=SimClock(), chunk_tokens=4, pricing=AWS_PAPER,
        )
        first = None
        for i in range(4):
            art = {"k": np.full((1, 150), i, np.float32)}  # 600 B each
            eid, _ = s.put(list(range(i * 100, i * 100 + 8)), art, tier="io2")
            first = first or eid
        assert s.evictions > 0
        assert not s.backends["io2"].contains(first)
        assert s._gb_hour_rate("io2") == AWS_PAPER.tier("io2").cost_per_gb_hour

    def test_missing_backend_for_tier_rejected(self):
        with pytest.raises(AssertionError):
            ContextStore(
                tier_capacities_gb={"io2": 1.0, "gp3": 1.0},
                backends={"io2": ObjectStoreBackend("io2")},
            )
