"""Checkpointing: atomic roundtrip, auto-resume, preemption survival with
bit-exact continuation, elastic reshape."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.data.synthetic import token_batches
from repro.models import registry
from repro.training import checkpoint as ckpt
from repro.training.fault import LoopConfig, Preempted, ResilientLoop
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def _tiny():
    cfg = reduced_config(get_config("qwen2-0.5b"), n_layers=2, vocab=64)
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3)
    return cfg, params, opt


def test_roundtrip_and_latest(tmp_path):
    _, params, opt = _tiny()
    tree = (params, opt.init(params))
    ckpt.save(tmp_path, 7, tree)
    ckpt.save(tmp_path, 13, tree)
    assert ckpt.latest_step(tmp_path) == 13
    restored, step = ckpt.restore(tmp_path, tree)
    assert step == 13
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_gc(tmp_path):
    _, params, opt = _tiny()
    for s in range(6):
        ckpt.save(tmp_path, s, params, keep=2)
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_incomplete_checkpoint_ignored(tmp_path):
    _, params, opt = _tiny()
    ckpt.save(tmp_path, 3, params)
    # simulate a crash mid-write at a later step: directory without manifest
    (tmp_path / "step_00000009").mkdir()
    assert ckpt.latest_step(tmp_path) == 3
    _, step = ckpt.restore(tmp_path, params)
    assert step == 3


def test_shape_mismatch_rejected(tmp_path):
    _, params, opt = _tiny()
    ckpt.save(tmp_path, 1, params)
    bad = jax.tree_util.tree_map(lambda x: np.zeros(x.shape + (2,), x.dtype), params)
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, bad)


def test_preemption_resume_bit_exact(tmp_path):
    """Kill training mid-run; re-invoking the loop restores and the final
    params are IDENTICAL to an uninterrupted run (same data order)."""
    cfg, params0, opt = _tiny()
    step_fn = jax.jit(make_train_step(cfg, opt))
    batches = [
        {k: jnp.asarray(v) for k, v in b.items()}
        for _, b in zip(range(12), token_batches(cfg, batch=4, seq_len=16, seed=1))
    ]
    batch_fn = lambda i: batches[i]

    # uninterrupted reference
    ref_dir = tmp_path / "ref"
    loop = ResilientLoop(step_fn, batch_fn,
                         LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(ref_dir)))
    ref = loop.run(params0, opt.init(params0))

    # preempted run: dies at step 6 (after the step-4 checkpoint)
    pre_dir = tmp_path / "pre"

    def bomb(step):
        if step == 6:  # after the async step-4 checkpoint was initiated
            raise Preempted("simulated preemption")

    loop1 = ResilientLoop(step_fn, batch_fn,
                          LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(pre_dir)),
                          failure_hook=bomb)
    with pytest.raises(Preempted):
        loop1.run(params0, opt.init(params0))
    loop1.ckpt.wait()
    assert ckpt.latest_step(pre_dir) == 4

    # plain re-invocation resumes from step 4 and finishes
    loop2 = ResilientLoop(step_fn, batch_fn,
                          LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(pre_dir)))
    out = loop2.run(params0, opt.init(params0))
    for a, b in zip(
        jax.tree_util.tree_leaves(ref["params"]),
        jax.tree_util.tree_leaves(out["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshape_restore(tmp_path):
    """Checkpoints are mesh-agnostic: save under one (data, model) layout,
    restore under another and shard explicitly — values identical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    _, params, _ = _tiny()
    ckpt.save(tmp_path, 1, params)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    restored, _ = ckpt.restore(tmp_path, params)
    sharded = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P())), restored
    )
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(sharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
