"""Chunked prefill: bit-exact parity with the dense suffix-prefill path.

The unified continuous-batching kernel pyramid, mirroring
tests/test_paged_decode.py:

  * kernel  — ``ref.chunked_prefill_ref`` vs the dense attention oracle on
    MIXED rows (prefill chunks, decode rows, idle padding), the C=1 decode
    degenerate case vs ``paged_decode_ref``, and the Pallas kernel
    (interpret mode) vs the jnp oracle;
  * model   — ``lm.prefill_chunked`` landing a prompt chunk-by-chunk while a
    second slot decodes in the SAME launches vs per-slot dense
    suffix-prefill/decode over real reduced archs (logits AND pool-resident
    KV rows, exact);
  * engine  — tests/test_unified.py (full-serve token parity, burst p99).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.kernels import ops, ref
from repro.kvcache import paged
from repro.models import registry


# --------------------------------------------------------------------------- #
# Kernel level
# --------------------------------------------------------------------------- #
def _mixed_case(rows, KV, hd, block, max_len, C, seed=0):
    """Random pool + tables for a mixed batch.

    ``rows`` is a list of (n_landed, n_chunk): a decode row is
    (L, 1)-with-chunk-positions [L-1], a prefill row (n_ctx, c) carries chunk
    positions [n_ctx, n_ctx+c), an idle row is (0, 0).  The chunk tokens' KV
    is already *in* the pool (the kernel contract is attention-only; the
    scatter happens at the model level), so n_landed counts them.
    """
    rng = np.random.default_rng(seed)
    B = len(rows)
    nb = max_len // block
    n_blocks = 1 + B * nb
    pool_k = rng.standard_normal((n_blocks * block, KV, hd)).astype(np.float32)
    pool_v = rng.standard_normal((n_blocks * block, KV, hd)).astype(np.float32)
    tables = np.zeros((B, nb), np.int32)
    dense_k = np.zeros((B, max_len, KV, hd), np.float32)
    dense_v = np.zeros((B, max_len, KV, hd), np.float32)
    q_pos = np.full((B, C), -(2**30), np.int32)
    nxt = 1
    for b, (n_landed, n_chunk) in enumerate(rows):
        total = n_landed
        for j in range(-(-total // block)) if total else []:
            tables[b, j] = nxt
            sl = slice(nxt * block, (nxt + 1) * block)
            dense_k[b, j * block : (j + 1) * block] = pool_k[sl]
            dense_v[b, j * block : (j + 1) * block] = pool_v[sl]
            nxt += 1
        if n_chunk:
            q_pos[b, :n_chunk] = np.arange(total - n_chunk, total)
    q = rng.standard_normal((B, C, 2 * KV, hd)).astype(np.float32)
    # dense mirror covers all max_len == nb*block rows; masked rows differ in
    # content but contribute exactly 0, so outputs are bitwise equal
    kv_pos = np.broadcast_to(np.arange(max_len, dtype=np.int32)[None], (B, max_len))
    return dict(
        q=q, pool_k=pool_k, pool_v=pool_v, tables=tables, q_pos=q_pos,
        dense_k=dense_k, dense_v=dense_v, kv_pos=kv_pos,
    )


MIXED_ROWS = [(97, 32), (128, 1), (0, 0), (40, 8)]  # prefill, decode, idle, tail


@pytest.mark.parametrize(
    "KV,window", [(4, None), (2, None), (2, 96)]  # MHA, GQA, GQA+window
)
def test_chunked_ref_matches_dense_ref_exactly(KV, window):
    """Gathering pool rows through the table and attending a MIXED batch of
    chunk/decode/idle rows is BITWISE the dense attention over equivalent
    slotted caches — block-boundary chunks, dump-block padding, -2^30 query
    padding included."""
    c = _mixed_case(MIXED_ROWS, KV=KV, hd=16, block=32, max_len=128, C=32)
    got = ref.chunked_prefill_ref(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=32, window=window,
    )
    want = ref.attention_ref(
        jnp.asarray(c["q"]), jnp.asarray(c["dense_k"]), jnp.asarray(c["dense_v"]),
        q_pos=jnp.asarray(c["q_pos"]), kv_pos=jnp.asarray(c["kv_pos"]),
        causal=True, window=window,
    )
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # idle row emits exactly zeros
    assert np.all(np.asarray(got)[2] == 0.0)


def test_chunked_ref_c1_is_paged_decode():
    """The C=1 degenerate case IS paged decode: same gather, same mask."""
    c = _mixed_case([(5, 1), (97, 1), (128, 1)], KV=2, hd=16, block=32,
                    max_len=128, C=1, seed=3)
    got = ref.chunked_prefill_ref(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=32,
    )
    want = ref.paged_decode_ref(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=32,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@pytest.mark.parametrize("KV,window", [(4, None), (2, None), (2, 200)])
def test_chunked_pallas_interpret_matches_ref(KV, window):
    """The Pallas kernel (interpret mode) agrees with the jnp oracle —
    exercises the scalar-prefetch table indirection, the [C, G] flash
    recurrence, chunk padding and dump-block masking."""
    from repro.kernels import chunked_prefill as cpk

    c = _mixed_case(
        [(130, 64), (257, 1), (0, 0), (384, 128)], KV=KV, hd=16, block=128,
        max_len=384, C=128, seed=5,
    )
    want = ref.chunked_prefill_ref(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=128, window=window,
    )
    got = cpk.chunked_prefill_attention(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=128, window=window, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6, rtol=2e-6)


def test_ops_chunked_prefill_dispatches_on_cpu():
    c = _mixed_case([(9, 4), (40, 1)], KV=2, hd=8, block=16, max_len=48, C=8,
                    seed=7)
    out = ops.chunked_prefill(
        jnp.asarray(c["q"]), jnp.asarray(c["pool_k"]), jnp.asarray(c["pool_v"]),
        block_table=jnp.asarray(c["tables"]), q_pos=jnp.asarray(c["q_pos"]),
        block=16,
    )
    assert out.shape == c["q"].shape and np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------- #
# Model level
# --------------------------------------------------------------------------- #
def _setup(arch, seed=0):
    cfg = reduced_config(get_config(arch))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return cfg, api, params


ATOL = 5e-6  # cross-launch-shape fp32 tolerance: a 1-row legacy decode
# matmul (gemv) and a C-row mixed launch (gemm) reduce in different orders,
# so logits agree to ~1e-6 — tokens (argmax) must still be IDENTICAL, which
# is the engine-level acceptance contract.  Same-shape comparisons (the
# kernel level above) stay bitwise.


@pytest.mark.parametrize("arch", ["llama-7b", "qwen2-1.5b", "olmoe-1b-7b"])
def test_model_prefill_chunked_token_exact(arch):
    """lm.prefill_chunked == dense suffix prefill while a decode row rides
    in the SAME launches: slot 0 lands its prompt chunk-by-chunk (crossing a
    block boundary mid-chunk stream), slot 1 decodes one token per launch —
    final prefill logits, every decode logit, and the pool-resident KV rows
    match the per-slot dense paths to ATOL, and every argmax token is
    identical."""
    cfg, api, params = _setup(arch)
    rng = np.random.default_rng(2)
    max_len, block, C = 64, 16, 16
    ctx0, prompt0 = 13, 24  # chunks land 13->37 across block boundaries
    ctx1 = 37
    B = 2

    # dense references: slot 0 suffix-prefills prompt0 after ctx0; slot 1
    # decodes from ctx1
    toks0 = list(map(int, rng.integers(0, cfg.vocab, ctx0 + prompt0)))
    toks1 = list(map(int, rng.integers(0, cfg.vocab, ctx1)))
    st0_ctx = api.init_state(cfg, 1, max_len)
    _, st0_ctx = api.prefill(
        params, cfg, jnp.asarray([toks0[:ctx0]], jnp.int32), st0_ctx
    )
    want_logits0, st0 = api.prefill(
        params, cfg, jnp.asarray([toks0[ctx0:]], jnp.int32), st0_ctx
    )
    st1 = api.init_state(cfg, 1, max_len)
    _, st1 = api.prefill(params, cfg, jnp.asarray([toks1], jnp.int32), st1)

    # paged mirror: blocks for the FULL totals upfront (the unified engine's
    # intake), but only the already-computed context rows landed
    ps = paged.PagedSlots(B, max_len, block)
    caches = paged.init_pool_caches(cfg, ps.pool.n_blocks, block, dtype=jnp.float32)
    ps.admit(0, ctx0 + prompt0)
    ps.admit(1, ctx1)
    new = []
    for ki, c in enumerate(caches):
        k, v = c.attn.k, c.attn.v
        for b, (st, L) in enumerate(((st0_ctx, ctx0), (st1, ctx1))):
            nb = -(-L // block)
            dst = paged.block_rows(ps.tables[b, :nb], block)[:L]
            k = k.at[:, dst].set(st.caches[ki].attn.k[:, 0, :L].astype(k.dtype))
            v = v.at[:, dst].set(st.caches[ki].attn.v[:, 0, :L].astype(v.dtype))
        new.append(paged.BlockCache(paged.KVCache(k, v), None))
    caches = tuple(new)

    # interleaved chunk stream: slot 0 lands C-grained chunks, slot 1 decodes
    dtoks = jnp.asarray([[5]], jnp.int32)
    landed = ctx0
    dec_len = ctx1
    got_logits0 = None
    step = 0
    while landed < ctx0 + prompt0:
        n_new = min(C, ctx0 + prompt0 - landed)
        tok_row0 = toks0[landed : landed + n_new] + [0] * (C - n_new)
        pos_row0 = list(range(landed, landed + n_new)) + [-(2**30)] * (C - n_new)
        tok_row1 = [int(dtoks[0, 0])] + [0] * (C - 1)
        pos_row1 = [dec_len] + [-(2**30)] * (C - 1)
        tokens = jnp.asarray([tok_row0, tok_row1], jnp.int32)
        q_pos = jnp.asarray([pos_row0, pos_row1], jnp.int32)
        last_idx = jnp.asarray([n_new - 1, 0], jnp.int32)
        logits, caches = api.prefill_chunked(
            params, cfg, tokens, caches,
            block_table=jnp.asarray(ps.tables), q_pos=q_pos, last_idx=last_idx,
            block=block,
        )
        landed += n_new
        if landed == ctx0 + prompt0:
            got_logits0 = logits[0]
        # dense decode reference for slot 1, lockstep
        want_dec, st1 = api.decode(params, cfg, dtoks, st1)
        np.testing.assert_allclose(
            np.asarray(logits[1]), np.asarray(want_dec[0]), atol=ATOL, rtol=ATOL,
            err_msg=f"{arch} step {step}",
        )
        assert int(jnp.argmax(logits[1])) == int(jnp.argmax(want_dec[0])), (
            arch, step)
        dec_len += 1
        dtoks = jnp.argmax(want_dec, axis=-1)[:, None].astype(jnp.int32)
        step += 1

    np.testing.assert_allclose(
        np.asarray(got_logits0), np.asarray(want_logits0[0]), atol=ATOL, rtol=ATOL,
        err_msg=arch,
    )
    assert int(jnp.argmax(got_logits0)) == int(jnp.argmax(want_logits0[0])), arch

    # pool rows == dense cache rows for every live token of both slots
    for b, (st, L) in enumerate(((st0, ctx0 + prompt0), (st1, dec_len))):
        nb = -(-L // block)
        rows = paged.block_rows(ps.tables[b, :nb], block)[:L]
        for ki in range(len(caches)):
            got_k = np.asarray(caches[ki].attn.k[:, rows])
            want_k = np.asarray(st.caches[ki].attn.k[:, 0, :L])
            np.testing.assert_allclose(
                got_k, want_k, atol=ATOL, rtol=ATOL, err_msg=f"{arch} {b} {ki}"
            )
