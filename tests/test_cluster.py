"""Cluster serving: shared-cold-tier ownership (refcounts, dedup, crash
safety), router invariants, bloom-staleness tolerance, 1-replica golden
parity, and copy-then-keep rebalancing — deterministic + hypothesis."""
import json
from collections import Counter

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import test_serving as ts
from repro.core.perf_model import PerfModel, V100_X4_HF
from repro.core.pricing import AWS_PAPER
from repro.configs import get_config
from repro.kvcache.hierarchy import (
    HostMemoryBackend,
    SharedBackendCore,
    SharedTierBackend,
    TieredStore,
    TierSpec,
)
from repro.kvcache.transfer import SimClock, TransferModel
from repro.serving import (
    AffinityRouter,
    AlwaysReusePlanner,
    ClusterConfig,
    CostAwarePlanner,
    EngineConfig,
    Request,
    RoundRobinRouter,
    ServingCluster,
)
from repro.serving import events as ev
from repro.serving.router import BloomDigest, ReplicaView, RouteDecision


def _transfer():
    return TransferModel(PerfModel(V100_X4_HF), AWS_PAPER)


def _art(i, floats=150):
    return {"k": np.full((1, floats), i, np.float32)}  # 4*floats bytes


def _shared_stores(n=2, cap_gb=1.0):
    """N stores, each host_dram + a namespaced view onto ONE shared s3 core."""
    core = SharedBackendCore()
    stores = []
    for i in range(n):
        clock = SimClock()
        tr = _transfer()
        backends = {
            "host_dram": HostMemoryBackend(
                "host_dram", transfer=tr, clock=clock
            ),
            "s3": SharedTierBackend(
                "s3", core=core, namespace=f"r{i}", transfer=tr, clock=clock
            ),
        }
        stores.append(
            TieredStore(
                tiers=[TierSpec("host_dram", cap_gb), TierSpec("s3", cap_gb)],
                transfer=tr, clock=clock, chunk_tokens=4,
                pricing=AWS_PAPER, backends=backends,
            )
        )
    return core, stores


def check_core_invariants(core, stores):
    """The shared tier's conservation laws, checked after every mutation:
    refcounts equal live key counts, every key resolves, resident bytes are
    the sum over DISTINCT contents (dedup), and every store's own s3 entries
    stay readable — no replica can orphan another's entry."""
    cnt = Counter(core._keys.values())
    assert dict(core._refs) == dict(cnt)
    assert set(core._contents) == set(cnt)
    stats = core.stats()
    assert stats["resident_bytes"] == pytest.approx(
        sum(nb for _, nb in core._contents.values())
    )
    assert stats["logical_bytes"] >= stats["resident_bytes"]
    for s in stores:
        for eid, e in s.entries.items():
            if e.tier == "s3":
                assert s.backends["s3"]._read(eid) is not None


# --------------------------------------------------------------------------- #
# Shared cold tier: dedup, refcounted ownership, crash safety
# --------------------------------------------------------------------------- #
class TestSharedColdTier:
    def test_dedup_and_byte_conservation(self):
        core, (s0, s1) = _shared_stores(2)
        toks = list(range(8))
        e0, _ = s0.put(toks, _art(1), tier="s3")
        e1, _ = s1.put(toks, _art(1), tier="s3")  # identical content
        check_core_invariants(core, [s0, s1])
        st_ = core.stats()
        assert st_["n_keys"] == 2 and st_["n_contents"] == 1
        assert st_["dedup_hits"] == 1
        assert st_["logical_bytes"] == 2 * st_["resident_bytes"]
        # each replica is billed its own logical bytes regardless of dedup
        assert s0.tiers["s3"].used_bytes == s1.tiers["s3"].used_bytes

        # one replica evicts: the payload must survive for the other
        assert s0._evict_one("s3")
        check_core_invariants(core, [s0, s1])
        assert core.stats()["n_contents"] == 1
        art, h = s1.fetch(e1)
        assert art is not None and np.allclose(art["k"], 1.0)

        # last owner evicts: content is actually reclaimed
        assert s1._evict_one("s3")
        check_core_invariants(core, [s1])
        assert core.stats() == {
            "n_contents": 0, "n_keys": 0, "resident_bytes": 0,
            "logical_bytes": 0,
            "dedup_saved_bytes": core.stats()["dedup_saved_bytes"],
            "dedup_hits": 1,
        }

    def test_replica_crash_orphans_nothing(self):
        core, stores = _shared_stores(3)
        # overlapping working sets: ctx0 on all three, ctx1 on r0+r1, ctx2 r0
        ctxs = [list(range(i * 8, i * 8 + 8)) for i in range(3)]
        stores[0].put(ctxs[0], _art(0), tier="s3")
        stores[0].put(ctxs[1], _art(1), tier="s3")
        stores[0].put(ctxs[2], _art(2), tier="s3")
        stores[1].put(ctxs[0], _art(0), tier="s3")
        stores[1].put(ctxs[1], _art(1), tier="s3")
        stores[2].put(ctxs[0], _art(0), tier="s3")
        check_core_invariants(core, stores)
        assert core.stats()["n_contents"] == 3

        # r0 crashes out: its keys release, shared content survives
        released = stores[0].backends["s3"].release_namespace()
        assert released == 3
        check_core_invariants(core, stores[1:])
        assert core.stats()["n_contents"] == 2  # ctx2 died with its only owner
        for s, eids in ((stores[1], 2), (stores[2], 1)):
            assert len(s.entries) == eids
            for eid in s.entries:
                art, _ = s.fetch(eid)
                assert art is not None

    @settings(max_examples=25, deadline=None)
    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["put", "evict", "crash"]),
                st.integers(0, 1),  # store index
                st.integers(0, 4),  # context index
            ),
            min_size=1, max_size=30,
        )
    )
    def test_ops_conserve_shared_bytes(self, ops):
        """Any interleaving of puts / evictions / a namespace crash keeps the
        shared core's refcounts and byte accounting exact, and never makes a
        surviving store's entry unreadable."""
        core, stores = _shared_stores(2)
        crashed = [False, False]
        for op, si, ci in ops:
            s = stores[si]
            if crashed[si]:
                continue
            if op == "put":
                s.put(list(range(ci * 8, ci * 8 + 8)), _art(ci), tier="s3")
            elif op == "evict":
                s._evict_one("s3")
            else:
                s.backends["s3"].release_namespace()
                s.entries.clear()  # the replica is gone; drop its metadata
                for t in s.tiers.values():
                    t.used_bytes = 0.0
                crashed[si] = True
            live = [x for x, c in zip(stores, crashed) if not c]
            check_core_invariants(core, live)
        # terminal state: resident bytes exactly cover the distinct contents
        stats = core.stats()
        assert stats["resident_bytes"] == sum(
            nb for _, nb in core._contents.values()
        )


# --------------------------------------------------------------------------- #
# Router invariants
# --------------------------------------------------------------------------- #
def _affinity_router(n=3):
    r = AffinityRouter()
    r.configure(
        cost_cfg=get_config("llama-7b"), pricing=AWS_PAPER,
        perf=PerfModel(V100_X4_HF), chunk_tokens=16,
        replica_ids=list(range(n)),
    )
    return r


def _req(ctx=None):
    return Request(
        req_id=0, context_tokens=ctx or list(range(64)),
        prompt_tokens=list(range(8)), max_new_tokens=4,
    )


class TestRouterInvariants:
    @settings(max_examples=50, deadline=None)
    @given(
        frees=st.lists(st.integers(0, 3), min_size=2, max_size=5),
        loads=st.lists(st.integers(0, 6), min_size=5, max_size=5),
        with_digest=st.booleans(),
    )
    def test_never_routes_to_full_replica_when_another_has_room(
        self, frees, loads, with_digest
    ):
        n = len(frees)
        digest = None
        if with_digest:
            digest = BloomDigest()
            digest.update([f"h{i}" for i in range(4)])
        views = [
            ReplicaView(
                replica=i, load=loads[i % len(loads)], free_slots=frees[i],
                queue_s=0.1 * loads[i % len(loads)], digest=digest,
                hit_tier="host_dram",
            )
            for i in range(n)
        ]
        req = _req()
        for router in (_affinity_router(n), RoundRobinRouter()):
            d = router.decide(req, views)
            assert 0 <= d.replica < n
            if any(f > 0 for f in frees):
                assert frees[d.replica] > 0, (frees, d.replica)

    def test_full_replica_skipped_deterministic(self):
        """Deterministic mirror of the hypothesis property: replica 1 holds
        the whole context but has no free slot — both routers must divert to
        a replica with room."""
        ctx = list(range(64))
        holder = BloomDigest()
        from repro.kvcache.chunks import chunk_hash_chain

        holder.update(chunk_hash_chain(ctx, 16))
        views = [
            ReplicaView(replica=0, load=1, free_slots=1, digest=None,
                        hit_tier="host_dram"),
            ReplicaView(replica=1, load=4, free_slots=0, digest=holder,
                        hit_tier="host_dram", queue_s=0.2),
        ]
        req = _req(ctx)
        for router in (_affinity_router(2), RoundRobinRouter()):
            for _ in range(4):
                assert router.decide(req, views).replica == 0
        # when NO replica has room, the affinity pick comes back
        views_full = [
            ReplicaView(replica=0, load=4, free_slots=0, digest=None,
                        hit_tier="host_dram", queue_s=0.2),
            views[1],
        ]
        assert _affinity_router(2).decide(req, views_full).replica == 1

    def test_affinity_prefers_digest_owner_when_costs_allow(self):
        router = _affinity_router(2)
        ctx = list(range(64))
        holder = BloomDigest()
        from repro.kvcache.chunks import chunk_hash_chain

        holder.update(chunk_hash_chain(ctx, 16))
        views = [
            ReplicaView(replica=0, load=0, free_slots=2, digest=None,
                        hit_tier="host_dram"),
            ReplicaView(replica=1, load=0, free_slots=2, digest=holder,
                        hit_tier="host_dram"),
        ]
        d = router.decide(_req(ctx), views)
        assert d.replica == 1 and d.matched_tokens == 64

    def test_cold_cluster_coloates_on_ring_owner(self):
        """No digests yet: identical contexts must still pick the SAME
        replica (the consistent-hash owner), so the first write-back lands
        where future traffic will look for it."""
        router = _affinity_router(3)
        views = [
            ReplicaView(replica=i, load=0, free_slots=2) for i in range(3)
        ]
        ctx = list(range(64))
        picks = {router.decide(_req(ctx), views).replica for _ in range(5)}
        assert len(picks) == 1
        assert picks == {router.decide(_req(ctx), views).ring_owner}


# --------------------------------------------------------------------------- #
# Cluster end-to-end
# --------------------------------------------------------------------------- #
SPECS = [
    TierSpec("host_dram", 1.0),
    TierSpec("local_nvme", 1.0),
    TierSpec("s3", 1.0),
]


def _cluster_ec(**kw):
    # cost_arch: price routing/planning at llama-7b scale while the actual
    # compute is the reduced arch — on the paper's V100+AWS numbers a
    # host_dram hit strictly beats recompute, so affinity has something to
    # win (at toy scale recompute is always cheapest and the router would
    # correctly ignore the cache).
    base = dict(
        max_slots=2, max_len=128, chunk_tokens=16,
        tier_specs=SPECS, store_tier="host_dram", cost_arch="llama-7b",
    )
    base.update(kw)
    return EngineConfig(**base)


def _paper_hw():
    return dict(pricing=AWS_PAPER, perf=PerfModel(V100_X4_HF))


class TestClusterServing:
    def test_one_replica_golden_parity(self):
        """A 1-replica cluster behind the affinity router IS the engine: the
        golden seed trace replays action- and cost-identically through it."""
        golden = json.loads(ts.GOLDEN.read_text())
        cfg, params = ts._setup("llama-7b")
        for name, (reqs, kw) in ts._golden_scenarios(cfg, params).items():
            kw = dict(kw)
            planner = kw.pop("planner", None)
            ec = EngineConfig(max_slots=2, max_len=128, chunk_tokens=16, **kw)
            cl = ServingCluster(
                cfg, params,
                cluster_cfg=ClusterConfig(n_replicas=1),
                engine_cfg=ec,
                planner_factory=(lambda p=planner: p) if planner else None,
            )
            for r in reqs:
                cl.submit(Request(**r))
            s = cl.run()
            want = golden[name]
            recs = sorted(cl.replicas[0].records, key=lambda r: r.req_id)
            assert len(recs) == len(want["records"]), name
            for rec, w in zip(recs, want["records"]):
                assert rec.action == w["action"], (name, rec.req_id)
                assert rec.matched_tokens == w["matched_tokens"], (
                    name, rec.req_id)
                for field in ("load_s", "prefill_s", "decode_s", "start_s",
                              "finish_s", "compute_cost"):
                    assert getattr(rec, field) == pytest.approx(
                        w[field], abs=1e-9
                    ), (name, rec.req_id, field)
            got = cl.replicas[0].summary().as_dict()
            for k, v in want["summary"].items():
                assert got[k] == pytest.approx(v, abs=1e-9), (name, k)
            assert s.n_requests == len(want["records"])

    def test_bloom_false_positives_cost_but_never_corrupt(self):
        """Force EVERY digest probe to hit (the worst staleness/FP case):
        routing is mispriced, but the landing replica recomputes what it
        doesn't hold — generated tokens are identical to a bare engine's."""
        cfg, params = ts._setup("qwen2-0.5b")
        reqs = ts._requests(cfg, n=8, n_ctx=2, ctx_len=64, prompt_len=8,
                            new=4, seed=0)
        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(n_replicas=2, gossip_interval_s=0.0),
            engine_cfg=_cluster_ec(),
            planner_factory=AlwaysReusePlanner,
            **_paper_hw(),
        )
        lying = BloomDigest()
        lying._bits = (1 << lying.m) - 1  # every probe answers "present"
        lying.n_added = 1
        cl._digests = [lying, lying]
        for r in reqs:
            cl.submit(Request(**r))
        cl.run()
        routed = [e for _, e in cl.events
                  if isinstance(e, ev.RequestRouted)]
        assert routed and all(e.matched_tokens == 64 for e in routed)

        eng, _, tok_ref, _ = ts._run(
            cfg, params, reqs, planner=AlwaysReusePlanner(),
            tier_specs=SPECS, store_tier="host_dram",
        )
        tok_cl = {rec.req_id: rec.tokens for rec in cl.records}
        assert tok_cl == tok_ref

    def test_rebalance_moves_hot_entry_toward_traffic(self):
        """Copy-then-keep: traffic for a context concentrates on a replica
        that does not hold its KV; rebalancing copies the donor's bytes into
        the target's hot tier (event-verified) with the donor's copy alive
        throughout, and the target then serves loads locally."""
        cfg, params = ts._setup("qwen2-0.5b")
        ctx = list(range(64))
        prompt = list(range(100, 108))

        # materialize a valid stored artifact via a throwaway engine
        seed_req = dict(req_id=0, context_tokens=ctx, prompt_tokens=prompt,
                        max_new_tokens=4, arrival_s=0.0, expected_reuses=4)
        donor_eng, _, _, _ = ts._run(
            cfg, params, [seed_req], planner=AlwaysReusePlanner(),
            tier_specs=SPECS, store_tier="host_dram",
        )
        (eid, entry), = donor_eng.store.entries.items()
        art = donor_eng.store.backends[entry.tier].peek(eid)
        assert art is not None

        class ScriptedRouter:
            """Pin every request on replica 1 (the non-holder)."""

            def configure(self, **_):
                pass

            def decide(self, req, views):
                return RouteDecision(replica=1, matched_tokens=0,
                                     score=0.0, ring_owner=-1)

        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(
                n_replicas=2, gossip_interval_s=0.05,
                rebalance_interval_s=0.05, rebalance_min_hits=2,
            ),
            engine_cfg=_cluster_ec(store_write_back=False),
            router=ScriptedRouter(),
            planner_factory=AlwaysReusePlanner,
            **_paper_hw(),
        )
        # replica 0 holds the context; nothing ever writes back (the
        # cost-aware "local frequency below break-even" regime)
        ck = cl.replicas[0].store.content_key(ctx)
        e0, _ = cl.replicas[0].store.put(
            ctx, art, tier="host_dram", saved_per_use=entry.saved_per_use
        )
        assert e0 is not None

        for i, t in enumerate((0.1, 0.4, 0.7)):
            cl.submit(Request(
                req_id=i, context_tokens=ctx, prompt_tokens=prompt,
                max_new_tokens=4, arrival_s=t, expected_reuses=4,
            ))
        cl.run()

        reb = [e for _, e in cl.events if isinstance(e, ev.ReplicaRebalanced)]
        assert len(reb) == 1 and cl.rebalances == 1
        r = reb[0]
        assert (r.from_replica, r.to_replica, r.content_key) == (0, 1, ck)
        # no unreachable window: the donor's copy survived the whole run...
        assert cl.replicas[0].store.entries[e0].content_key == ck
        # ...and the target now holds its own hot-tier copy
        tgt = [e for e in cl.replicas[1].store.entries.values()
               if e.content_key == ck]
        assert len(tgt) == 1 and tgt[0].tier == "host_dram"
        # the copy landed between arrivals: the last request LOADED locally
        recs = sorted(cl.replicas[1].records, key=lambda x: x.req_id)
        assert [x.action for x in recs][:1] == ["recompute"]
        assert recs[-1].action == "load" and recs[-1].matched_tokens == 64

    def test_affinity_beats_round_robin_on_hit_rate(self):
        """The economics headline at fleet scale: affinity routing keeps each
        context's traffic on one replica, so aggregate hit rate strictly
        beats cache-oblivious round-robin on a skewed reuse workload."""
        cfg, params = ts._setup("qwen2-0.5b")
        reqs = ts._requests(cfg, n=16, n_ctx=3, ctx_len=64, prompt_len=8,
                            new=4, seed=1)
        # spread arrivals so capacity pressure never overrides affinity
        for i, r in enumerate(reqs):
            r["arrival_s"] = i * 0.2

        def run(router):
            cl = ServingCluster(
                cfg, params,
                cluster_cfg=ClusterConfig(
                    n_replicas=2, gossip_interval_s=0.05
                ),
                engine_cfg=_cluster_ec(),
                router=router,
                planner_factory=AlwaysReusePlanner,
                **_paper_hw(),
            )
            for r in reqs:
                cl.submit(Request(**r))
            return cl, cl.run()

        cl_a, s_a = run(None)  # AffinityRouter default
        cl_r, s_r = run(RoundRobinRouter())
        assert s_a.n_requests == s_r.n_requests == 16
        assert s_a.hit_rate > s_r.hit_rate, (s_a.hit_rate, s_r.hit_rate)
        # identical tokens either way (routing never changes outputs)
        tok_a = {r.req_id: r.tokens for r in cl_a.records}
        tok_r = {r.req_id: r.tokens for r in cl_r.records}
        assert tok_a == tok_r

    def test_remove_replica_releases_only_its_shared_keys(self):
        cfg, params = ts._setup("qwen2-0.5b")
        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(n_replicas=2),
            engine_cfg=_cluster_ec(store_tier="s3"),
            **_paper_hw(),
        )
        ctx0, ctx1 = list(range(64)), list(range(64, 128))
        cl.replicas[0].store.put(ctx0, _art(0), tier="s3")
        cl.replicas[1].store.put(ctx0, _art(0), tier="s3")  # dedup'd twin
        cl.replicas[1].store.put(ctx1, _art(1), tier="s3")
        assert cl.core.stats() == dict(
            cl.core.stats(), n_keys=3, n_contents=2, dedup_hits=1
        )
        released = cl.remove_replica(0)
        assert released == 1
        stats = cl.core.stats()
        assert stats["n_keys"] == 2 and stats["n_contents"] == 2
        for eid in cl.replicas[1].store.entries:
            art, _ = cl.replicas[1].store.fetch(eid)
            assert art is not None
        # the removed replica is invisible to routing and the idle predicate
        assert all(v.replica == 1 for v in cl.views())
        assert cl.idle


# --------------------------------------------------------------------------- #
# Delta gossip: incremental digests are bit-identical to full rebuilds
# --------------------------------------------------------------------------- #
class TestDeltaGossip:
    def _check_equiv(self, cl):
        """The staleness-equivalence invariant: after any gossip tick, each
        live replica's incrementally-maintained digest has EXACTLY the bits
        a from-scratch rebuild over the store's current hash surface would
        produce — delta shipping changes the wire bytes, never the answer."""
        for i, eng in enumerate(cl.replicas):
            if not cl._alive[i]:
                continue
            fresh = BloomDigest(cl.cc.digest_bits, cl.cc.digest_hashes)
            fresh.update(eng.store.digest_hashes())
            assert cl._digests[i]._bits == fresh._bits, i

    def test_delta_ticks_equal_full_rebuild(self):
        cfg, params = ts._setup("qwen2-0.5b")
        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(n_replicas=2),
            engine_cfg=_cluster_ec(),
            **_paper_hw(),
        )
        store = cl.replicas[0].store

        cl.gossip_now()  # first tick: both replicas full-sync from scratch
        self._check_equiv(cl)
        base_full = cl.gossip_full_syncs
        assert base_full == 2

        # put-only window: every tick ships only the add-set, no resyncs
        eids = []
        for j in range(4):
            eid, _ = store.put(
                [j * 50 + k for k in range(32)], _art(j), tier="host_dram"
            )
            eids.append(eid)
            cl.gossip_now()
            self._check_equiv(cl)
        assert cl.gossip_full_syncs == base_full
        assert cl.gossip_delta_hashes > 0

        # a removal (discard) bumps the digest epoch: bloom bits cannot be
        # cleared, so the next tick full-rebuilds — and stays exact
        assert store.discard(eids[1])
        cl.gossip_now()
        self._check_equiv(cl)
        assert cl.gossip_full_syncs == base_full + 1

        # an eviction is a removal too
        assert store._evict_one("host_dram")
        cl.gossip_now()
        self._check_equiv(cl)
        assert cl.gossip_full_syncs == base_full + 2

        # and after a resync, deltas resume
        deltas = cl.gossip_delta_hashes
        store.put(list(range(900, 932)), _art(9), tier="host_dram")
        cl.gossip_now()
        self._check_equiv(cl)
        assert cl.gossip_full_syncs == base_full + 2
        assert cl.gossip_delta_hashes > deltas

    def test_quiescent_ticks_ship_nothing(self):
        """No store mutations between ticks => no hashes, no resyncs (the
        steady-state wire cost of gossip is zero)."""
        cfg, params = ts._setup("qwen2-0.5b")
        cl = ServingCluster(
            cfg, params,
            cluster_cfg=ClusterConfig(n_replicas=2),
            engine_cfg=_cluster_ec(),
            **_paper_hw(),
        )
        cl.replicas[0].store.put(list(range(32)), _art(0), tier="host_dram")
        cl.gossip_now()
        full, deltas = cl.gossip_full_syncs, cl.gossip_delta_hashes
        for _ in range(3):
            cl.gossip_now()
            self._check_equiv(cl)
        assert cl.gossip_full_syncs == full
        assert cl.gossip_delta_hashes == deltas
