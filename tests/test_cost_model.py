"""The paper's §2 analytical model, validated against the paper's own numbers
plus hypothesis property tests of its structure."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.cost_model import (
    Workload,
    break_even_reuses,
    cost_kv,
    cost_ratio,
    cost_text,
    delay_kv,
    delay_text,
    s_storage_bytes,
    simplified_ratio,
)
from repro.core.perf_model import PerfModel, V100_X1_PAPER, V100_X4_HF, tpu_v5e
from repro.core.pricing import AWS_PAPER, GB

LLAMA = get_config("llama-7b")
PM = PerfModel(V100_X4_HF)


# --------------------------------------------------------------------------- #
# Paper-number checks (§2 Insights, footnotes 1-2)
# --------------------------------------------------------------------------- #
class TestPaperNumbers:
    def test_kv_size_10k_tokens_is_5p2_gb(self):
        s = s_storage_bytes(LLAMA, 10_000)
        assert s / GB == pytest.approx(5.24, abs=0.1)  # paper: "5.2 GB"

    def test_storage_cost_per_hour_matches_8p8e4(self):
        # io2: $0.125 / GB-month (paper ref [1])
        per_hour = AWS_PAPER.tier("io2").cost_per_gb_hour * s_storage_bytes(
            LLAMA, 10_000
        ) / GB
        assert per_hour == pytest.approx(8.8e-4, rel=0.1)

    def test_prefill_cost_matches_0p0058(self):
        pm1 = PerfModel(V100_X1_PAPER)
        t = pm1.t_prefill(LLAMA, 10_000)
        dollars = 3.0 / 3600.0 * t
        assert dollars == pytest.approx(5.8e-3, rel=0.15)  # paper footnote 2

    def test_prefill_cost_over_7x_storage(self):
        """Paper: prefill cost 'already more than 7 times larger' than the
        hourly storage+transmission cost."""
        pm1 = PerfModel(V100_X1_PAPER)
        prefill = 3.0 / 3600.0 * pm1.t_prefill(LLAMA, 10_000)
        storage = AWS_PAPER.tier("io2").cost_per_gb_hour * s_storage_bytes(
            LLAMA, 10_000
        ) / GB
        assert prefill / storage > 6.0

    def test_break_even_is_about_once_per_hour(self):
        """Paper: 'more economical as long as the context is reused more than
        once per hour'."""
        w = Workload(L_context=10_000, L_prompt=32, L_output=32, N=1)
        n_star = break_even_reuses(LLAMA, w, AWS_PAPER, PM)
        assert n_star is not None and n_star <= 3

    def test_delay_saving_band_at_10k(self):
        """Fig 2(a) at 10K input: delay saving toward the 2.9x end."""
        w = Workload(L_context=10_000, L_prompt=32, L_output=32, N=5)
        dt = delay_text(LLAMA, w, PM)
        dk = delay_kv(LLAMA, w, PM, tier=AWS_PAPER.tier("io2"))
        assert 1.5 <= dt.e2e_s / dk.e2e_s <= 4.0

    def test_cost_saving_band(self):
        """Fig 2 cost-saving envelope: 1.3-4.5x across the paper's sweeps."""
        w = Workload(L_context=10_000, L_prompt=32, L_output=32, N=5)
        r = cost_ratio(LLAMA, w, AWS_PAPER, PM)
        assert 1.3 <= r <= 4.5


# --------------------------------------------------------------------------- #
# Structural properties (hypothesis)
# --------------------------------------------------------------------------- #
wl = st.builds(
    Workload,
    L_context=st.integers(512, 40_000),
    L_prompt=st.integers(1, 256),
    L_output=st.integers(1, 512),
    N=st.integers(1, 200),
)


class TestProperties:
    @settings(max_examples=30, deadline=None)
    @given(w=wl)
    def test_costs_positive_and_storage_small(self, w):
        ck = cost_kv(LLAMA, w, AWS_PAPER, PM)
        assert ck.compute > 0 and ck.storage >= 0 and ck.transmission >= 0
        # paper insight: storage is a minimal portion of total cost
        assert ck.storage < 0.25 * ck.total

    @settings(max_examples=30, deadline=None)
    @given(w=wl)
    def test_ratio_grows_with_reuse_count(self, w):
        r1 = cost_ratio(LLAMA, w, AWS_PAPER, PM)
        r2 = cost_ratio(LLAMA, dataclasses.replace(w, N=w.N + 50), AWS_PAPER, PM)
        assert r2 >= r1 - 1e-9

    @settings(max_examples=30, deadline=None)
    @given(w=wl)
    def test_simplified_ratio_approximates_full_model(self, w):
        """The paper's closed form drops storage+transmission (pushing it
        above the full ratio) but also assumes prefill additivity —
        T_p(Lc+Lp) ~= T_p(Lc)+T_p(Lp) — which the quadratic attention term
        violates slightly in the other direction.  So: >= 1 always, and the
        full model never exceeds it by more than the attention
        superadditivity margin (a few %)."""
        simp = simplified_ratio(LLAMA, w, PM)
        full = cost_ratio(LLAMA, w, AWS_PAPER, PM)
        assert simp >= 1.0
        assert full <= simp * 1.05

    @settings(max_examples=20, deadline=None)
    @given(w=wl, comp=st.sampled_from([0.5, 1.0]))
    def test_compression_never_hurts(self, w, comp):
        full = cost_kv(LLAMA, w, AWS_PAPER, PM, compression=1.0).total
        half = cost_kv(LLAMA, w, AWS_PAPER, PM, compression=comp).total
        assert half <= full + 1e-12

    @settings(max_examples=20, deadline=None)
    @given(
        L=st.integers(1_000, 64_000),
        arch=st.sampled_from(
            ["llama-7b", "granite-34b", "mixtral-8x22b", "mamba2-1.3b", "jamba-1.5-large-398b"]
        ),
    )
    def test_storage_bytes_structure(self, L, arch):
        cfg = get_config(arch)
        s = s_storage_bytes(cfg, L)
        assert s > 0
        if cfg.family == "ssm":
            # O(1) in L for attention-free archs
            assert s == s_storage_bytes(cfg, 2 * L)
        elif cfg.sliding_window:
            assert s_storage_bytes(cfg, 10 * cfg.sliding_window) == s_storage_bytes(
                cfg, 20 * cfg.sliding_window
            )
        else:
            assert s_storage_bytes(cfg, 2 * L) > s

    def test_mqa_cheaper_to_store_than_mha(self):
        """granite's MQA (kv=1) stores ~48x less than llama MHA per layer."""
        g = get_config("granite-34b")
        per_tok_g = g.kv_bytes_per_token() / g.n_layers
        per_tok_l = LLAMA.kv_bytes_per_token() / LLAMA.n_layers
        assert per_tok_l / per_tok_g == pytest.approx(32.0, rel=0.01)

    def test_tpu_target_also_benefits(self):
        """Beyond-paper: the model extrapolated to the TPU v5e target still
        favours reuse for long contexts."""
        pm = PerfModel(tpu_v5e(8, hosts=1))
        w = Workload(L_context=32_768, L_prompt=64, L_output=64, N=10)
        from repro.core.pricing import tpu_v5e_pod

        assert cost_ratio(LLAMA, w, tpu_v5e_pod(8), pm) > 1.0
