"""Fault injection and failure handling: the typed error taxonomy, checksum
integrity, the seeded deterministic injector, cost-aware retries, graceful
degradation to recompute, and cluster crash recovery.

The headline properties (deterministic mirrors + hypothesis chaos): under ANY
seeded fault schedule — transient fetch failures, in-flight corruption, tier
brownouts, a mid-run replica crash — every request still finishes with tokens
bitwise-identical to the fault-free run, and the cost ledger still conserves
against the serving summary at 1e-9."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced_config
from repro.kvcache.backend import HostMemoryBackend
from repro.kvcache.faults import (
    CorruptPayload,
    FaultInjector,
    KeyNotFound,
    RetryPolicy,
    StorageError,
    TierUnavailable,
    payload_checksum,
    retryable,
)
from repro.kvcache.hierarchy import DiskSpillBackend, TieredStore, TierSpec
from repro.models import registry
from repro.obs import Telemetry
from repro.serving import (
    AlwaysReusePlanner,
    ClusterConfig,
    EngineConfig,
    Request,
    ServingCluster,
    ServingEngine,
)
from repro.serving import events as ev
from repro.serving.scheduler import AdmissionQueue


# --------------------------------------------------------------------------- #
# Typed errors
# --------------------------------------------------------------------------- #
class TestTypedErrors:
    def test_retryable_classification(self):
        assert retryable(TierUnavailable("x", tier="s3"))
        assert retryable(CorruptPayload("x", at_rest=False))
        assert not retryable(CorruptPayload("x", at_rest=True))
        assert not retryable(KeyNotFound("x", tier="s3", key="k"))
        assert not retryable(ValueError("not a storage error"))

    def test_key_not_found_is_a_key_error(self):
        # back-compat: pre-existing ``except KeyError`` call sites keep working
        with pytest.raises(KeyError):
            raise KeyNotFound("gone", tier="host_dram", key="k")

    def test_error_carries_accounting_context(self):
        e = TierUnavailable("drop", tier="s3", key="k", delay_s=0.25,
                            wasted_bytes=1024.0, reason="unavailable")
        assert (e.tier, e.key, e.delay_s, e.wasted_bytes, e.reason) == \
            ("s3", "k", 0.25, 1024.0, "unavailable")
        assert isinstance(e, StorageError)


# --------------------------------------------------------------------------- #
# Content checksum
# --------------------------------------------------------------------------- #
class TestChecksum:
    def test_container_identity_irrelevant(self):
        a = {"k": np.arange(6, dtype=np.float32), "v": [1, 2, (3, "s")]}
        b = {"k": np.arange(6, dtype=np.float32), "v": [1, 2, (3, "s")]}
        assert payload_checksum(a) == payload_checksum(b)

    def test_content_change_detected(self):
        a = {"k": np.zeros(4, np.float32)}
        b = {"k": np.zeros(4, np.float32)}
        b["k"][2] = 1e-7
        assert payload_checksum(a) != payload_checksum(b)

    def test_dtype_and_shape_matter(self):
        assert payload_checksum(np.zeros(4, np.float32)) != \
            payload_checksum(np.zeros(4, np.float64))
        assert payload_checksum(np.zeros((2, 2), np.float32)) != \
            payload_checksum(np.zeros(4, np.float32))


# --------------------------------------------------------------------------- #
# Seeded injector
# --------------------------------------------------------------------------- #
class TestInjector:
    def test_deterministic_across_instances(self):
        a = FaultInjector(seed=5, fail_rate=0.3, corrupt_rate=0.2)
        b = FaultInjector(seed=5, fail_rate=0.3, corrupt_rate=0.2)
        keys = [f"k{i}" for i in range(200)]
        assert [a.should_fail("s3", k) for k in keys] == \
            [b.should_fail("s3", k) for k in keys]
        assert [a.should_corrupt("s3", k) for k in keys] == \
            [b.should_corrupt("s3", k) for k in keys]

    def test_interleaving_independent(self):
        """The n-th draw for a (tier, key) is a pure hash — what other keys
        or tiers did in between cannot change it."""
        a = FaultInjector(seed=9, fail_rate=0.4)
        b = FaultInjector(seed=9, fail_rate=0.4)
        seq_a = [a.should_fail("s3", "hot") for _ in range(20)]
        seq_b = []
        for i in range(20):
            b.should_fail("host_dram", f"noise{i}")  # interleaved traffic
            seq_b.append(b.should_fail("s3", "hot"))
            b.should_fail("s3", f"other{i}")
        assert seq_a == seq_b

    def test_rates_are_respected_statistically(self):
        inj = FaultInjector(seed=0, fail_rate=0.3, corrupt_rate=0.1)
        n = 4000
        fails = sum(inj.should_fail("s3", f"k{i}") for i in range(n))
        corrupts = sum(inj.should_corrupt("s3", f"k{i}") for i in range(n))
        assert abs(fails / n - 0.3) < 0.05
        assert abs(corrupts / n - 0.1) < 0.05
        assert inj.stats()["injected_failures"] == fails

    def test_per_tier_rates_and_arm(self):
        inj = FaultInjector(seed=1, fail_rate={"s3": 1.0})
        assert inj.should_fail("s3", "k")
        assert not inj.should_fail("host_dram", "k")
        inj.arm(fail_rate={"*": 0.0})
        assert not inj.should_fail("s3", "k")

    def test_brownout_window(self):
        inj = FaultInjector(seed=0)
        inj.add_brownout("host_dram", 1.0, 2.0)
        assert not inj.browned_out("host_dram", 0.5)
        assert inj.browned_out("host_dram", 1.0)
        assert inj.browned_out("host_dram", 1.999)
        assert not inj.browned_out("host_dram", 2.0)  # half-open window
        assert not inj.browned_out("s3", 1.5)
        assert inj.stats()["brownout_rejections"] == 2

    def test_due_crashes_pop_once(self):
        inj = FaultInjector(seed=0)
        inj.schedule_crash(1, 0.5)
        inj.schedule_crash(0, 2.0)
        assert inj.due_crashes(0.4) == []
        due = inj.due_crashes(1.0)
        assert [(c.replica, c.at_s) for c in due] == [(1, 0.5)]
        assert inj.due_crashes(1.0) == []  # popped, not re-fired
        assert [(c.replica, c.at_s) for c in inj.due_crashes(3.0)] == [(0, 2.0)]
        assert inj.stats()["crashes_fired"] == 2

    @given(seed=st.integers(0, 2**32 - 1),
           rate=st.floats(0.0, 1.0),
           key=st.text(min_size=1, max_size=12))
    @settings(max_examples=50, deadline=None)
    def test_draw_sequence_is_pure(self, seed, rate, key):
        a = FaultInjector(seed=seed, fail_rate=rate)
        b = FaultInjector(seed=seed, fail_rate=rate)
        assert [a.should_fail("s3", key) for _ in range(8)] == \
            [b.should_fail("s3", key) for _ in range(8)]


# --------------------------------------------------------------------------- #
# Retry policy
# --------------------------------------------------------------------------- #
class TestRetryPolicy:
    def test_backoff_grows_exponentially(self):
        p = RetryPolicy(backoff_s=0.01, backoff_factor=2.0)
        assert p.backoff(1) == pytest.approx(0.01)
        assert p.backoff(2) == pytest.approx(0.02)
        assert p.backoff(3) == pytest.approx(0.04)

    def test_attempt_bounds_and_tier_override(self):
        p = RetryPolicy(max_attempts=3, tier_max_attempts={"s3": 1},
                        cost_aware=False)
        exc = TierUnavailable("x", tier="host_dram")
        assert p.should_retry(exc, 1)
        assert p.should_retry(exc, 2)
        assert not p.should_retry(exc, 3)
        assert not p.should_retry(TierUnavailable("x", tier="s3"), 1)

    def test_permanent_failures_never_retry(self):
        p = RetryPolicy(cost_aware=False)
        assert not p.should_retry(KeyNotFound("x", tier="s3", key="k"), 1)
        assert not p.should_retry(CorruptPayload("x", at_rest=True), 1)
        assert p.should_retry(CorruptPayload("x", at_rest=False), 1)

    def test_cost_gate_prefers_recompute_when_cheaper(self):
        p = RetryPolicy(max_attempts=5, cost_aware=True)
        exc = TierUnavailable("x", tier="s3")
        # retrying is cheaper than recomputing: retry
        assert p.should_retry(exc, 1, retry_cost=1e-6, recompute_cost=1e-3)
        # recompute is cheaper: stop retrying even with attempts left
        assert not p.should_retry(exc, 1, retry_cost=1e-3,
                                  recompute_cost=1e-6)

    def test_retry_cost_prices_idle_gpu_and_refetch(self):
        p = RetryPolicy()
        gb = 1024.0 ** 3
        c = p.retry_cost(backoff_s=0.1, est_load_s=0.4, nbytes=2 * gb,
                         gpu_cost_per_s=10.0, per_gb_fee=0.5)
        assert c == pytest.approx(10.0 * 0.5 + 0.5 * 2)


# --------------------------------------------------------------------------- #
# Backend integrity: atomic spill, checksum verify, typed raises
# --------------------------------------------------------------------------- #
class TestBackendIntegrity:
    def test_disk_spill_atomic_no_stray_tmp(self, tmp_path):
        b = DiskSpillBackend("local_nvme", root=tmp_path)
        b.put("k", {"x": np.arange(8, dtype=np.float32)}, nbytes=32.0)
        assert not list(tmp_path.glob("*.tmp"))
        payload, _ = b.get("k")
        assert np.allclose(payload["x"], np.arange(8, dtype=np.float32))

    def test_disk_spill_torn_file_raises_corrupt_at_rest(self, tmp_path):
        b = DiskSpillBackend("local_nvme", root=tmp_path)
        b.put("k", {"x": np.zeros(16, np.float32)}, nbytes=64.0)
        path = b._path("k")
        path.write_bytes(path.read_bytes()[: path.stat().st_size // 2])
        with pytest.raises(CorruptPayload) as ei:
            b.get("k")
        assert ei.value.at_rest

    def test_disk_spill_bitrot_fails_embedded_checksum(self, tmp_path):
        import pickle

        b = DiskSpillBackend("local_nvme", root=tmp_path)
        b.put("k", {"x": np.zeros(16, np.float32)}, nbytes=64.0)
        path = b._path("k")
        rec = pickle.loads(path.read_bytes())
        rec["payload"]["x"][3] = 42.0  # valid pickle, rotten content
        path.write_bytes(pickle.dumps(rec))
        with pytest.raises(CorruptPayload) as ei:
            b.get("k")
        assert ei.value.at_rest

    def test_missing_key_raises_typed_not_found(self, tmp_path):
        with pytest.raises(KeyNotFound):
            DiskSpillBackend("local_nvme", root=tmp_path).get("never-put")
        with pytest.raises(KeyNotFound):
            HostMemoryBackend("host_dram").get("never-put")

    def test_memory_backend_verifies_checksum_on_get(self):
        b = HostMemoryBackend("host_dram")
        b.put("k", {"x": np.zeros(4, np.float32)}, nbytes=16.0)
        tampered = {"x": np.zeros(4, np.float32)}
        tampered["x"][0] = 1.0
        b._data["k"] = (tampered, 16.0)
        with pytest.raises(CorruptPayload) as ei:
            b.get("k")
        assert ei.value.at_rest

    def test_injected_faults_fire_after_charge(self):
        inj = FaultInjector(seed=0, fail_rate=1.0)
        b = HostMemoryBackend("host_dram", faults=inj)
        b.put("k", {"x": np.zeros(4, np.float32)}, nbytes=16.0)
        with pytest.raises(TierUnavailable) as ei:
            b.get("k")
        assert ei.value.wasted_bytes == 16.0

    def test_brownout_fails_fast_uncharged(self):
        inj = FaultInjector(seed=0)
        inj.add_brownout("host_dram", 0.0, 10.0)
        b = HostMemoryBackend("host_dram", faults=inj)
        with pytest.raises(TierUnavailable):
            b.put("k", {"x": np.zeros(4, np.float32)}, nbytes=16.0)
        with pytest.raises(TierUnavailable) as ei:
            b.get("k")
        assert ei.value.delay_s == 0.0  # no bytes ever moved


# --------------------------------------------------------------------------- #
# Store-level handling: put rollback, corrupt-entry discard
# --------------------------------------------------------------------------- #
class TestStoreFailureHandling:
    def _store(self, faults=None):
        return TieredStore(
            tiers=[TierSpec("host_dram", 1.0)], chunk_tokens=4, faults=faults,
        )

    def test_failed_put_rolls_back_all_bookkeeping(self):
        inj = FaultInjector(seed=0)
        inj.add_brownout("host_dram", 0.0, 10.0)
        s = self._store(faults=inj)
        eid, delay = s.put(list(range(8)), {"x": np.zeros(4, np.float32)},
                           tier="host_dram")
        assert eid is None and delay == 0.0
        assert s.failed_puts == 1
        assert not s.entries  # never advertised
        _, entry = s.lookup(list(range(8)))
        assert entry is None

    def test_at_rest_corruption_discards_entry(self):
        s = self._store()
        eid, _ = s.put(list(range(8)), {"x": np.zeros(4, np.float32)},
                       tier="host_dram")
        assert eid is not None
        tampered = {"x": np.zeros(4, np.float32)}
        tampered["x"][0] = 5.0
        s.backends["host_dram"]._data[eid] = (tampered, 16.0)
        with pytest.raises(CorruptPayload):
            s.fetch(eid)
        assert s.discards == 1
        assert eid not in s.entries  # next lookup plans an honest recompute


# --------------------------------------------------------------------------- #
# Queue drain (crash harvesting)
# --------------------------------------------------------------------------- #
def _req(i, arrival=0.0):
    return Request(req_id=i, context_tokens=[1, 2, 3], prompt_tokens=[4],
                   max_new_tokens=1, arrival_s=arrival)


class TestQueueDrain:
    def test_drain_returns_everything_once(self):
        q = AdmissionQueue()
        for i in range(4):
            q.push(_req(i, arrival=0.1 * i))
        q.pop_admissible(1.0)  # one already admitted: not drained
        got = q.drain()
        assert sorted(r.req_id for r in got) == [1, 2, 3]
        assert q.drain() == []
        assert q.pop_admissible(10.0) is None

    def test_drain_covers_pending_and_ready(self):
        q = AdmissionQueue()
        q.push(_req(0, arrival=0.0))
        q.push(_req(1, arrival=99.0))  # not yet arrived
        q.peek_next(0.0)  # promotes req 0 into the ready heap
        assert sorted(r.req_id for r in q.drain()) == [0, 1]


# --------------------------------------------------------------------------- #
# Engine: retries, degradation, brownout planning — tokens never change
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama-7b"))
    api = registry.get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=6, n_ctx=2, ctx_len=48, prompt_len=8, new=3, seed=0):
    rng = np.random.default_rng(seed)
    ctxs = [list(map(int, rng.integers(0, cfg.vocab, ctx_len)))
            for _ in range(n_ctx)]
    return [
        dict(req_id=i, context_tokens=ctxs[i % n_ctx],
             prompt_tokens=list(map(int, rng.integers(0, cfg.vocab,
                                                      prompt_len))),
             max_new_tokens=new, arrival_s=i * 0.01,
             expected_reuses=max(n // n_ctx, 1))
        for i in range(n)
    ]


def _run_engine(cfg, params, reqs, *, faults=None, retry=None, tel=None,
                **ec_kw):
    ec = EngineConfig(max_slots=2, max_len=128, chunk_tokens=16,
                      faults=faults, retry_policy=retry, **ec_kw)
    eng = ServingEngine(cfg, params, engine_cfg=ec,
                        planner=AlwaysReusePlanner(), telemetry=tel)
    for r in reqs:
        eng.submit(Request(**r))
    summary = eng.run()
    return eng, summary, {r.req_id: r.tokens for r in eng.records}


class TestEngineDegradation:
    def test_faulted_engine_is_token_identical(self, setup):
        cfg, params = setup
        reqs = _requests(cfg)
        _, _, tok0 = _run_engine(cfg, params, reqs)

        tel = Telemetry()
        inj = FaultInjector(seed=7, fail_rate=0.4, corrupt_rate=0.2)
        eng, summary, tok1 = _run_engine(
            cfg, params, reqs, faults=inj,
            retry=RetryPolicy(max_attempts=2, cost_aware=False), tel=tel,
        )
        assert tok1 == tok0
        fs = eng.fault_stats()
        assert fs["fetch_failures"] > 0
        assert fs["fetch_wasted_bytes"] > 0
        evs = [e for _, e in tel.events]  # replica-tagged, replica 0 here
        n_failed = sum(isinstance(e, ev.FetchFailed) for e in evs)
        n_deg = sum(isinstance(e, ev.DegradedToRecompute) for e in evs)
        assert n_failed == fs["fetch_failures"]
        assert n_deg == fs["degraded_requests"]
        # degraded requests are recorded as recompute and flagged
        degraded_ids = {e.req_id for e in evs
                        if isinstance(e, ev.DegradedToRecompute)}
        for rec in eng.records:
            assert rec.degraded == (rec.req_id in degraded_ids)
            if rec.degraded:
                assert rec.action == "recompute"
        # the ledger still conserves, wasted attempts marked zero-dollar
        tel.check(summary)
        marks = [e for e in tel.ledger.entries
                 if e.activity == "fetch_failed"]
        assert len(marks) == fs["fetch_failures"]
        assert all(m.dollars == 0.0 and m.nbytes > 0 for m in marks)

    def test_cost_aware_gate_skips_pointless_retries(self, setup):
        """At reduced-config scale recomputing a short prefix costs almost
        nothing, so the cost-aware gate degrades instead of retrying."""
        cfg, params = setup
        reqs = _requests(cfg)
        inj = FaultInjector(seed=7, fail_rate=0.8)
        eng, _, _ = _run_engine(cfg, params, reqs, faults=inj,
                                retry=RetryPolicy(max_attempts=3))
        fs = eng.fault_stats()
        assert fs["fetch_failures"] > 0 and fs["fetch_retries"] == 0

    def test_brownout_plans_around_the_tier(self, setup):
        """Entries ingested BEFORE the window exist on the browned-out tier,
        but requests arriving inside it plan an honest recompute — the
        lookup excludes unavailable tiers, so no fetch is ever attempted."""
        cfg, params = setup
        reqs = _requests(cfg)
        late = [dict(r, req_id=r["req_id"] + 10, arrival_s=1e3 + r["arrival_s"])
                for r in reqs[:2]]
        kw = dict(tier_specs=[TierSpec("host_dram", 1.0)],
                  store_tier="host_dram")
        _, _, tok0 = _run_engine(cfg, params, reqs + late, **kw)
        inj = FaultInjector(seed=1)
        inj.add_brownout("host_dram", 500.0, 1e9)
        eng, _, tok1 = _run_engine(cfg, params, reqs + late, faults=inj, **kw)
        assert tok1 == tok0
        acts = {r.req_id: r.action for r in eng.records}
        assert "load" in acts.values()  # pre-window traffic did reuse
        assert len(eng.store.entries) > 0  # entries exist on the dead tier
        assert all(acts[r["req_id"]] == "recompute" for r in late)
        # planned around, never attempted: degradation-free graceful path
        assert eng.fault_stats()["fetch_failures"] == 0
        assert inj.stats()["brownout_rejections"] > 0


# --------------------------------------------------------------------------- #
# Cluster: mid-run crash recovery + the chaos property
# --------------------------------------------------------------------------- #
def _run_cluster(cfg, params, reqs, *, faults=None, retry=None, tel=None):
    ec = EngineConfig(
        max_slots=2, max_len=128, chunk_tokens=16,
        tier_specs=[TierSpec("host_dram", 1.0), TierSpec("s3", 1.0)],
        faults=faults, retry_policy=retry,
    )
    cl = ServingCluster(cfg, params,
                        cluster_cfg=ClusterConfig(n_replicas=2),
                        engine_cfg=ec, planner_factory=AlwaysReusePlanner,
                        telemetry=tel)
    for r in reqs:
        cl.submit(Request(**r))
    summary = cl.run()
    return cl, summary, {r.req_id: r.tokens for r in cl.records}


@pytest.fixture(scope="module")
def cluster_baseline(setup):
    cfg, params = setup
    reqs = _requests(cfg, n=8)
    _, _, tok0 = _run_cluster(cfg, params, reqs)
    return reqs, tok0


class TestClusterCrash:
    def test_crash_resubmits_and_stays_token_identical(self, setup,
                                                       cluster_baseline):
        cfg, params = setup
        reqs, tok0 = cluster_baseline
        tel = Telemetry()
        inj = FaultInjector(seed=3, fail_rate=0.3)
        inj.schedule_crash(1, 0.02)
        cl, summary, tok1 = _run_cluster(
            cfg, params, reqs, faults=inj,
            retry=RetryPolicy(max_attempts=2, cost_aware=False), tel=tel,
        )
        crashes = [e for _, e in cl.events if isinstance(e, ev.ReplicaCrashed)]
        assert len(crashes) == 1 and crashes[0].replica == 1
        assert inj.stats()["crashes_fired"] == 1
        # every request (including harvested in-flight/queued ones) finished,
        # exactly once, with the fault-free tokens
        assert tok1 == tok0
        # the dead replica took no requests after the crash
        assert all(rec.req_id in tok0 for rec in cl.records)
        for i, s in enumerate(summary.replicas):
            tel.check(s, replica=i)

    def test_crash_of_missing_replica_is_ignored(self, setup,
                                                 cluster_baseline):
        cfg, params = setup
        reqs, tok0 = cluster_baseline
        inj = FaultInjector(seed=0)
        inj.schedule_crash(7, 0.01)  # no such replica
        inj.schedule_crash(1, 0.01)
        inj.schedule_crash(1, 0.03)  # double-kill: second must be a no-op
        cl, _, tok1 = _run_cluster(cfg, params, reqs, faults=inj)
        crashes = [e for _, e in cl.events if isinstance(e, ev.ReplicaCrashed)]
        assert len(crashes) == 1
        assert tok1 == tok0


class TestChaosProperty:
    """ISSUE acceptance: ANY seeded fault schedule leaves cluster tokens
    bitwise-identical to the fault-free run and the ledger conserving."""

    @given(seed=st.integers(0, 2**16),
           fail_rate=st.floats(0.0, 0.5),
           corrupt_rate=st.floats(0.0, 0.3),
           crash_replica=st.integers(0, 1),
           crash_at=st.floats(0.0, 0.3))
    @settings(max_examples=5, deadline=None)
    def test_any_schedule_token_identical_and_conserving(
            self, setup, cluster_baseline, seed, fail_rate, corrupt_rate,
            crash_replica, crash_at):
        cfg, params = setup
        reqs, tok0 = cluster_baseline
        tel = Telemetry()
        inj = FaultInjector(seed=seed, fail_rate=fail_rate,
                            corrupt_rate=corrupt_rate)
        inj.add_brownout("host_dram", crash_at, crash_at + 0.05)
        inj.schedule_crash(crash_replica, crash_at)
        cl, summary, tok1 = _run_cluster(
            cfg, params, reqs, faults=inj,
            retry=RetryPolicy(max_attempts=2), tel=tel,
        )
        assert tok1 == tok0
        for i, s in enumerate(summary.replicas):
            tel.check(s, replica=i)
